import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) pair on the
production meshes, with NO device allocation (ShapeDtypeStruct inputs).

    PYTHONPATH=src python -m repro.launch.dryrun                 # full grid
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi4-mini-3.8b \
        --shape decode_32k --multi-pod                           # one pair

Per pair it records compile success, ``memory_analysis()`` (fits-in-HBM
proof), ``cost_analysis()`` FLOPs/bytes, and the parsed collective schedule
-- the inputs to EXPERIMENTS.md §Dry-run and §Roofline. Results stream into
experiments/dryrun_<mesh>.json so partial runs resume.

The XLA_FLAGS line above MUST run before any other import: jax locks the
device count at first init, and the 16x16 / 2x16x16 meshes need 512 host
placeholder devices. Smoke tests and benchmarks never import this module.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SKIPS
from repro.configs.shapes import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.roofline.analysis import roofline_from_compiled
from repro.roofline.analytic import bytes_estimate, flops_estimate

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments")


def run_pair(arch: str, shape: str, mesh, *, chips: int, fsdp: bool = True,
             weight_stationary: bool = True, verbose: bool = True) -> dict:
    t0 = time.time()
    spec = build_step(arch, shape, mesh, fsdp=fsdp,
                      decode_batch_replicated=weight_stationary)
    if spec is None:
        return {"arch": arch, "shape": shape, "status": "skipped",
                "reason": SKIPS[(arch, shape)]}
    try:
        with mesh:
            jitted = jax.jit(spec.step,
                             in_shardings=spec.in_shardings,
                             out_shardings=spec.out_shardings,
                             donate_argnums=spec.donate_argnums)
            lowered = jitted.lower(*spec.args)
            compiled = lowered.compile()
        mf = flops_estimate(spec.model_cfg, spec.shape_cfg)
        ab = bytes_estimate(spec.model_cfg, spec.shape_cfg)
        rep = roofline_from_compiled(spec.name, compiled, chips=chips,
                                     model_flops=mf, analytic_bytes=ab)
        mem = {}
        try:
            ma = compiled.memory_analysis()
            mem = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_bytes": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
            }
        except Exception as e:           # CPU backend may not implement it
            mem = {"error": str(e)}
        out = {"arch": arch, "shape": shape, "status": "ok",
               "step": spec.name.split("/")[-1],
               "compile_s": round(time.time() - t0, 1),
               "memory": mem,
               "roofline": rep.as_dict()}
        if verbose:
            peak = mem.get("peak_bytes")
            peak_s = f"{peak/1e9:7.2f} GB" if peak else "    n/a"
            print(f"OK   {arch:22s} {shape:12s} {out['step']:7s} "
                  f"compile={out['compile_s']:6.1f}s peak={peak_s} "
                  f"dom={rep.dominant:10s} "
                  f"c/m/x={rep.compute_s*1e3:.1f}/{rep.memory_s*1e3:.1f}/"
                  f"{rep.collective_s*1e3:.1f} ms", flush=True)
        return out
    except Exception as e:
        if verbose:
            print(f"FAIL {arch:22s} {shape:12s} {type(e).__name__}: "
                  f"{str(e)[:200]}", flush=True)
            traceback.print_exc()
        return {"arch": arch, "shape": shape, "status": "fail",
                "error": f"{type(e).__name__}: {e}",
                "compile_s": round(time.time() - t0, 1)}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-weight-stationary", action="store_true",
                    help="paper-faithful baseline decode (batch-sharded; "
                    "weights gathered every step)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [(False,), (True,)] if args.both_meshes else \
        [(args.multi_pod,)]

    os.makedirs(OUT_DIR, exist_ok=True)
    any_fail = False
    for (multi_pod,) in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = 512 if multi_pod else 256
        tag = "multipod" if multi_pod else "singlepod"
        out_path = args.out or os.path.join(OUT_DIR, f"dryrun_{tag}.json")
        results = {}
        if os.path.exists(out_path):
            with open(out_path) as f:
                results = json.load(f)
        print(f"== mesh {dict(mesh.shape)} ({chips} chips) -> {out_path}",
              flush=True)
        for arch in archs:
            for shape in shapes:
                key = f"{arch}|{shape}"
                if results.get(key, {}).get("status") == "ok":
                    continue
                results[key] = run_pair(
                    arch, shape, mesh, chips=chips, fsdp=not args.no_fsdp,
                    weight_stationary=not args.no_weight_stationary)
                if results[key]["status"] == "fail":
                    any_fail = True
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
        n_ok = sum(1 for r in results.values() if r["status"] == "ok")
        n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
        n_fail = sum(1 for r in results.values() if r["status"] == "fail")
        print(f"== {tag}: {n_ok} ok / {n_skip} skipped / {n_fail} failed",
              flush=True)
    return 1 if any_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
