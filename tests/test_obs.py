"""repro.obs (PR tentpole): per-request lifecycle tracing, fleet
metrics export, and the profiling baseline plumbing.

Contracts locked down here:

  * ZERO overhead when off: the default engine/server hold NULL_TRACER
    and the hot path performs no tracer calls at all (every NullTracer
    method is patched to raise; a full serve run must not trip one),
  * tracing changes nothing: a traced cluster run is bit-identical to
    the untraced run at temperature 0,
  * trace completeness: a disaggregated run with real KV migrations
    produces one contiguous, fully-closed trace per request
    (``validate_trace(..., require_migrations=True)`` is clean) --
    including under abort mid-chunked-prefill, decode-target death
    during migration, and disconnect-timeout,
  * the runtime sanitizer cross-checks the tracer: a span deleted out
    from under a live request (or left open past its request) is a
    ``SanitizerError`` at the next step boundary,
  * the Perfetto/Chrome export shape, the Prometheus text snapshot,
    the shared ``repro.obs.stats`` summary helper, the JSONL sink, the
    validate CLI, and the ``scripts/trace_report.py`` attribution.
"""
import asyncio
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.analysis.sanitizer import SanitizerError
from repro.api import EngineConfig, GenerationConfig, LVLM, Request
from repro.core.serving.disaggregation import CostModel
from repro.obs import (JsonlSink, NULL_TRACER, NullTracer, Tracer,
                       load_trace, mean_or_none, percentile_summary,
                       summarize_records, to_chrome_trace, validate_trace,
                       write_chrome_trace)
from repro.serving.metrics import MetricsRegistry

MAX_NEW = 6
GEN = GenerationConfig(decoder="greedy", temperature=0.0,
                       max_new_tokens=MAX_NEW)
COST = CostModel(kv_bytes_per_token=100_000)


@pytest.fixture(scope="module")
def lvlm():
    return LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)


def _ec(**kw):
    base = dict(max_batch=4, cache_len=96, temperature=0.0, sanitize=True)
    base.update(kw)
    return EngineConfig(**base)


def _prompts(n, seed=0, lo=8, hi=16):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, 512, size=rng.randint(lo, hi)))
            for _ in range(n)]


def _reqs(prompts, new=MAX_NEW):
    return [Request(rid=i, tokens=list(p), max_new_tokens=new)
            for i, p in enumerate(prompts)]


async def _consume(stream):
    return [tok async for tok in stream]


def _drive_all(front, reqs):
    async def drive():
        async with front:
            return await asyncio.gather(
                *(_consume(front.submit(r)) for r in reqs))

    outs = asyncio.run(drive())
    return {r.rid: list(o) for r, o in zip(reqs, outs)}


# ------------------------------------------------- zero overhead when off --


def test_untraced_hot_path_makes_no_tracer_calls(lvlm, monkeypatch):
    """The default (untraced) stack must not call ANY tracer method --
    guarded sites skip on ``enabled`` alone. Patching every NullTracer
    emit to raise turns a single stray call into a test failure."""
    def boom(*a, **k):
        raise AssertionError("tracer method called on the untraced path")

    for name in ("span_begin", "span_end", "span_abort", "instant",
                 "slice", "counter"):
        monkeypatch.setattr(NullTracer, name, boom)
    res = lvlm.serve(_reqs(_prompts(3, seed=1)), engine_cfg=_ec(), gen=GEN)
    assert res.engine.tracer is NULL_TRACER
    assert res.stats["finished"] == 3
    # the async/cluster path too (admission, pump counters, migration)
    router = lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                roles=["prefill", "decode"])
    got = _drive_all(router, _reqs(_prompts(2, seed=2)))
    assert all(len(o) == MAX_NEW for o in got.values())


def test_traced_run_is_bit_identical_at_temp0(lvlm):
    prompts = _prompts(4, seed=3)
    ref = _drive_all(lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                        roles=["prefill", "decode"]),
                     _reqs(prompts))
    tracer = Tracer()
    got = _drive_all(lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                        roles=["prefill", "decode"],
                                        obs=tracer),
                     _reqs(prompts))
    assert got == ref
    assert tracer.events            # and the traced run actually traced


# ------------------------------------------------------ trace completeness --


def test_disagg_trace_is_complete_across_migrations(lvlm):
    """One shared tracer across a prefill/decode fleet: every request
    yields one contiguous trace that survives the migration boundary,
    with zero orphan spans and monotonic per-request clocks."""
    tracer = Tracer()
    router = lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                roles=["prefill", "decode"], obs=tracer)
    got = _drive_all(router, _reqs(_prompts(4, seed=4)))
    assert all(len(o) == MAX_NEW for o in got.values())
    assert router.summary()["disaggregation"]["migrations"] == 4
    assert tracer.open_spans() == []
    assert tracer.open_requests() == set()
    problems = validate_trace(to_chrome_trace(tracer.events),
                              require_migrations=True)
    assert problems == []
    # the migration span begins on the source replica and ends on the
    # importer -- ONE span, two replicas
    for rid in got:
        b = next(e for e in tracer.events
                 if e["k"] == "B" and e["name"] == "kv_migration"
                 and e["rid"] == rid)
        e = next(e for e in tracer.events
                 if e["k"] == "E" and e["name"] == "kv_migration"
                 and e["rid"] == rid)
        assert (b["rep"], e["rep"]) == (0, 1)


def test_abort_mid_chunked_prefill_closes_trace(lvlm):
    """Aborting a request between prefill chunks closes every open span
    (request + prefill) with the abort marker -- no orphans, and the
    sanitizer (on at every pump iteration) stays clean."""
    tracer = Tracer()
    server = lvlm.serve_async(
        _ec(cache_len=128, scheduler="chunked", chunk_size=8),
        gen=GEN, obs=tracer)
    eng = server.engine
    prompt = list(np.random.RandomState(5).randint(1, 512, size=40))
    steps = {"n": 0}
    real_step = eng.step

    def step_then_abort():
        progressed = real_step()
        steps["n"] += 1
        if steps["n"] == 2:          # 40-token prompt, 8-token chunks:
            server.abort(0)          # still mid-prefill, span open
        return progressed

    eng.step = step_then_abort

    async def drive():
        async with server:
            s = server.submit(Request(rid=0, tokens=prompt,
                                      max_new_tokens=MAX_NEW))
            return await _consume(s), s

    got, stream = asyncio.run(drive())
    assert stream.aborted and got == []
    begun = {e["name"] for e in tracer.events if e["k"] == "B"}
    assert "prefill" in begun        # the abort really hit mid-prefill
    assert tracer.open_spans() == []
    ends = [e for e in tracer.events if e["k"] == "E"
            and (e.get("attrs") or {}).get("aborted")]
    assert {e["name"] for e in ends} >= {"request", "prefill"}
    assert validate_trace(to_chrome_trace(tracer.events)) == []


def test_decode_target_death_closes_trace(lvlm):
    """Every decode target refuses the import: the export cancels and
    the request resumes on the source -- the kv_migration span still
    closes (cancelled), the request span closes at finish."""
    tracer = Tracer()
    router = lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                roles=["prefill", "decode"], obs=tracer)

    async def broken_import(request, ticket, *, ready_at=0.0):
        raise RuntimeError("injected import failure (dead importer)")

    router.replicas[1].server.import_stream = broken_import
    got = _drive_all(router, _reqs(_prompts(2, seed=6)))
    assert all(len(o) == MAX_NEW for o in got.values())
    assert router.migrations == []
    assert tracer.open_spans() == []
    cancelled = [e for e in tracer.events
                 if e["k"] == "E" and e["name"] == "kv_migration"
                 and (e.get("attrs") or {}).get("cancelled")]
    assert len(cancelled) == 2
    assert validate_trace(to_chrome_trace(tracer.events)) == []


def test_disconnect_timeout_closes_trace(lvlm):
    """A consumer hang-up aborts via the pump's disconnect sweep: the
    trace closes with the abort marker instead of leaking the span."""
    tracer = Tracer()
    server = lvlm.serve_async(_ec(), gen=GEN, disconnect_timeout_s=0.05,
                              obs=tracer)
    eng = server.engine
    real_step = eng.step

    def paced_step():
        import time
        time.sleep(0.02)
        return real_step()

    eng.step = paced_step
    p0, p1 = _prompts(2, seed=7, lo=10, hi=12)
    r_stall = Request(rid=0, tokens=p0, max_new_tokens=24)
    r_live = Request(rid=1, tokens=p1, max_new_tokens=24)

    async def drive():
        async with server:
            s0 = server.submit(r_stall)
            t1 = asyncio.create_task(_consume(server.submit(r_live)))
            got = []
            async for tok in s0:
                got.append(tok)
                if len(got) == 2:
                    await asyncio.sleep(0.5)     # consumer goes silent
            return got, await t1, s0

    got, out1, s0 = asyncio.run(drive())
    assert s0.disconnected and len(out1) == 24
    assert server.disconnects == 1
    assert tracer.open_spans() == []
    end = next(e for e in tracer.events if e["k"] == "E"
               and e["name"] == "request" and e["rid"] == 0)
    assert (end.get("attrs") or {}).get("aborted")
    assert validate_trace(to_chrome_trace(tracer.events)) == []


# -------------------------------------------- sanitizer <-> tracer cross --


def test_sanitizer_flags_missing_span_for_live_request(lvlm):
    tracer = Tracer()
    eng = lvlm._serve_engine(_ec(), GEN, tracer=tracer)
    eng.submit(Request(rid=0, tokens=_prompts(1, seed=8)[0],
                       max_new_tokens=MAX_NEW))
    # tamper: close the live request's span out from under it
    tracer.span_end("request", 0, replica=0, vt=eng.clock)
    with pytest.raises(SanitizerError, match="no open trace span"):
        eng.step()


def test_sanitizer_flags_orphan_span(lvlm):
    tracer = Tracer()
    eng = lvlm._serve_engine(_ec(), GEN, tracer=tracer)
    eng.submit(Request(rid=0, tokens=_prompts(1, seed=9)[0],
                       max_new_tokens=MAX_NEW))
    # tamper: open a span for a request this replica never saw
    tracer.span_begin("request", 99, replica=0, vt=eng.clock)
    with pytest.raises(SanitizerError, match="orphan span"):
        eng.step()


def test_span_abort_closes_all_open_spans_innermost_first():
    t = Tracer()
    t.span_begin("request", 1, replica=0, vt=0.0)
    t.span_begin("prefill", 1, replica=0, vt=0.1)
    t.span_begin("request", 2, replica=0, vt=0.1)
    t.span_abort(1, replica=0, vt=0.2, reason="test")
    assert t.open_spans() == [(2, "request")]
    ends = [e for e in t.events if e["k"] == "E"]
    assert [e["name"] for e in ends] == ["prefill", "request"]
    assert all(e["attrs"]["aborted"] and e["attrs"]["reason"] == "test"
               for e in ends)
    assert t.open_requests(0) == {2}


def test_double_begin_auto_aborts_stale_span():
    t = Tracer()
    t.span_begin("request", 1, replica=0, vt=0.0)
    t.span_begin("request", 1, replica=1, vt=0.5)
    # the stale span closed (aborted), the new one is open on replica 1
    assert t.open_spans() == [(1, "request")]
    assert t.open_requests(1) == {1}
    assert validate_trace(to_chrome_trace(t.events + [
        t._event("E", "request", rid=1, replica=1, vt=0.6)])) == []


# ------------------------------------------------------- perfetto export --


def _tiny_trace():
    t = Tracer()
    t.span_begin("request", 1, replica=0, vt=0.0, prompt_len=8)
    t.instant("first_token", 1, replica=0, vt=0.001)
    t.slice("engine_step", 0.0, 0.002, replica=0)
    t.slice("decode_step", 0.0, 0.002, replica=0, slot=3, rid=1)
    t.counter("kv_committed_tokens", 12, replica=0, vt=0.002)
    t.span_end("request", 1, replica=0, vt=0.002, tokens=4)
    return t


def test_chrome_trace_shape():
    doc = to_chrome_trace(_tiny_trace().events)
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["name"] for m in meta} >= {"process_name", "thread_name"}
    b = next(e for e in evs if e["ph"] == "b")
    e_ = next(e for e in evs if e["ph"] == "e")
    assert b["cat"] == e_["cat"] == "request"
    assert b["id"] == e_["id"] == 1
    assert b["ts"] == 0.0 and e_["ts"] == pytest.approx(2000.0)  # vt * 1e6
    assert b["args"]["prompt_len"] == 8 and "wall_s" in b["args"]
    lanes = {e["name"]: e["tid"] for e in evs if e["ph"] == "X"}
    assert lanes["engine_step"] == 0 and lanes["decode_step"] == 4  # 1+slot
    c = next(e for e in evs if e["ph"] == "C")
    assert c["args"]["value"] == 12
    i = next(e for e in evs if e["ph"] == "i")
    assert i["s"] == "t"
    # round-trips through json
    json.loads(json.dumps(doc))


def test_write_and_load_chrome_trace(tmp_path):
    p = str(tmp_path / "trace.json")
    write_chrome_trace(_tiny_trace().events, p)
    doc = load_trace(p)
    assert validate_trace(doc) == []


def test_validate_catches_orphans_unbalanced_and_rewinds():
    t = _tiny_trace()
    orphan = [e for e in t.events
              if not (e["k"] == "E" and e["name"] == "request")]
    probs = validate_trace(to_chrome_trace(orphan))
    assert any("orphan" in p for p in probs)
    # a request timeline that rewinds its virtual clock
    rewind = [
        {"k": "B", "name": "request", "rid": 1, "rep": 0, "vt": 1.0,
         "wt": 0.0},
        {"k": "E", "name": "request", "rid": 1, "rep": 0, "vt": 0.5,
         "wt": 1.0},
    ]
    probs = validate_trace(to_chrome_trace(rewind))
    assert any("clock went backwards" in p for p in probs)
    probs = validate_trace({"traceEvents": []})
    assert any("no request spans" in p for p in probs)


def test_validate_require_migrations():
    t = _tiny_trace()                 # a request that never migrated
    probs = validate_trace(to_chrome_trace(t.events),
                           require_migrations=True)
    assert any("migration" in p for p in probs)


def test_validate_cli(tmp_path):
    from repro.obs import validate as vmod
    good = str(tmp_path / "good.json")
    write_chrome_trace(_tiny_trace().events, good)
    assert vmod.main([good]) == 0
    bad = str(tmp_path / "bad.json")
    t = _tiny_trace()
    with open(bad, "w", encoding="utf-8") as f:
        json.dump(to_chrome_trace(t.events[:-1]), f)   # drop the close
    assert vmod.main([bad]) != 0


def test_jsonl_sink_streams_and_loads(tmp_path):
    p = str(tmp_path / "events.jsonl")
    t = Tracer()
    sink = JsonlSink(p)
    t.add_sink(sink)
    t.span_begin("request", 1, replica=0, vt=0.0)
    t.span_end("request", 1, replica=0, vt=0.1)
    sink.close()
    lines = [json.loads(line) for line in open(p, encoding="utf-8")]
    assert lines == t.events
    assert validate_trace(load_trace(p)) == []   # jsonl auto-converts


# --------------------------------------------------------- prometheus --


def test_server_metrics_snapshot(lvlm):
    server = lvlm.serve_async(_ec(), gen=GEN)
    got = _drive_all(server, _reqs(_prompts(3, seed=10)))
    assert all(len(o) == MAX_NEW for o in got.values())
    text = server.metrics_snapshot()
    assert "# TYPE repro_requests_finished_total counter" in text
    assert "repro_requests_finished_total 3.0" in text
    assert 'repro_ttft_seconds{quantile="0.5"}' in text
    assert "repro_ttft_seconds_count 3" in text
    assert "repro_kv_committed_tokens 0.0" in text
    assert "repro_admitted_total 3.0" in text
    # HELP/TYPE headers appear once per family
    assert text.count("# TYPE repro_requests_finished_total") == 1


def test_router_metrics_snapshot_labels_replicas(lvlm):
    router = lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                roles=["prefill", "decode"])
    got = _drive_all(router, _reqs(_prompts(2, seed=11)))
    assert all(len(o) == MAX_NEW for o in got.values())
    text = router.metrics_snapshot()
    assert 'replica="0"' in text and 'replica="1"' in text
    assert "repro_migrations_total 2.0" in text
    assert "repro_migrated_kv_tokens_total" in text
    assert 'repro_migrated_in_total{replica="1"} 2.0' in text
    # parses as prometheus text: every non-comment line is name{...} value
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        assert name and float(value) is not None


# ----------------------------------------------------- stats helper --


def test_stats_helper_handles_empty_and_matches_registry():
    assert mean_or_none([]) is None
    assert mean_or_none([1.0, 3.0]) == 2.0
    s = percentile_summary([], "ttft")
    assert s["ttft_p50"] is None and s["ttft_p95"] is None
    out = summarize_records([])
    assert out["finished"] == 0 and out["ttft_p50"] is None
    # the registry summary IS the shared helper's output (plus engine
    # extras) -- the dedup satellite's contract
    reg = MetricsRegistry()
    req = Request(rid=0, tokens=[1, 2, 3], max_new_tokens=2)
    req.first_token_time, req.finish_time = 0.01, 0.02
    req.submit_time, req.start_time = 0.0, 0.0
    req.generated = [5, 6]
    rec = reg.observe(req, queue_wait=0.002, decoder="greedy")
    assert rec.tokens == 2
    assert reg.summary() == summarize_records(reg.records)


# ----------------------------------------------------- trace_report --


def _load_trace_report():
    root = os.path.join(os.path.dirname(__file__), "..")
    spec = importlib.util.spec_from_file_location(
        "trace_report", os.path.join(root, "scripts", "trace_report.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_trace_report_attribution_sums_to_lifetime(lvlm, tmp_path, capsys):
    tracer = Tracer()
    router = lvlm.serve_cluster(
        2, _ec(cost=COST, scheduler="chunked", chunk_size=8),
        gen=GEN, roles=["prefill", "decode"], obs=tracer)
    got = _drive_all(router, _reqs(_prompts(3, seed=12, lo=20, hi=30)))
    assert all(len(o) == MAX_NEW for o in got.values())
    p = str(tmp_path / "events.jsonl")
    tracer.write_jsonl(p)
    tr = _load_trace_report()
    request, stages = tr.attribute(tr.load_events(p))
    assert set(request) == set(got)
    for rid, (b, e, aborted) in request.items():
        assert not aborted
        named = sum(stages[rid].values())
        assert 0.0 <= named <= (e - b) + 1e-9
        assert stages[rid]["kv_migration"] > 0.0    # it really migrated
    assert tr.main([p]) == 0
    out = capsys.readouterr().out
    assert "kv_migration" in out and "engine occupancy" in out
