"""Hypothesis shim: use the real library when installed, else a tiny
random-draw fallback so the property tests still RUN (no shrinking, no
database -- just ``max_examples`` seeded random examples per test).

Test modules import ``given`` / ``settings`` / ``st`` from here instead of
from ``hypothesis`` directly; the suite collects and passes either way.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random as _random

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    class st:  # noqa: N801  (mimics the hypothesis.strategies module)
        @staticmethod
        def integers(min_value=0, max_value=1 << 31):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.randint(0, 1)))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda r: r.choice(items))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(
                lambda r: [elements._draw(r)
                           for _ in range(r.randint(min_size, max_size))])

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda r: tuple(s._draw(r) for s in strats))

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*garg_strats, **gkw_strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                # deterministic per-test seed (no flaky CI)
                rng = _random.Random(fn.__qualname__)
                for _ in range(n):
                    drawn = [s._draw(rng) for s in garg_strats]
                    dkw = {k: s._draw(rng) for k, s in gkw_strats.items()}
                    fn(*args, *drawn, **kwargs, **dkw)
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
