"""Benchmark harness: one module per survey taxonomy category.

    PYTHONPATH=src python -m benchmarks.run [category ...]

Rows print as ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import time

from benchmarks import (bench_decoding, bench_kernels, bench_kv_cache,
                        bench_moe, bench_serving, bench_token_compression)

CATEGORIES = {
    "token_compression": bench_token_compression.run,   # survey dim 1
    "kv_cache": bench_kv_cache.run,                     # survey dim 2a/2b
    "serving": bench_serving.run,                       # survey dim 2c
    "kernels": bench_kernels.run,                       # survey dim 3c
    "moe": bench_moe.run,                               # survey dim 3b + §V
    "decoding": bench_decoding.run,                     # survey dim 4
}


def main() -> None:
    picks = sys.argv[1:] or list(CATEGORIES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in picks:
        if name not in CATEGORIES:
            raise SystemExit(f"unknown category {name!r}; "
                             f"known: {sorted(CATEGORIES)}")
        CATEGORIES[name]()
    print(f"# total {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
