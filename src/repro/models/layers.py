"""Functional layer library (no flax): ParamSpec trees + pure apply fns.

Conventions
-----------
* Params are nested dicts of jnp arrays. Each module contributes a nested
  dict of ``ParamSpec`` describing shape + *logical* sharding axes; the
  sharding package maps logical axes -> mesh axes.
* Weight layouts keep heads unfused: wq [embed, heads, head_dim] etc., so
  the "heads" logical axis is shardable independently of head_dim.
* All matmuls accumulate in float32 (``preferred_element_type``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# param specs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float = 1.0            # stddev for normal (caller fan-in adjusts)
    dtype: Optional[str] = None   # override model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, init="normal", scale=None, dtype=None) -> ParamSpec:
    if scale is None:
        # default fan-in init: 1/sqrt(first contracted dim)
        scale = 1.0 / max(1.0, float(shape[0])) ** 0.5 if init == "normal" else 1.0
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def is_leaf_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree, path=()):
    """Map ``fn(path, spec)`` over a nested dict of ParamSpec."""
    if isinstance(tree, dict):
        return {k: tree_map_specs(fn, v, path + (k,)) for k, v in tree.items()}
    return fn(path, tree)


def init_params(specs, key: jax.Array, default_dtype: str):
    """Materialize a param tree from a spec tree (deterministic per path)."""
    def _one(path, s: ParamSpec):
        dt = jnp.dtype(s.dtype or default_dtype)
        if s.init == "zeros":
            return jnp.zeros(s.shape, dt)
        if s.init == "ones":
            return jnp.ones(s.shape, dt)
        # stable across processes: Python's hash() is PYTHONHASHSEED-
        # randomized, which made param init (and any borderline argmax
        # downstream) differ run to run
        import zlib
        k = jax.random.fold_in(
            key, zlib.crc32("/".join(path).encode()) % (2 ** 31))
        if dt == jnp.int8:      # quantized weights: scale lives separately
            return jax.random.randint(k, s.shape, -64, 65, jnp.int32
                                      ).astype(jnp.int8)
        return (jax.random.normal(k, s.shape, jnp.float32) * s.scale).astype(dt)
    return tree_map_specs(_one, specs)


def abstract_params(specs, default_dtype: str):
    """ShapeDtypeStruct tree for lowering without allocation (dry-run)."""
    def _one(path, s: ParamSpec):
        return jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or default_dtype))
    return tree_map_specs(_one, specs)


def param_count(specs) -> int:
    total = 0

    def _one(path, s: ParamSpec):
        nonlocal total
        total += int(np.prod(s.shape))
        return s
    tree_map_specs(_one, specs)
    return total


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def norm_specs(cfg, with_bias: Optional[bool] = None) -> Dict[str, ParamSpec]:
    use_bias = cfg.norm == "layernorm" if with_bias is None else with_bias
    out = {"scale": spec((cfg.d_model,), ("embed",), init="ones")}
    if use_bias:
        out["bias"] = spec((cfg.d_model,), ("embed",), init="zeros")
    return out


def constrain_batch_sharding(x):
    """Pin [B, S, d] activations to batch->data(+pod) sharding.

    Tried as §Perf iteration 4 at dense-layer boundaries and REFUTED
    (collective 44.0s -> 48.6s, peak +108 GB on deepseek-v3 train_4k:
    GSPMD's own placement was already better). Kept as a utility --
    no-op without an active mesh (CPU smoke paths).
    """
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.shape:
            from jax._src import mesh as _mesh_lib
            am = _mesh_lib.thread_resources.env.physical_mesh
        if am is None or not am.shape:
            return x
        axes = tuple(a for a in ("pod", "data") if a in am.shape)
        parts = 1
        for a in axes:
            parts *= am.shape[a]
        if parts <= 1 or x.shape[0] % parts:
            return x
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            x, P(axes, *([None] * (x.ndim - 1))))
    except Exception:
        return x


def constrain_replicated(x):
    """Pin a (small) decode activation to full replication: the SPMD
    partitioner then computes fsdp-sharded matmuls as partial-sum +
    all-reduce of the tiny per-token activations instead of all-gathering
    the weight shards every decode step (weight-stationary decode)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.shape:
            from jax._src import mesh as _mesh_lib
            am = _mesh_lib.thread_resources.env.physical_mesh
        if am is None or not am.shape:
            return x
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P())
    except Exception:
        return x


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def quantize_ffn_params(params):
    """Post-training int8 quantization of FFN weights (per-out-channel).

    Walks the param tree; every MLP dict ({wi|wi_gate,wi_up}, wo) gets its
    weights replaced by int8 + f32 scale pairs matching the
    ``weight_quant='int8_ffn'`` spec layout. Stacked-layer leading dims are
    handled transparently (scales are per [layer, out_channel]... reduced
    over the input dim only).
    """
    def quant(w):
        wf = jnp.asarray(w, jnp.float32)
        scale = jnp.max(jnp.abs(wf), axis=-2, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-8)
        q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
        return q, jnp.squeeze(scale, -2)

    def walk(node):
        if not isinstance(node, dict):
            return node
        if "wo" in node and ("wi" in node or "wi_gate" in node) \
                and not any(k.endswith("_s") for k in node):
            out = dict(node)
            for k in ("wi", "wi_gate", "wi_up", "wo"):
                if k in node:
                    out[k], out[k + "_s"] = quant(node[k])
            return out
        return {k: walk(v) for k, v in node.items()}

    return walk(params)


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------

def embed_specs(cfg) -> Dict[str, ParamSpec]:
    out = {"tok": spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                       scale=0.02)}
    if not cfg.tie_embeddings:
        out["unembed"] = spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return out


def embed_tokens(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p, x, softcap: float = 0.0):
    w = p["unembed"] if "unembed" in p else p["tok"].T
    logits = jnp.einsum("...d,dv->...v", x, w,
                        preferred_element_type=jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


# --------------------------------------------------------------------------
# rotary embeddings (RoPE and Qwen2-VL M-RoPE)
# --------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64)
                            / head_dim))


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions [..., S] -> cos,sin [..., S, head_dim//2] (float32)."""
    freqs = jnp.asarray(_rope_freqs(head_dim, theta), jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def mrope_cos_sin(positions_thw, head_dim: int, theta: float, sections):
    """Qwen2-VL multimodal RoPE.

    positions_thw: [3, B, S] (temporal, height, width position ids).
    ``sections`` split head_dim//2 frequency pairs into (t, h, w) groups;
    each group takes its angle from the corresponding position stream.
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    cos_t, sin_t = [], []
    freqs = jnp.asarray(_rope_freqs(head_dim, theta), jnp.float32)
    start = 0
    for i, sec in enumerate(sections):
        f = freqs[start:start + sec]
        ang = positions_thw[i].astype(jnp.float32)[..., None] * f
        cos_t.append(jnp.cos(ang))
        sin_t.append(jnp.sin(ang))
        start += sec
    return jnp.concatenate(cos_t, -1), jnp.concatenate(sin_t, -1)


def apply_rope(x, cos, sin):
    """x [..., S, H, D]; cos/sin [..., S, D//2] broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs (SwiGLU / squared-ReLU / GELU)
# --------------------------------------------------------------------------

def mlp_specs(cfg, d_ff: Optional[int] = None, axes_in: str = "embed",
              ffn_axis: str = "ffn") -> Dict[str, ParamSpec]:
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    quant = getattr(cfg, "weight_quant", "none") == "int8_ffn"
    wdt = "int8" if quant else None
    if cfg.activation == "swiglu":
        out = {
            "wi_gate": spec((d, d_ff), (axes_in, ffn_axis), dtype=wdt),
            "wi_up": spec((d, d_ff), (axes_in, ffn_axis), dtype=wdt),
            "wo": spec((d_ff, d), (ffn_axis, axes_in), dtype=wdt),
        }
        if quant:
            out["wi_gate_s"] = spec((d_ff,), (ffn_axis,), init="ones",
                                    dtype="float32")
            out["wi_up_s"] = spec((d_ff,), (ffn_axis,), init="ones",
                                  dtype="float32")
            out["wo_s"] = spec((d,), (axes_in,), init="ones",
                               dtype="float32")
        return out
    out = {
        "wi": spec((d, d_ff), (axes_in, ffn_axis), dtype=wdt),
        "wo": spec((d_ff, d), (ffn_axis, axes_in), dtype=wdt),
    }
    if quant:
        out["wi_s"] = spec((d_ff,), (ffn_axis,), init="ones",
                           dtype="float32")
        out["wo_s"] = spec((d,), (axes_in,), init="ones", dtype="float32")
    return out


def _qmm(x, w, scale):
    """x @ int8-w with per-output-channel dequant AFTER the matmul: the
    int8 weight is what moves through HBM/ICI (half the bf16 bytes); the
    MXU-side dequant is a cheap row scale. Survey dim-3 efficiency staple
    for serving (§Perf int8_ffn iteration)."""
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    return y * scale.astype(jnp.float32)


def apply_mlp(p, x, activation: str):
    quant = "wo_s" in p
    if activation == "swiglu":
        if quant:
            g = _qmm(x, p["wi_gate"], p["wi_gate_s"])
            u = _qmm(x, p["wi_up"], p["wi_up_s"])
        else:
            g = jnp.einsum("...d,df->...f", x, p["wi_gate"],
                           preferred_element_type=jnp.float32)
            u = jnp.einsum("...d,df->...f", x, p["wi_up"],
                           preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * u
    else:
        if quant:
            h = _qmm(x, p["wi"], p["wi_s"])
        else:
            h = jnp.einsum("...d,df->...f", x, p["wi"],
                           preferred_element_type=jnp.float32)
        if activation == "relu2":
            h = jnp.square(jax.nn.relu(h))
        elif activation == "gelu":
            h = jax.nn.gelu(h)
        else:
            raise ValueError(activation)
    h = h.astype(x.dtype)
    if quant:
        y = jnp.einsum("...f,fd->...d", h, p["wo"].astype(h.dtype),
                       preferred_element_type=jnp.float32)
        return (y * p["wo_s"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
