"""Benchmark: visual token compression (survey dim 1).

Measures, per pruner:
  * wall time of the compression op itself,
  * attention-FLOPs saved at the backbone (quadratic in kept tokens),
  * QUALITY: end-to-end logit fidelity -- KL(full-model || pruned-model)
    on a smoke VLM -- plus oracle-attention recall of the kept set.
The survey's core claim: large visual-token reductions cost little output
fidelity because visual tokens are redundant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jit
from repro.configs import get_config
# analysis: allow L001 (micro-bench: times internal pruning kernels
# directly rather than through the per-request facade strategies)
from repro.core.token_compression.pruning import PRUNERS
from repro.models import build


def _kl(p_logits, q_logits):
    p = jax.nn.log_softmax(p_logits, -1)
    q = jax.nn.log_softmax(q_logits, -1)
    return float(jnp.sum(jnp.exp(p) * (p - q), -1).mean())


def run() -> None:
    cfg = get_config("qwen2-vl-2b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    b, s, nv, d = 2, 24, cfg.num_visual_tokens, cfg.d_model

    # structured "image": few distinct textures + noise (redundancy source)
    centers = rng.randn(4, d) * 0.5
    ve = np.stack([centers[rng.randint(4, size=nv)]
                   + 0.05 * rng.randn(nv, d) for _ in range(b)])
    batch = {
        "tokens": jnp.asarray(rng.randint(1, cfg.vocab_size, (b, s))),
        "visual_embeds": jnp.asarray(ve, jnp.float32),
    }
    full_logits, _ = jax.jit(model.forward)(params, batch)
    full_last = full_logits[:, -1]

    fwd = jax.jit(model.forward)
    for name in sorted(PRUNERS):
        for keep_ratio in (0.5, 0.25):
            keep = max(1, int(nv * keep_ratio))
            kwargs = {}
            if name == "fastv":
                kwargs["scores"] = jnp.asarray(rng.rand(b, nv), jnp.float32)
            if name in ("sparsevlm", "cdpruner"):
                emb = jax.jit(lambda p, t: p["embed"]["tok"][t])(
                    params, batch["tokens"])
                kwargs["query"] = emb
            fn = jax.jit(lambda e, kw=kwargs, n=name, k=keep:
                         PRUNERS[n](e, k, **kw)[0])
            us = time_jit(fn, batch["visual_embeds"])
            kept = fn(batch["visual_embeds"])
            pruned_logits, _ = fwd(params, dict(batch, visual_embeds=kept))
            kl = _kl(full_last, pruned_logits[:, -1])
            # attention FLOPs ~ (Nv+S)^2: report the quadratic saving
            frac = ((keep + s) ** 2) / ((nv + s) ** 2)
            emit(f"tokcomp/{name}/keep{keep_ratio}", us,
                 f"kl={kl:.4f};attn_flops_frac={frac:.3f}")


if __name__ == "__main__":
    run()
