"""Table-driven SLO-adaptive quality policy (the degradation ladder).

The paper's acceleration knobs -- visual-token compression ratio,
speculative ``gamma``, early-exit confidence thresholds -- all trade
quality for latency, and the right operating point depends on load
(EffiVLM-BENCH measures exactly this frontier offline; the sweep harness
in ``repro.control.sweep`` reproduces that measurement). This module is
the ONLINE half's brain: a small, fully-deterministic state machine that
maps a scalar *pressure* signal onto a rung of a degradation ladder.

The ladder is a table (``ControlConfig.ladder``): rung 0 is the
preferred operating point (no overrides at all); each deeper rung names
a more aggressive compression preset, a decoder remap (the per-request
way to shrink speculative lookahead all the way to zero:
``speculative -> greedy``), a ``gamma_scale`` applied to the engine's
registered speculative decoders, and an ``exit_scale`` applied to the
early-exit confidence threshold (scaling the threshold DOWN makes exits
fire earlier -- the degrade direction: fewer layers per token).

Thrash-proofing is structural, not statistical:

  * hysteresis -- the level only RISES when pressure >= ``high_pressure``
    and only FALLS when pressure <= ``low_pressure`` (a strict band, so a
    pressure sitting between the marks changes nothing);
  * cooldown -- consecutive level changes are separated by at least
    ``cooldown_s`` on the engine's virtual clock, and each change moves
    exactly ONE rung.

Together these give the no-oscillation property the hypothesis suite
locks down: for ANY pressure trace, two level changes are never closer
than ``cooldown_s``, so presets cannot flap within a cooldown window.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ControlLevel:
    """One rung of the degradation ladder.

    ``compression=None`` / empty ``decoder_map`` / scale 1.0 mean "leave
    that knob alone" -- rung 0 is all-defaults, i.e. no actuation.
    """
    name: str
    compression: Optional[str] = None      # Request.compression override
    decoder_map: Tuple[Tuple[str, str], ...] = ()   # e.g. (("speculative",
    #                                                        "greedy"),)
    gamma_scale: float = 1.0               # engine speculative gamma scale
    exit_scale: float = 1.0                # early-exit threshold scale (<1
    #                                        = exit earlier = cheaper)

    def remap_decoder(self, name: str) -> Optional[str]:
        for src, dst in self.decoder_map:
            if src == name:
                return dst
        return None


#: Preferred -> degraded -> aggressive. Ratios follow the presets the
#: sweep harness measures, so an operator can read the offline frontier
#: (BENCH_pareto.json) and know what each rung costs in quality.
DEFAULT_LADDER: Tuple[ControlLevel, ...] = (
    ControlLevel("preferred"),
    ControlLevel("degraded", compression="fastv-0.5", gamma_scale=0.5),
    ControlLevel("aggressive", compression="fastv-0.25",
                 decoder_map=(("speculative", "greedy"),),
                 gamma_scale=0.25, exit_scale=0.8),
)


@dataclasses.dataclass
class ControlConfig:
    """Knobs of the adaptive policy (all deterministic; virtual-clock
    cooldown, so traced/paced runs behave identically)."""
    ladder: Tuple[ControlLevel, ...] = DEFAULT_LADDER
    high_pressure: float = 0.85      # raise the level at/above this
    low_pressure: float = 0.60       # lower the level at/below this
    cooldown_s: float = 0.005        # min virtual s between level changes
    queue_ref: int = 4               # deferred-queue depth that alone
    #                                  saturates the pressure signal
    route_keep_max: float = 0.5      # replicas whose default compression
    #                                  keeps <= this fraction of visual
    #                                  tokens count as "aggressive" for
    #                                  the video routing bias

    def __post_init__(self):
        if len(self.ladder) < 1:
            raise ValueError("ladder needs at least the preferred rung")
        if self.ladder[0].compression is not None \
                or self.ladder[0].decoder_map \
                or self.ladder[0].gamma_scale != 1.0 \
                or self.ladder[0].exit_scale != 1.0:
            raise ValueError("ladder rung 0 must be the no-override "
                             "preferred operating point")
        if not 0.0 < self.low_pressure < self.high_pressure <= 2.0:
            raise ValueError("need 0 < low_pressure < high_pressure")
        if self.cooldown_s < 0.0:
            raise ValueError("cooldown_s must be >= 0")
        if self.queue_ref < 1:
            raise ValueError("queue_ref must be >= 1")


@dataclasses.dataclass
class LevelState:
    """Per-server hysteresis state: current rung + last-change clock."""
    level: int = 0
    last_change: float = float("-inf")


class AdaptivePolicy:
    """The pressure -> ladder-rung map (see module docstring).

    Stateless over servers: callers hold one ``LevelState`` per server
    and pass it to ``update``. This keeps the no-thrash property a
    one-object unit the property tests can drive with adversarial
    pressure traces and synthetic clocks.
    """

    def __init__(self, cfg: Optional[ControlConfig] = None):
        self.cfg = cfg if cfg is not None else ControlConfig()

    # ---------------------------------------------------------- signals --
    def pressure(self, server) -> float:
        """Scalar load signal in [0, ~1]: the max of the KV-watermark
        fraction and the (normalized) admission deferred-queue depth --
        exactly the two time-series ``_emit_counters`` /
        ``metrics_snapshot()`` already export (``kv_committed_tokens``,
        ``admission_queue_depth``), read live instead of scraped."""
        eng = server.engine
        kv = eng.kv_committed_tokens() / max(1, eng.kv_capacity_tokens)
        q = server.admission.queue_depth / float(self.cfg.queue_ref)
        return max(kv, min(1.0, q))

    # ------------------------------------------------------------ update --
    def update(self, state: LevelState, pressure: float,
               clock: float) -> int:
        """Advance ``state`` by at most ONE rung for this observation.

        Hysteresis band + cooldown (see module docstring). Returns the
        (possibly unchanged) level. Pure in everything but ``state``."""
        cfg = self.cfg
        if clock - state.last_change < cfg.cooldown_s:
            return state.level
        if pressure >= cfg.high_pressure \
                and state.level < len(cfg.ladder) - 1:
            state.level += 1
            state.last_change = clock
        elif pressure <= cfg.low_pressure and state.level > 0:
            state.level -= 1
            state.last_change = clock
        return state.level

    def rung(self, level: int) -> ControlLevel:
        return self.cfg.ladder[level]

    # ------------------------------------------------------- actuations --
    def overrides_for(self, level: int, compression: Optional[str],
                      decoder: Optional[str], default_decoder: str
                      ) -> Dict[str, Optional[str]]:
        """Per-request field rewrites for ``level`` given the request's
        CURRENT preferred fields (``None`` = engine default). Empty dict
        = nothing to change at this rung."""
        rung = self.rung(level)
        out: Dict[str, Optional[str]] = {}
        if rung.compression is not None \
                and rung.compression != compression:
            out["compression"] = rung.compression
        eff = decoder if decoder is not None else default_decoder
        mapped = rung.remap_decoder(eff)
        if mapped is not None and mapped != decoder:
            out["decoder"] = mapped
        return out
