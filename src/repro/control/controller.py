"""``Controller``: the online SLO-adaptive actuator.

One controller instance fronts a server -- or a whole fleet (the
``serve_cluster(control=...)`` path shares ONE controller across every
replica plus the Router, like the tracer/profiler) -- and closes the
loop the ROADMAP asked for: under KV/SLO pressure the serving layer
*degrades gracefully instead of deferring*; when pressure drops it
*recovers fully* to the preferred operating point.

Wiring (every call site is guarded by ``if control is not None:`` so
``control=None`` makes ZERO policy calls -- the zero-overhead-when-off
discipline the tracer/profiler established, locked by the same
patch-to-raise test):

  * ``AsyncLVLMServer._admit`` calls ``shape(server, req)`` before the
    admission gate: at rung > 0 the incoming request's ``compression`` /
    ``decoder`` fields are rewritten to the rung's aggressive preset
    (shrinking its KV need BEFORE the watermark check);
  * the server pump calls ``on_step(server)`` once per iteration: the
    policy re-reads the live pressure signals, walks the hysteresis +
    cooldown state machine, applies the rung's engine-level knobs
    (speculative ``gamma`` scale, early-exit threshold scale), reshapes
    every DEFERRED waiter to the new rung -- deepening or REVERTING its
    override -- refreshes the queued KV needs, and re-enters
    ``maybe_admit`` so shrunken requests drain immediately;
  * ``_admit`` resolution calls ``commit(req)`` (the request enters the
    engine under its current fields -- the override is consumed) or
    ``revert(req)`` (cancelled/retracted at the gate: the request gets
    its preferred fields back, so nothing stays degraded by accident);
  * the Router calls ``route_bias(req, candidates)`` at dispatch: while
    any replica is under pressure, video-heavy requests prefer replicas
    whose DEFAULT compression is aggressive (``policies.
    prefer_aggressive``).

Override lifecycle is a tracked resource (analysis R-table
``control_override``): the ``_overrides[rid]`` bind is the acquire;
every CFG path must consume it via ``commit`` or restore it via
``revert`` -- no request is ever left permanently downgraded after
pressure clears. Every actuation is traced (``control_actuation`` /
``control_level`` instants) and counted; ``metrics_snapshot()`` exposes
the ``repro_control_*`` families.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.control.policy import (AdaptivePolicy, ControlConfig,
                                  LevelState)

_ACTUATION_KINDS = ("compression", "decoder", "gamma", "exit", "route")


class Controller:
    """Fleet-shareable adaptive-control actuator (see module docstring)."""

    def __init__(self, policy=None):
        if policy is None:
            policy = AdaptivePolicy()
        elif isinstance(policy, ControlConfig):
            policy = AdaptivePolicy(policy)
        elif not isinstance(policy, AdaptivePolicy):
            raise TypeError("control= expects None/True, a ControlConfig, "
                            f"an AdaptivePolicy, or a Controller; got "
                            f"{policy!r}")
        self.policy = policy
        # per-server hysteresis state + per-engine preferred knob values
        self._state: Dict[int, LevelState] = {}
        self._servers: List = []
        self._knob_orig: Dict[int, Dict[str, Dict[str, float]]] = {}
        # rid -> (preferred compression, preferred decoder): the override
        # record -- acquire here, release in commit()/revert() (R-table
        # resource ``control_override``)
        self._overrides: Dict[int, Tuple[Optional[str], Optional[str]]] = {}
        self.actuations: Dict[str, int] = {k: 0 for k in _ACTUATION_KINDS}
        self.commits = 0
        self.reverts = 0
        self.level_changes = 0

    # --------------------------------------------------------- lifecycle --
    def attach(self, server) -> None:
        """Register a server (one per replica; idempotent). Captures the
        engine's PREFERRED decoder knobs so rung scales always apply to
        the originals and rung 0 restores them exactly."""
        sid = id(server)
        if sid in self._state:
            return
        self._state[sid] = LevelState()
        self._servers.append(server)
        eng = server.engine
        orig: Dict[str, Dict[str, float]] = {}
        for name, dec in eng._decoders.items():
            knobs: Dict[str, float] = {}
            if hasattr(dec, "gamma"):
                knobs["gamma"] = float(dec.gamma)
            if hasattr(dec, "threshold"):
                knobs["threshold"] = float(dec.threshold)
            if knobs:
                orig[name] = knobs
        self._knob_orig[id(eng)] = orig

    def level(self, server) -> int:
        st = self._state.get(id(server))
        return st.level if st is not None else 0

    @property
    def fleet_level(self) -> int:
        """Deepest rung any attached server currently sits on."""
        return max((st.level for st in self._state.values()), default=0)

    # -------------------------------------------------------- pump hook --
    def on_step(self, server) -> int:
        """Per-pump-iteration hook: observe pressure, walk the hysteresis
        state machine, and on a level change actuate engine knobs +
        reshape the deferred queue. Returns the current level."""
        st = self._state.get(id(server))
        if st is None:
            self.attach(server)
            st = self._state[id(server)]
        prof = server.profiler
        if prof.enabled:
            prof.site_begin("control_step")
        before = st.level
        level = self.policy.update(st, self.policy.pressure(server),
                                   server.engine.clock)
        if level != before:
            self.level_changes += 1
            if server.tracer.enabled:
                server.tracer.instant(
                    "control_level", replica=server.engine.trace_replica,
                    vt=server.engine.clock, level=level,
                    rung=self.policy.rung(level).name)
            self._apply_engine_knobs(server, level)
            self._reshape_deferred(server, level)
        if prof.enabled:
            prof.site_end("control_step")
        return level

    def _apply_engine_knobs(self, server, level: int) -> None:
        """Scale the registered decoders' gamma / early-exit threshold
        for this rung, relative to the PREFERRED values captured at
        attach (so rung 0 is an exact restore). Shrinking gamma below a
        running request's reservation is safe -- ``Request.lookahead``
        was stamped at submit and the verify clamp bounds block writes --
        it simply drafts shorter blocks from the next round on."""
        rung = self.policy.rung(level)
        eng = server.engine
        for name, knobs in self._knob_orig.get(id(eng), {}).items():
            dec = eng._decoders.get(name)
            if dec is None:
                continue
            if "gamma" in knobs:
                g = max(1, int(round(knobs["gamma"] * rung.gamma_scale)))
                if g != dec.gamma:
                    dec.gamma = g
                    self.actuations["gamma"] += 1
                    if server.tracer.enabled:
                        server.tracer.instant(
                            "control_actuation",
                            replica=eng.trace_replica, vt=eng.clock,
                            kind="gamma", decoder=name, value=g)
            if "threshold" in knobs:
                t = knobs["threshold"] * rung.exit_scale
                if t != dec.threshold:
                    dec.threshold = t
                    self.actuations["exit"] += 1
                    if server.tracer.enabled:
                        server.tracer.instant(
                            "control_actuation",
                            replica=eng.trace_replica, vt=eng.clock,
                            kind="exit", decoder=name, value=t)

    def _reshape_deferred(self, server, level: int) -> None:
        """Rewrite every DEFERRED waiter to the new rung -- deeper
        presets under pressure, full revert at rung 0 -- refresh the
        queued KV needs (stale needs would gate admission on tokens the
        pruner will drop), then re-enter ``maybe_admit`` so anything
        that now fits drains immediately (the hysteresis re-entry the
        property suite proves deadlock-free)."""
        adm = server.admission
        touched = False
        for entry in list(adm._waiters):
            req = entry[1]
            if getattr(req, "_imported", False):
                continue     # migrated-in KV is already post-compression
            if level > 0:
                changed = self._apply(server, req, level)
            else:
                changed = self.revert(req)
            if changed:
                adm.refresh(req)
                touched = True
        if touched:
            adm.maybe_admit()

    # -------------------------------------------------- request shaping --
    def shape(self, server, req) -> bool:
        """Admission-time hook: rewrite an INCOMING request to the
        server's current rung (no-op at rung 0). Returns True if any
        field changed."""
        st = self._state.get(id(server))
        if st is None:
            self.attach(server)
            st = self._state[id(server)]
        if st.level == 0:
            return False
        return self._apply(server, req, st.level)

    def shape_sync(self, engine, req) -> bool:
        """Closed-loop (``LVLM.serve``) variant: pressure is the KV
        fraction of what is already submitted; an override applied here
        is committed immediately (the request is being submitted now)."""
        sid = id(engine)
        st = self._state.setdefault(sid, LevelState())
        kv = engine.kv_committed_tokens() / max(1,
                                                engine.kv_capacity_tokens)
        before = st.level
        level = self.policy.update(st, kv, engine.clock)
        if level != before:
            self.level_changes += 1
        if level == 0:
            return False
        changed = self._apply_fields(req, level, engine._default_name,
                                     tracer=engine.tracer,
                                     replica=engine.trace_replica,
                                     vt=engine.clock)
        if changed:
            self.commit(req)
        return changed

    def _apply(self, server, req, level: int) -> bool:
        return self._apply_fields(req, level, server.engine._default_name,
                                  tracer=server.tracer,
                                  replica=server.engine.trace_replica,
                                  vt=server.engine.clock)

    def _apply_fields(self, req, level: int, default_decoder: str, *,
                      tracer, replica: int, vt: float) -> bool:
        rid = req.rid
        prior = self._overrides.get(rid)
        base_comp, base_dec = prior if prior is not None \
            else (req.compression, req.decoder)
        ov = self.policy.overrides_for(level, base_comp, base_dec,
                                       default_decoder)
        if not ov:
            # this rung leaves the request's preferred fields alone; a
            # shallower rung after a deeper one must restore them
            if prior is not None:
                return self.revert(req)
            return False
        if prior is None:
            self._overrides[rid] = (base_comp, base_dec)   # acquire
        new_comp = ov.get("compression", base_comp)
        new_dec = ov.get("decoder", base_dec)
        if new_comp == req.compression and new_dec == req.decoder:
            return False
        req.compression = new_comp
        req.decoder = new_dec
        # the stamped post-compression count belongs to the OLD strategy
        req.nv_compressed = None
        for kind in ov:
            self.actuations[kind] += 1
            if tracer.enabled:
                tracer.instant("control_actuation", rid, replica=replica,
                               vt=vt, kind=kind, to=ov[kind],
                               level=level)
        return True

    # ------------------------------------------------ override lifecycle --
    def commit(self, req) -> bool:
        """The request entered the engine under its current (possibly
        degraded) fields: consume the override record."""
        rec = self._overrides.pop(req.rid, None)
        if rec is None:
            return False
        self.commits += 1
        return True

    def revert(self, req) -> bool:
        """Restore the request's PREFERRED fields (pressure cleared while
        it was still deferred, or it was cancelled at the gate)."""
        rec = self._overrides.pop(req.rid, None)
        if rec is None:
            return False
        orig_comp, orig_dec = rec
        req.compression = orig_comp
        req.decoder = orig_dec
        req.nv_compressed = None
        self.reverts += 1
        return True

    # ----------------------------------------------------------- routing --
    def route_bias(self, request, candidates: List) -> List:
        """Dispatch-time bias: while ANY replica is under pressure,
        video-heavy requests prefer aggressive-pruning replicas (their
        default strategy keeps <= ``route_keep_max`` of visual tokens).
        Falls back to the full candidate list when none qualify."""
        if getattr(request, "visual_embeds", None) is None \
                or self.fleet_level == 0 or len(candidates) < 2:
            return candidates
        from repro.cluster.policies import prefer_aggressive
        aggressive = prefer_aggressive(
            candidates, max_keep=self.policy.cfg.route_keep_max)
        if aggressive and len(aggressive) < len(candidates):
            self.actuations["route"] += 1
            return aggressive
        return candidates

    # ----------------------------------------------------------- reports --
    def summary(self) -> Dict:
        out = {"control_level": self.fleet_level,
               "control_commits": self.commits,
               "control_reverts": self.reverts,
               "control_level_changes": self.level_changes,
               "control_overrides_open": len(self._overrides)}
        for kind, n in self.actuations.items():
            out[f"control_actuations/{kind}"] = n
        return out

    def prom_families(self, prom) -> None:
        """Render the ``repro_control_*`` families into a ``PromText``
        (the server renders them standalone; a fleet renders them ONCE
        at router level, like the shared profiler)."""
        for server in self._servers:
            prom.gauge("control_level",
                       "Current degradation-ladder rung (0 = preferred).",
                       self.level(server),
                       labels={"replica":
                               str(server.engine.trace_replica)})
        for kind in _ACTUATION_KINDS:
            prom.counter("control_actuations_total",
                         "Controller actuations by kind.",
                         self.actuations[kind], labels={"kind": kind})
        prom.counter("control_commits_total",
                     "Overrides committed into the engine.", self.commits)
        prom.counter("control_reverts_total",
                     "Overrides reverted to preferred fields.",
                     self.reverts)
        prom.counter("control_level_changes_total",
                     "Hysteresis level transitions.", self.level_changes)
        prom.gauge("control_overrides_open",
                   "Deferred requests currently holding an override.",
                   len(self._overrides))
