import os

# smoke tests/benches must see the single real CPU device -- the 512-device
# XLA_FLAGS override belongs ONLY to launch/dryrun.py (its first two lines).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "dryrun's device-count override must not leak into the test env"

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long jit-heavy equivalence / subprocess tests (the CI "
        'smoke job deselects them with -m "not slow")')


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
