"""``LVLM``: the single public inference facade.

Wraps the whole config -> build -> param init/restore -> engine pipeline the
way vLLM's ``LLM`` / SGLang's runtime front their engines:

    from repro.api import LVLM, GenerationConfig

    lvlm = LVLM.from_pretrained("qwen2-vl-2b", smoke=True)
    out = lvlm.generate(prompt_tokens,
                        GenerationConfig(decoder="greedy", max_new_tokens=16,
                                         compression="fastv-0.5"))
    for tok in lvlm.generate_stream(prompt_tokens):   # per-token iterator
        ...
    report = lvlm.serve(requests, EngineConfig(scheduler="chunked"))

Every decode strategy (greedy / sampling / speculative / early_exit) runs
through the SAME engine + decoder-hook path, so compression presets,
schedulers, and the virtual-clock metrics compose with all of them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.api.compressors import make_compressor
from repro.api.decoders import make_decoder
from repro.api.generation import DECODER_NAMES, GenerationConfig
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.serving import Engine, EngineConfig, Request
from repro.models.registry import build
from repro.serving import AsyncLVLMServer

Prompt = Sequence[int]


@dataclasses.dataclass
class GenerationResult:
    """One prompt's continuation plus run-level stats."""
    tokens: List[int]                 # generated token ids
    prompt_len: int                   # text tokens (visual not included)
    decoder: str
    stats: Dict                       # engine summary + decoder counters
    request: Request                  # full lifecycle record (ttft/jct/...)


@dataclasses.dataclass
class ServeResult:
    """Outcome of a full serving run (scheduler metrics + raw requests)."""
    stats: Dict
    requests: List[Request]
    engine: Engine


def _is_single_prompt(prompts) -> bool:
    return len(prompts) > 0 and not hasattr(prompts[0], "__len__")


class LVLM:
    """Facade over (model, params); see module docstring."""

    def __init__(self, model, params):
        self.model = model
        self.params = params

    # ---------------------------------------------------------- factory --
    @classmethod
    def from_pretrained(cls, arch: str, *, smoke: bool = False,
                        seed: int = 0, checkpoint: Optional[str] = None,
                        **overrides) -> "LVLM":
        """config -> build -> param init (or checkpoint restore).

        ``overrides`` are ``ModelConfig.with_`` fields, e.g.
        ``LVLM.from_pretrained("qwen2-vl-2b", smoke=True, vocab_size=512)``.
        """
        cfg = get_config(arch, smoke=smoke)
        if overrides:
            cfg = cfg.with_(**overrides)
        model = build(cfg)
        if checkpoint is not None:
            from repro.training.checkpoint import load_checkpoint
            params, _step = load_checkpoint(checkpoint)
        else:
            params = model.init(jax.random.PRNGKey(seed))
        return cls(model, params)

    @classmethod
    def from_config(cls, cfg: ModelConfig, *, seed: int = 0) -> "LVLM":
        model = build(cfg)
        return cls(model, model.init(jax.random.PRNGKey(seed)))

    @property
    def cfg(self) -> ModelConfig:
        return self.model.cfg

    def with_params(self, params) -> "LVLM":
        """Same architecture, new weights (e.g. after training)."""
        return LVLM(self.model, params)

    # ----------------------------------------------------------- engine --
    def _strategy_decoders(self, gen: GenerationConfig,
                           draft: Optional["LVLM"]) -> Dict:
        """Named decoder instances parameterized by ``gen`` -- registered
        with the engine so PER-REQUEST strategies (``Request.decoder``) use
        the caller's gamma/LANTERN/exit knobs (and draft model) instead of
        bare defaults. Validation is lazy: an entry only errors if a
        request actually selects it."""
        return {
            "speculative": make_decoder(
                "speculative", gen,
                draft=None if draft is None else draft.model,
                d_params=None if draft is None else draft.params),
            "early_exit": make_decoder("early_exit", gen),
        }

    def _build_engine(self, gen: GenerationConfig, *, max_batch: int,
                      cache_len: int, draft: Optional["LVLM"] = None,
                      engine_cfg: Optional[EngineConfig] = None,
                      compressors: Optional[Dict] = None) -> Engine:
        if engine_cfg is None:
            engine_cfg = EngineConfig(max_batch=max_batch,
                                      cache_len=cache_len,
                                      scheduler="continuous")
        # generation knobs always come from gen; engine_cfg keeps only the
        # serving-layer knobs (batch, cache, scheduler, prefix cache, cost).
        # Every strategy (speculative/early_exit included) is batched, so
        # max_batch is never forced down to 1 any more. The RAW temperature
        # goes on the engine: greedy decoding is enforced per group by the
        # greedy instances themselves, so a greedy DEFAULT must not zero
        # the temperature of per-request sampling/speculative overrides.
        # gen.compression is sugar for a NAMED default strategy registered
        # with the engine (EngineConfig.compression is never mutated);
        # per-request overrides resolve against the same registry.
        engine_cfg = dataclasses.replace(
            engine_cfg,
            temperature=gen.temperature,
            top_k=gen.top_k, top_p=gen.top_p,
            eos_id=gen.eos_id, seed=gen.seed,
            decoder=gen.decoder)
        decoders = self._strategy_decoders(gen, draft)
        return Engine(self.model, self.params, engine_cfg,
                      decoder=decoders.get(gen.decoder), decoders=decoders,
                      compressor=make_compressor(gen.compression),
                      compressors=compressors)

    @staticmethod
    def _resolve_obs(obs):
        """``obs=`` facade knob -> a ``repro.obs.Tracer`` or None.

        ``None``/``False`` -> no tracing (the engine holds NULL_TRACER and
        every instrumentation site short-circuits); ``True`` -> a fresh
        ``Tracer``; a ``Tracer`` instance is used as-is (share one across
        servers to merge their events into a single trace)."""
        if obs is None or obs is False:
            return None
        if obs is True:
            from repro.obs import Tracer
            return Tracer()
        return obs

    @staticmethod
    def _resolve_profile(profile):
        """``profile=`` facade knob -> a ``repro.obs.Profiler`` or None.

        Mirrors ``_resolve_obs``: ``None``/``False`` -> no profiling (the
        engine holds NULL_PROFILER and every hot-path site short-circuits);
        ``True`` -> a fresh ``Profiler``; a ``Profiler`` instance is used
        as-is (share one across servers to merge site histograms)."""
        if profile is None or profile is False:
            return None
        if profile is True:
            from repro.obs import Profiler
            return Profiler()
        return profile

    @staticmethod
    def _resolve_control(control):
        """``control=`` facade knob -> a ``repro.control.Controller`` or
        None.

        Mirrors ``_resolve_obs``: ``None``/``False`` -> no adaptive
        control (ZERO policy calls -- every site guards on
        ``control is not None``); ``True`` -> a fresh ``Controller`` with
        the default degradation ladder; a ``ControlConfig`` or
        ``AdaptivePolicy`` wraps into a fresh ``Controller``; a
        ``Controller`` instance is used as-is (share one across replicas
        so the fleet walks a single ladder)."""
        if control is None or control is False:
            return None
        from repro.control import Controller
        if control is True:
            return Controller()
        if isinstance(control, Controller):
            return control
        return Controller(control)

    def _requests(self, prompts, gen, visual_embeds) -> List[Request]:
        n = len(prompts)
        if visual_embeds is None:
            ves: List[Optional[np.ndarray]] = [None] * n
        elif isinstance(visual_embeds, (list, tuple)):
            ves = list(visual_embeds)
        else:                                      # one array, one prompt
            ves = [np.asarray(visual_embeds)]
        if len(ves) != n:
            raise ValueError(f"{n} prompts but {len(ves)} visual_embeds")
        return [Request(rid=i, tokens=[int(t) for t in p],
                        max_new_tokens=gen.max_new_tokens,
                        visual_embeds=ve)
                for i, (p, ve) in enumerate(zip(prompts, ves))]

    @staticmethod
    def _cache_len(reqs: List[Request], gen: GenerationConfig) -> int:
        if not reqs:
            raise ValueError("generate() needs at least one prompt")
        margin = 2 + (gen.gamma if gen.decoder == "speculative" else 0)
        need = max(r.prompt_len + r.max_new_tokens for r in reqs) + margin
        return -(-need // 16) * 16                 # round up to x16

    # --------------------------------------------------------- generate --
    def generate(self, prompts, gen: Optional[GenerationConfig] = None, *,
                 visual_embeds=None, draft: Optional["LVLM"] = None,
                 engine_cfg: Optional[EngineConfig] = None,
                 compressors: Optional[Dict] = None
                 ) -> Union[GenerationResult, List[GenerationResult]]:
        """Generate continuations with any decoder strategy.

        ``prompts``: one token-id sequence or a list of them (a single
        prompt returns a single ``GenerationResult``). ``visual_embeds``:
        one [Nv, d] array (single prompt) or a list parallel to ``prompts``.
        ``draft``: an ``LVLM`` used as the speculative draft model (None ->
        self-draft). ``compressors``: extra named compression strategies
        registered with the engine (preset/parametric names resolve
        without registration).
        """
        gen = gen if gen is not None else GenerationConfig()
        # every strategy is a batched slot strategy: multiple prompts run
        # concurrently even for speculative (all speculative slots share
        # each jitted draft/verify round) and early_exit (per-slot loop)
        single = _is_single_prompt(prompts)
        if single:
            prompts = [prompts]
        reqs = self._requests(prompts, gen, visual_embeds)
        eng = self._build_engine(
            gen, max_batch=min(8, max(1, len(reqs))),
            cache_len=self._cache_len(reqs, gen), draft=draft,
            engine_cfg=engine_cfg, compressors=compressors)
        for r in reqs:
            eng.submit(r)
        run_stats = eng.run()
        stats = dict(run_stats, **eng.decoder_stats())
        results = [GenerationResult(tokens=list(r.generated),
                                    prompt_len=len(r.tokens),
                                    decoder=gen.decoder, stats=stats,
                                    request=r)
                   for r in reqs]
        return results[0] if single else results

    def generate_stream(self, prompt: Prompt,
                        gen: Optional[GenerationConfig] = None, *,
                        visual_embeds=None, draft: Optional["LVLM"] = None
                        ) -> Iterator[int]:
        """Per-token iterator over one prompt's continuation (any decoder).

        Tokens are yielded as the engine emits them -- speculative rounds
        surface several at once, which is exactly the technique's point.
        """
        gen = gen if gen is not None else GenerationConfig()
        reqs = self._requests([prompt], gen,
                              None if visual_embeds is None
                              else [np.asarray(visual_embeds)])
        eng = self._build_engine(gen, max_batch=1,
                                 cache_len=self._cache_len(reqs, gen),
                                 draft=draft)
        req = reqs[0]
        eng.submit(req)
        served = 0
        while eng.step():
            while served < len(req.generated):
                yield req.generated[served]
                served += 1
        while served < len(req.generated):
            yield req.generated[served]
            served += 1

    # ------------------------------------------------------------ serve --
    def _serve_engine(self, engine_cfg: Optional[EngineConfig] = None,
                      gen: Optional[GenerationConfig] = None,
                      draft: Optional["LVLM"] = None,
                      compressors: Optional[Dict] = None,
                      tracer=None, profiler=None) -> Engine:
        """Serving-engine wiring shared by ``serve`` (sync, closed-loop)
        and ``serve_async`` (streaming, open-loop): resolve the default
        strategy + generation knobs onto the EngineConfig and register
        every named per-request strategy (decoders AND compressors)."""
        ec = engine_cfg if engine_cfg is not None else EngineConfig()
        g = gen if gen is not None else GenerationConfig(
            decoder=ec.decoder if ec.decoder in DECODER_NAMES else "sampling",
            temperature=ec.temperature, top_k=ec.top_k, top_p=ec.top_p,
            eos_id=ec.eos_id, compression=ec.compression)
        if gen is not None:
            # raw temperature: the greedy strategy forces 0 per group, so
            # per-request sampling overrides keep the caller's temperature.
            # gen.compression becomes the engine's registered DEFAULT
            # strategy below -- EngineConfig.compression is left alone.
            ec = dataclasses.replace(
                ec, decoder=gen.decoder,
                temperature=gen.temperature,
                top_k=gen.top_k, top_p=gen.top_p, eos_id=gen.eos_id)
        decoders = self._strategy_decoders(g, draft)
        return Engine(self.model, self.params, ec,
                      decoder=decoders.get(ec.decoder), decoders=decoders,
                      compressor=make_compressor(g.compression),
                      compressors=compressors, tracer=tracer,
                      profiler=profiler)

    def serve(self, requests: List[Request],
              engine_cfg: Optional[EngineConfig] = None,
              gen: Optional[GenerationConfig] = None,
              draft: Optional["LVLM"] = None,
              compressors: Optional[Dict] = None,
              obs=None, profile=None, control=None) -> ServeResult:
        """Full serving run: scheduler + batching + virtual-clock metrics.

        ``engine_cfg`` keeps its internal-layer knobs (scheduler, batch,
        prefix cache, ...); ``gen`` optionally selects the DEFAULT decoder
        strategy and compression preset on top. Any request may override
        the strategy per-request via ``Request.decoder`` -- one engine run
        serves greedy, sampling, speculative, and early-exit requests
        concurrently, with speculative slots batched per draft/verify call
        (stats from a mixed run are prefixed per strategy, e.g.
        ``"speculative/acceptance"``). ``draft`` supplies the speculative
        draft model for both the default and per-request speculative
        requests (None -> self-draft).

        Like decoders, COMPRESSION is per-request: ``Request.compression``
        names a strategy (any preset/parametric name, or a key of
        ``compressors``) resolved against the engine registry, so one
        batch mixes e.g. ``none`` chat traffic with ``framefusion-0.25``
        video traffic; admission and KV accounting use each request's
        post-compression token count.

        Stats include TTFT/TPOT percentiles (p50/p95/p99), per-request
        SLO attainment fractions, the virtual-clock decode cost per
        strategy group (``decode_cost_by_group``), and per-compression-
        strategy prefill token reduction (``compression/<name>/...``).
        For open-loop traffic with streaming delivery and cancellation,
        see ``serve_async``.
        """
        eng = self._serve_engine(engine_cfg, gen, draft,
                                 compressors=compressors,
                                 tracer=self._resolve_obs(obs),
                                 profiler=self._resolve_profile(profile))
        ctl = self._resolve_control(control)
        for r in requests:
            if ctl is not None:
                # closed-loop shaping: degrade against already-committed
                # KV; the override commits immediately (submitted now)
                ctl.shape_sync(eng, r)
            eng.submit(r)
        stats = dict(eng.run(), **eng.decoder_stats())
        stats["decode_cost_by_group"] = dict(eng.group_costs)
        for name, cs in eng.compression_stats().items():
            for k, v in cs.items():
                stats[f"compression/{name}/{k}"] = v
        return ServeResult(stats=stats, requests=list(eng.finished),
                           engine=eng)

    def serve_async(self, engine_cfg: Optional[EngineConfig] = None,
                    gen: Optional[GenerationConfig] = None, *,
                    draft: Optional["LVLM"] = None,
                    admission=None, metrics=None, compressors=None,
                    pacing: str = "virtual", pacing_scale: float = 1.0,
                    disconnect_timeout_s: Optional[float] = None,
                    obs=None, profile=None,
                    control=None) -> AsyncLVLMServer:
        """Async streaming server over the same engine wiring as ``serve``.

        Returns a ``repro.serving.AsyncLVLMServer``: a background pump over
        the grouped step loop with per-request async token channels,
        KV-watermark admission control (backpressure instead of pool
        exhaustion), mid-stream cancellation that frees every held
        resource, and per-request TTFT/TPOT/queue-wait SLO telemetry:

            server = lvlm.serve_async(EngineConfig(max_batch=8))
            async with server:
                async for tok in server.submit(req):
                    ...

        ``admission`` is a ``repro.serving.AdmissionConfig`` (high/low KV
        watermarks, optional max inflight, deferred-queue ``order``:
        "fifo" or SLO-slack "slack"); ``metrics`` an optional shared
        ``MetricsRegistry``. ``pacing="wall"`` sleeps each step's virtual
        duration (times ``pacing_scale``) in real time -- the default
        "virtual" runs steps back-to-back and stays deterministic.
        ``disconnect_timeout_s`` aborts a stream whose consumer stopped
        reading for that many wall seconds. At temperature 0 the streams
        are bit-identical to ``serve``'s outputs.
        """
        return AsyncLVLMServer(self, engine_cfg=engine_cfg, gen=gen,
                               draft=draft, admission=admission,
                               metrics=metrics, compressors=compressors,
                               pacing=pacing, pacing_scale=pacing_scale,
                               disconnect_timeout_s=disconnect_timeout_s,
                               tracer=self._resolve_obs(obs),
                               profiler=self._resolve_profile(profile),
                               control=self._resolve_control(control))

    def serve_cluster(self, replicas=2,
                      engine_cfg: Optional[EngineConfig] = None,
                      gen: Optional[GenerationConfig] = None, *,
                      routing="round_robin", draft: Optional["LVLM"] = None,
                      admission=None, compressors=None,
                      roles: Optional[Sequence[str]] = None,
                      shared_prefix: Optional[bool] = None,
                      pacing: str = "virtual",
                      pacing_scale: float = 1.0,
                      disconnect_timeout_s: Optional[float] = None,
                      obs=None, profile=None, control=None) -> "Router":
        """Multi-engine router: N async server replicas behind ONE submit
        surface (``repro.cluster.Router``), with pluggable routing.

        ``replicas`` is an int (homogeneous fleet sharing ``engine_cfg`` /
        ``gen`` / ``draft`` / ``admission``) or a sequence of per-replica
        override dicts with any of those keys -- a heterogeneous fleet,
        e.g. one speculative-heavy replica and one early-exit replica:

            router = lvlm.serve_cluster(
                [{"gen": GenerationConfig(decoder="speculative", gamma=4)},
                 {"gen": GenerationConfig(decoder="early_exit")}],
                routing="least_kv")
            async with router:
                async for tok in router.submit(req):
                    ...

        ``routing`` is a ``repro.cluster.ROUTING_POLICIES`` name
        (round_robin | least_kv | prefix_affinity) or a policy instance.

        ``roles`` disaggregates the fleet (unified | prefill | decode,
        one per replica; a per-replica spec dict may carry a ``"role"``
        key instead): prefill replicas hand post-compression KV to
        decode replicas over the modeled KV link. ``shared_prefix``
        promotes the per-replica prefix caches to one cluster-shared
        radix tier (default: exactly when the fleet is role-split).
        Pacing/disconnect knobs apply to every replica (see
        ``serve_async``). With one replica the router streams are
        bit-identical to the bare server's.
        """
        from repro.cluster import Router

        if isinstance(replicas, int):
            if replicas < 1:
                raise ValueError("serve_cluster needs at least one replica")
            specs: List[Dict] = [{} for _ in range(replicas)]
        else:
            specs = [dict(s) for s in replicas]
            if not specs:
                raise ValueError("serve_cluster needs at least one replica")
        if roles is not None and len(roles) != len(specs):
            raise ValueError(f"roles has {len(roles)} entries for "
                             f"{len(specs)} replicas")
        rep_roles = list(roles) if roles is not None \
            else ["unified"] * len(specs)
        # ONE tracer for the whole fleet: a migrated request's spans land
        # in a single contiguous trace; the Router assigns each engine its
        # replica track index. Same for the profiler: fleet-merged site
        # histograms, rendered once in Router.metrics_snapshot()
        tracer = self._resolve_obs(obs)
        profiler = self._resolve_profile(profile)
        # ... and ONE adaptive controller: per-replica pressure levels,
        # fleet-shared actuation counters, router-level routing bias
        ctl = self._resolve_control(control)
        servers = []
        for i, spec in enumerate(specs):
            unknown = set(spec) - {"engine_cfg", "gen", "draft", "admission",
                                   "compressors", "role"}
            if unknown:
                raise ValueError(f"unknown replica spec keys: {unknown}")
            if "role" in spec:
                rep_roles[i] = spec["role"]
            servers.append(self.serve_async(
                spec.get("engine_cfg", engine_cfg),
                spec.get("gen", gen),
                draft=spec.get("draft", draft),
                admission=spec.get("admission", admission),
                compressors=spec.get("compressors", compressors),
                pacing=pacing, pacing_scale=pacing_scale,
                disconnect_timeout_s=disconnect_timeout_s,
                obs=tracer, profile=profiler, control=ctl))
        return Router(servers, routing=routing, roles=rep_roles,
                      shared_prefix=shared_prefix, control=ctl)
