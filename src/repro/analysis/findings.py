"""Findings, waiver comments, and the regression baseline.

A ``Finding`` is one rule violation at a file:line. Two suppression
mechanisms compose:

  * **waivers** -- ``# analysis: allow L001 (reason)`` on the offending
    line (or the line directly above it) waives that rule there, with
    the reason kept in the source as documentation. ``# analysis:
    atomic-step`` is the A002 fence variant (see rules_async.py).
  * **baseline** -- a committed JSON file of known findings; the runner
    reports only findings NOT in the baseline, so CI fails on
    regressions while pre-existing debt is paid down incrementally.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Set, Tuple

SEVERITIES = ("error", "warning", "info")

_WAIVE_RE = re.compile(
    r"#\s*analysis:\s*allow\s+([A-Z]\d{3}(?:\s*,\s*[A-Z]\d{3})*)"
    r"(?:\s*\(([^)]*)\))?")
_FENCE_RE = re.compile(r"#\s*analysis:\s*atomic-step")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation. ``key`` (rule, path, line) is the baseline
    identity; ``message`` is for humans."""
    path: str
    line: int
    rule: str
    severity: str
    message: str

    @property
    def key(self) -> Tuple[str, str, int]:
        return (self.rule, self.path, self.line)

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} "
                f"[{self.severity}] {self.message}")

    def to_json(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "severity": self.severity, "message": self.message}

    @staticmethod
    def from_json(d: Dict) -> "Finding":
        return Finding(path=d["path"], line=int(d["line"]), rule=d["rule"],
                       severity=d.get("severity", "error"),
                       message=d.get("message", ""))


def _directive_span(lines: List[str], i: int) -> List[int]:
    """Lines covered by a directive comment at 1-based line ``i``: the
    directive's own line, any comment-only continuation lines below it,
    and the first code line after them (so a multi-line explanatory
    comment above a statement still covers the statement)."""
    span = [i]
    j = i + 1
    while j <= len(lines) and lines[j - 1].lstrip().startswith("#"):
        span.append(j)
        j += 1
    span.append(j)
    return span


def parse_waivers(src: str) -> Dict[int, Set[str]]:
    """Map line number -> set of waived rule ids. A waiver comment
    covers its own line, trailing comment lines, and the next code line
    (so a comment block above a multi-line statement waives it)."""
    lines = src.splitlines()
    waived: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _WAIVE_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",")}
        for line in _directive_span(lines, i):
            waived.setdefault(line, set()).update(rules)
    return waived


def fence_lines(src: str) -> Set[int]:
    """Lines carrying an ``# analysis: atomic-step`` fence (same span
    semantics as waivers: directive + comment block + next code line)."""
    lines = src.splitlines()
    out: Set[int] = set()
    for i, text in enumerate(lines, start=1):
        if _FENCE_RE.search(text):
            out.update(_directive_span(lines, i))
    return out


def apply_waivers(findings: List[Finding], src: str) -> List[Finding]:
    waived = parse_waivers(src)
    return [f for f in findings if f.rule not in waived.get(f.line, ())]


class Baseline:
    """Committed set of accepted findings; matching is by (rule, path)
    plus line with a small tolerance so unrelated edits above a
    baselined finding do not resurrect it."""

    LINE_SLACK = 10

    def __init__(self, findings: Optional[List[Finding]] = None):
        self.findings: List[Finding] = list(findings or [])

    @staticmethod
    def load(path: str) -> "Baseline":
        with open(path) as f:
            data = json.load(f)
        return Baseline([Finding.from_json(d)
                         for d in data.get("findings", [])])

    def save(self, path: str) -> None:
        data = {"version": 1,
                "findings": [f.to_json() for f in sorted(self.findings)]}
        with open(path, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")

    def is_baselined(self, finding: Finding) -> bool:
        for b in self.findings:
            if (b.rule == finding.rule and b.path == finding.path
                    and abs(b.line - finding.line) <= self.LINE_SLACK):
                return True
        return False

    def filter(self, findings: List[Finding]) -> List[Finding]:
        return [f for f in findings if not self.is_baselined(f)]
