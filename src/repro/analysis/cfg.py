"""A small statement-level control-flow graph over one function's AST.

Built for the R-rules' acquire/release reachability question: "is there
a path from this acquire statement to a function exit that avoids every
matching release?". Nodes are statements (identified by object), edges
follow structured control flow:

  * ``if`` branches, ``for``/``while`` loops (with ``break``/
    ``continue`` and ``else`` clauses),
  * ``try``: every statement in the try body may also jump to each
    handler (exceptions can occur anywhere), handlers and body route
    through ``finally``,
  * ``return`` / ``raise`` edge to EXIT -- through enclosing ``finally``
    blocks, innermost first,
  * ``with`` bodies are inlined (context-manager cleanup is not a
    release site in this codebase's tables).

The graph is conservative in the safe direction for a linter: it may
contain infeasible paths (flagging at worst a spurious finding, fixed
with a waiver) but never drops a feasible one.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

ENTRY = "<entry>"
EXIT = "<exit>"


class CFG:
    def __init__(self) -> None:
        self.succ: Dict[object, Set[object]] = {ENTRY: set(), EXIT: set()}

    def add_edge(self, a: object, b: object) -> None:
        self.succ.setdefault(a, set()).add(b)
        self.succ.setdefault(b, set())

    def statements(self) -> List[ast.stmt]:
        return [n for n in self.succ if isinstance(n, ast.stmt)]

    def reachable(self, sources: Iterable[object],
                  avoiding: Set[object]) -> Set[object]:
        """Nodes reachable from ``sources`` without ENTERING any node in
        ``avoiding`` (source nodes themselves are expanded)."""
        seen: Set[object] = set()
        stack = [s for s in sources if s in self.succ]
        while stack:
            n = stack.pop()
            if n in seen:
                continue
            seen.add(n)
            for m in self.succ.get(n, ()):
                if m not in seen and m not in avoiding:
                    stack.append(m)
        return seen

    def path_avoiding(self, start: object, goal: object,
                      avoiding: Set[object]) -> bool:
        """True iff a path start -> goal exists that never enters an
        ``avoiding`` node (start itself is allowed to be in it)."""
        if start == goal:
            return True
        return goal in self.reachable([start], avoiding)


class _Builder:
    """One pass over a function body; loop/finally context on a stack."""

    def __init__(self) -> None:
        self.cfg = CFG()
        # stack of (break_targets, continue_target) for enclosing loops
        self._loops: List[tuple] = []
        # stack of enclosing finally bodies (innermost last)
        self._finallies: List[List[ast.stmt]] = []

    # ------------------------------------------------------------ helpers --
    def _jump_exit(self, node: ast.stmt) -> None:
        """return/raise: route through enclosing finally blocks to EXIT."""
        prev: object = node
        for fin in reversed(self._finallies):
            if fin:
                self.cfg.add_edge(prev, fin[0])
                prev = self._block_tail(fin)
                if prev is None:        # finally itself always jumps
                    return
        self.cfg.add_edge(prev, EXIT)

    def _block_tail(self, body: List[ast.stmt]) -> Optional[object]:
        """Last fall-through node of an already-built block (None when
        the block cannot fall through)."""
        # blocks are built before this is consulted; fall-through is the
        # last statement unless it is a terminal jump
        if not body:
            return None
        last = body[-1]
        if isinstance(last, (ast.Return, ast.Raise, ast.Break, ast.Continue)):
            return None
        return last

    def build(self, fn: ast.FunctionDef) -> CFG:
        tails = self._body(fn.body, [ENTRY])
        for t in tails:
            self.cfg.add_edge(t, EXIT)
        return self.cfg

    def _body(self, body: List[ast.stmt],
              preds: List[object]) -> List[object]:
        """Wire ``body`` after ``preds``; returns the fall-through tails."""
        cur = preds
        for stmt in body:
            cur = self._stmt(stmt, cur)
        return cur

    # --------------------------------------------------------- statements --
    def _stmt(self, node: ast.stmt, preds: List[object]) -> List[object]:
        for p in preds:
            self.cfg.add_edge(p, node)
        if isinstance(node, (ast.Return, ast.Raise)):
            self._jump_exit(node)
            return []
        if isinstance(node, ast.Break):
            if self._loops:
                self._loops[-1][0].append(node)
            else:
                self.cfg.add_edge(node, EXIT)
            return []
        if isinstance(node, ast.Continue):
            if self._loops:
                self.cfg.add_edge(node, self._loops[-1][1])
            else:
                self.cfg.add_edge(node, EXIT)
            return []
        if isinstance(node, ast.If):
            then_tails = self._body(node.body, [node])
            else_tails = (self._body(node.orelse, [node])
                          if node.orelse else [node])
            return then_tails + else_tails
        if isinstance(node, (ast.For, ast.While, ast.AsyncFor)):
            breaks: List[object] = []
            self._loops.append((breaks, node))
            body_tails = self._body(node.body, [node])
            for t in body_tails:
                self.cfg.add_edge(t, node)      # loop back
            self._loops.pop()
            # loop may not execute / finishes: fall through (via else)
            after: List[object] = [node]
            if node.orelse:
                after = self._body(node.orelse, [node])
            return after + breaks
        if isinstance(node, ast.Try):
            fin = node.finalbody or []
            if fin:
                self._finallies.append(fin)
            body_tails = self._body(node.body, [node])
            handler_tails: List[object] = []
            handler_entries: List[object] = []
            for h in node.handlers:
                ht = self._body(h.body, [node])
                handler_tails += ht
                if h.body:
                    handler_entries.append(h.body[0])
            # any statement in the try body may raise into any handler
            body_nodes = [n for n in ast.walk(node)
                          if isinstance(n, ast.stmt) and n is not node
                          and self._inside(node.body, n)]
            for bn in body_nodes:
                for he in handler_entries:
                    self.cfg.add_edge(bn, he)
            else_tails = (self._body(node.orelse, body_tails)
                          if node.orelse else body_tails)
            tails = else_tails + handler_tails
            if fin:
                self._finallies.pop()
                fin_tails = self._body(fin, tails or [node])
                return fin_tails
            return tails
        if isinstance(node, (ast.With, ast.AsyncWith)):
            return self._body(node.body, [node])
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return [node]                       # nested defs: opaque
        return [node]

    @staticmethod
    def _inside(body: List[ast.stmt], node: ast.stmt) -> bool:
        for stmt in body:
            for n in ast.walk(stmt):
                if n is node:
                    return True
        return False


def build_cfg(fn: ast.FunctionDef) -> CFG:
    """CFG of one (sync or async) function definition."""
    return _Builder().build(fn)


def function_defs(tree: ast.AST):
    """Every (possibly nested / method) function def in the module."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
