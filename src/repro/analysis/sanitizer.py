"""Runtime sanitizer: conservation asserts at engine/server step
boundaries -- the dynamic half of the R-rules.

The static R-rules prove release sites EXIST on every path; the
sanitizer proves the accounting actually balances while the system
runs, so a static finding can be confirmed (the assert trips) or waived
(it never does) with evidence. Enabled via ``EngineConfig.sanitize`` or
``REPRO_SANITIZE=1`` (CI's smoke job runs the whole suite with it on).

Invariants checked after every ``Engine.step`` (and, server-side, after
every pump iteration):

  * **kv conservation** -- ``Engine.kv_committed_tokens()`` equals an
    independent walk of live requests' ``kv_request_tokens`` (guards
    incremental-counter drift if accounting is ever cached).
  * **slot table** -- every bound ``slot_req`` entry is a live request
    (no slot held by a DONE/aborted request), live positions stay
    inside the cache, and no two slots share one request.
  * **draft rows** -- every decoder's ``bound_slots()`` is a subset of
    the live slot set (a row bound to a freed slot is a draft-pool
    leak).
  * **prefix pins** -- pin counts equal the live requests pinning each
    key, every pinned key is still cached, and pinned entries never
    exceed live requests.
  * **server streams** -- every live engine request has a registered
    stream; aborted/finished streams are deregistered.
  * **trace completeness** (when a ``repro.obs`` tracer is enabled) --
    open ``request`` spans owned by the replica == its live requests
    at every step/pump boundary: no orphan spans, no untraced
    requests (rids mid-migration are exempt on the source until its
    ``complete_export``).

This module is import-light (stdlib only) so ``repro.core`` can import
it lazily without layering cycles.
"""
from __future__ import annotations

import os
from typing import List

_ENV = "REPRO_SANITIZE"


class SanitizerError(AssertionError):
    """A conservation invariant failed at a step boundary."""


def sanitize_enabled(default: bool = False) -> bool:
    """True when REPRO_SANITIZE is set to a truthy value ('1', 'true',
    'yes', 'on')."""
    val = os.environ.get(_ENV)
    if val is None:
        return default
    return val.strip().lower() in ("1", "true", "yes", "on")


def _live_requests(engine) -> List:
    from repro.core.serving.request import State
    return [r for pool in (engine.running, engine.waiting) for r in pool
            if r.state is not State.DONE]


def check_engine_conservation(engine) -> List[str]:
    """Return a list of violated-invariant descriptions (empty = clean)."""
    from repro.core.serving.request import State

    problems: List[str] = []
    live = _live_requests(engine)
    live_ids = {id(r) for r in live}

    # kv conservation: the committed counter vs an independent walk
    committed = engine.kv_committed_tokens()
    walked = sum(engine.kv_request_tokens(r) for r in live)
    if committed != walked:
        problems.append(
            f"kv_committed_tokens()={committed} != sum of live "
            f"kv_request_tokens={walked}")

    # slot table: bound slots <-> live requests, one slot per request
    seen_req_slots = {}
    for slot, r in enumerate(engine.slot_req):
        if r is None:
            continue
        if r.state is State.DONE or id(r) not in live_ids:
            problems.append(
                f"slot {slot} still bound to retired/aborted request "
                f"rid={r.rid} (state={r.state}) -- slot leak")
        prev = seen_req_slots.setdefault(id(r), slot)
        if prev != slot:
            problems.append(
                f"request rid={r.rid} bound to slots {prev} and {slot}")
        pos = int(engine.slot_pos[slot])
        if pos >= engine.ec.cache_len:
            problems.append(
                f"slot {slot} position {pos} outside cache_len="
                f"{engine.ec.cache_len}")

    # draft-pool rows: bound rows must be a subset of live bound slots
    bound_live = {s for s, r in enumerate(engine.slot_req) if r is not None}
    for name, dec in getattr(engine, "_decoders", {}).items():
        bound = getattr(dec, "bound_slots", None)
        if bound is None:
            continue
        leaked = set(bound()) - bound_live
        if leaked:
            problems.append(
                f"decoder `{name}` draft-pool rows {sorted(leaked)} bound "
                "to freed slots -- draft-row leak")

    # migration exports: every ticket pins a live MIGRATING request, and
    # every pinned MIGRATING request has its ticket (the export pin is the
    # acquire side of the migration protocol; complete/cancel_export are
    # the only releases)
    exports = dict(getattr(engine, "_exports", {}))
    for rid, ticket in exports.items():
        r = ticket.get("req")
        if r is None or id(r) not in live_ids:
            problems.append(
                f"export ticket rid={rid} references a request no longer "
                "live on this engine -- export pin leak")
            continue
        if r.state is not State.MIGRATING:
            problems.append(
                f"export ticket rid={rid} pinned but request state is "
                f"{r.state} (expected MIGRATING)")
        if engine.slot_req[ticket["slot"]] is not r:
            problems.append(
                f"export ticket rid={rid} slot {ticket['slot']} no longer "
                "bound to the exporting request")
    for r in live:
        if (r.state is State.MIGRATING
                and getattr(r, "_export_pin", None) is not None
                and r.rid not in exports):
            problems.append(
                f"request rid={r.rid} MIGRATING with an export pin the "
                "engine no longer tracks")

    # prefix pins: counts == live pinning requests (export tickets count
    # as holders: export_kv moves pin ownership to the ticket until the
    # source release); pinned keys cached
    pins = dict(getattr(engine, "_prefix_pins", {}))
    holders = {}
    for r in live:
        key = getattr(r, "_prefix_pin", None)
        if key is not None:
            holders[key] = holders.get(key, 0) + 1
    for ticket in exports.values():
        key = ticket.get("prefix_pin")
        if key is not None:
            holders[key] = holders.get(key, 0) + 1
    for key, n in pins.items():
        if n <= 0:
            problems.append(f"prefix pin {key[0]!r} has non-positive "
                            f"count {n}")
        held = holders.get(key, 0)
        if n != held:
            problems.append(
                f"prefix pin count {n} for variant {key[0]!r} != "
                f"{held} live request(s) holding it -- pin leak")
        if key not in engine._prefix:
            problems.append(
                f"prefix pin for variant {key[0]!r} references an entry "
                "no longer in the cache")
    for key, held in holders.items():
        if key not in pins:
            problems.append(
                f"{held} live request(s) hold prefix pin {key[0]!r} "
                "that the engine no longer counts")

    # trace completeness (repro.obs): when tracing is on, the open
    # "request" spans this replica owns must match its live requests --
    # a span left open past retire/abort is an orphan the Perfetto
    # export would render as a request that never ended, and a missing
    # span means an instrumentation gap. A rid mid-migration may appear
    # live here while its trace track already moved to the importing
    # replica (the source still holds its export ticket until
    # complete_export), so exported rids are exempt on the live side.
    tracer = getattr(engine, "tracer", None)
    if tracer is not None and getattr(tracer, "enabled", False):
        rep = getattr(engine, "trace_replica", 0)
        live_rids = {r.rid for r in live}
        owned = tracer.open_requests(rep)
        for rid in sorted(live_rids - owned - set(exports)):
            problems.append(
                f"live request rid={rid} has no open trace span -- "
                "instrumentation gap")
        for rid in sorted(owned - live_rids):
            problems.append(
                f"open request span rid={rid} owned by replica {rep} "
                "has no live request -- orphan span")
    return problems


def check_server_conservation(server) -> List[str]:
    """Server-level invariants over ``AsyncLVLMServer`` + its engine."""
    problems = check_engine_conservation(server.engine)
    stream_rids = set(server._streams)
    live_rids = {r.rid for r in _live_requests(server.engine)}
    orphans = live_rids - stream_rids
    if orphans:
        problems.append(
            f"engine requests {sorted(orphans)} live with no registered "
            "stream -- token fan-out would drop them")
    for rid, stream in server._streams.items():
        if stream.aborted:
            problems.append(
                f"aborted stream rid={rid} still registered in _streams")
    return problems


def assert_conserved(obj, checker, where: str) -> None:
    problems = checker(obj)
    if problems:
        raise SanitizerError(
            f"sanitizer: {len(problems)} conservation violation(s) at "
            f"{where}:\n  - " + "\n  - ".join(problems))
