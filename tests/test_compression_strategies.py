"""Per-request pluggable CompressionStrategy (PR tentpole).

Acceptance contract of bringing visual-token compression to API parity
with decoders:

  * mixed-compression batch equivalence: one engine serving ``none`` /
    ``fastv-0.5`` / ``framefusion-0.25`` requests emits, per request at
    temperature 0, bit-identical tokens to three single-preset runs,
  * KV accounting (admission watermarks / ``kv_request_tokens`` /
    ``least_kv`` routing) uses POST-compression token counts -- the
    reservation shrinks with ``keep_ratio``,
  * prefix-cache keys include the compression variant: the same prompt
    under two variants yields two entries, and a hit is bit-identical to
    a cold prefill under that variant,
  * cross-modal pruners receive the text-prompt ``query`` embeddings
    (the old engine path passed ``query=None``),
  * ``GenerationConfig.compression`` registers a NAMED default strategy
    instead of mutating ``EngineConfig.compression``,
  * custom duck-typed strategies register via ``Engine(compressors=...)``;
    per-request KV compaction on a non-compacting engine errors cleanly.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CompressionConfig, EngineConfig, GenerationConfig,
                       LVLM, Request, make_compressor)
from repro.core.serving import Engine
from repro.core.token_compression.policy import (compress_visual_tokens,
                                                 compressed_token_count)

MIX_PRESETS = ("none", "fastv-0.5", "framefusion-0.25")


@pytest.fixture(scope="module")
def vlm():
    return LVLM.from_pretrained("qwen2-vl-2b", smoke=True)


def _workload(cfg, n, seed=5, lo=7, hi=13):
    rng = np.random.RandomState(seed)
    prompts = [list(rng.randint(1, cfg.vocab_size,
                                size=rng.randint(lo, hi))) for _ in range(n)]
    ves = [rng.randn(cfg.num_visual_tokens, cfg.d_model).astype(np.float32)
           * 0.02 for _ in range(n)]
    return prompts, ves


# -------------------------------------------- mixed-batch equivalence --


@pytest.mark.slow
def test_mixed_compression_batch_matches_single_preset_runs(vlm):
    """The acceptance criterion: none / fastv-0.5 / framefusion-0.25 in
    ONE batch, each request bit-identical to its single-preset run."""
    prompts, ves = _workload(vlm.cfg, 3)
    reqs = [Request(rid=i, tokens=list(p), max_new_tokens=6,
                    visual_embeds=ve, compression=c)
            for i, (p, ve, c) in enumerate(zip(prompts, ves, MIX_PRESETS))]
    rep = vlm.serve(reqs,
                    EngineConfig(max_batch=3, cache_len=96,
                                 temperature=0.0),
                    gen=GenerationConfig(decoder="greedy", temperature=0.0,
                                         max_new_tokens=6))
    assert rep.stats["finished"] == 3
    by_rid = {r.rid: r.generated for r in rep.requests}
    for i, preset in enumerate(MIX_PRESETS):
        ref = vlm.generate(prompts[i], GenerationConfig(
            decoder="greedy", max_new_tokens=6, compression=preset),
            visual_embeds=ves[i])
        assert by_rid[i] == ref.tokens, preset


def test_mixed_compression_smoke(vlm):
    """Fast CI smoke: ``none`` + ``fastv-0.5`` requests in one batch
    finish, compress to the right per-slot visual counts, and report
    per-strategy prefill token reduction."""
    prompts, ves = _workload(vlm.cfg, 2, seed=6)
    nv = vlm.cfg.num_visual_tokens
    reqs = [Request(rid=0, tokens=list(prompts[0]), max_new_tokens=3,
                    visual_embeds=ves[0]),
            Request(rid=1, tokens=list(prompts[1]), max_new_tokens=3,
                    visual_embeds=ves[1], compression="fastv-0.5")]
    rep = vlm.serve(reqs, EngineConfig(max_batch=2, cache_len=64,
                                       temperature=0.0),
                    gen=GenerationConfig(decoder="greedy", temperature=0.0,
                                         max_new_tokens=3))
    assert rep.stats["finished"] == 2
    eng = rep.engine
    assert eng.slot_nv[0] == nv
    assert eng.slot_nv[1] == nv // 2
    cs = eng.compression_stats()
    assert cs["none"]["prefill_token_reduction"] == 0.0
    assert cs["fastv-0.5"]["prefill_token_reduction"] == pytest.approx(0.5)
    assert rep.stats["compression/fastv-0.5/visual_tokens_out"] == nv // 2


# ------------------------------------------------------ KV accounting --


def test_kv_reservation_shrinks_with_keep_ratio(vlm):
    """Admission / kv_request_tokens must reserve the POST-compression
    prompt, monotonically shrinking with keep_ratio."""
    eng = Engine(vlm.model, vlm.params,
                 EngineConfig(max_batch=2, cache_len=256))
    rng = np.random.RandomState(0)
    ve = rng.randn(vlm.cfg.num_visual_tokens, vlm.cfg.d_model
                   ).astype(np.float32)

    def reserved(compression):
        return eng.kv_request_tokens(Request(
            rid=99, tokens=list(range(1, 13)), max_new_tokens=8,
            visual_embeds=ve, compression=compression))

    full, half, quarter = (reserved(None), reserved("fastv-0.5"),
                           reserved("fastv-0.25"))
    assert full > half >= quarter
    # exact: text 12 + nv 16 + new 8 = 36 -> 48; halved nv 8 -> 28 -> 32
    assert full == 48 and half == 32
    # committed pressure (the admission watermark signal) shrinks too
    r = Request(rid=0, tokens=list(range(1, 13)), max_new_tokens=8,
                visual_embeds=ve, compression="fastv-0.5")
    eng.submit(r)
    assert eng.kv_committed_tokens() == half


def test_least_kv_routing_sees_compressed_load(vlm):
    """JSQ on KV must see that a compressed request is lighter: a replica
    holding the fastv-0.25 variant of the SAME workload reports a lower
    kv_load than its sibling holding the uncompressed one."""
    router = vlm.serve_cluster(2, EngineConfig(max_batch=2, cache_len=256),
                               routing="least_kv")
    ra, rb = router.replicas
    rng = np.random.RandomState(1)
    ve = rng.randn(vlm.cfg.num_visual_tokens, vlm.cfg.d_model
                   ).astype(np.float32)
    toks = list(range(1, 13))
    ra.inflight[0] = Request(rid=0, tokens=list(toks), max_new_tokens=8,
                             visual_embeds=ve, compression="fastv-0.25")
    rb.inflight[1] = Request(rid=1, tokens=list(toks), max_new_tokens=8,
                             visual_embeds=ve)
    assert ra.kv_load() < rb.kv_load()


def test_compressed_token_count_matches_compressor_output():
    """The shape-only accounting count must equal what the pruner/merger
    actually emits, for every preset family (incl. tome's capped-round
    loop)."""
    rng = np.random.RandomState(3)
    embeds = jnp.asarray(rng.randn(1, 48, 16), jnp.float32)
    for preset in ("none", "fastv-0.5", "l2-0.3", "divprune-0.25",
                   "tome-0.4", "framefusion-0.25"):
        strat = make_compressor(preset)
        out, _idx, _info = compress_visual_tokens(strat.cc, embeds)
        assert out.shape[1] == strat.compressed_token_count(48), preset
        assert (strat.compressed_token_count(48)
                == compressed_token_count(strat.cc, 48))


# -------------------------------------------------- prefix-cache keys --


def test_prefix_cache_two_variants_two_entries(vlm):
    """Same prompt under two compression variants must produce two cache
    entries -- a fastv-0.5 prefill never serves a none lookup."""
    eng = Engine(vlm.model, vlm.params,
                 EngineConfig(max_batch=2, cache_len=64, prefix_cache=True,
                              prefix_block=8))
    prompt = list(range(1, 17))
    eng.submit(Request(rid=0, tokens=list(prompt), max_new_tokens=2))
    eng.submit(Request(rid=1, tokens=list(prompt), max_new_tokens=2,
                       compression="fastv-0.5"))
    eng.run()
    variants = {key[0] for key in eng._prefix}
    assert len(eng._prefix) == 2
    assert variants == {"none", "fastv-0.5"}
    # lookups are variant-scoped
    assert eng._prefix_lookup(prompt, variant="none")[0] == 16
    assert eng._prefix_lookup(prompt, variant="fastv-0.5")[0] == 16
    assert eng._prefix_lookup(prompt, variant="divprune-0.5")[0] == 0


def test_prefix_hit_bit_identical_to_cold_prefill(vlm):
    """A prefix hit under a variant reproduces the cold prefill under
    that variant bit-for-bit."""
    rng = np.random.RandomState(9)
    prompt = list(rng.randint(1, vlm.cfg.vocab_size, size=16))

    def run(prefix_cache):
        eng = Engine(vlm.model, vlm.params,
                     EngineConfig(max_batch=1, cache_len=64,
                                  prefix_cache=prefix_cache,
                                  prefix_block=8))
        outs = []
        for rid in (0, 1):
            r = Request(rid=rid, tokens=list(prompt), max_new_tokens=4,
                        compression="fastv-0.5")
            eng.submit(r)
            eng.run()
            outs.append(list(r.generated))
        return outs, eng

    (warm_a, warm_b), eng = run(prefix_cache=True)
    (cold_a, cold_b), _ = run(prefix_cache=False)
    assert eng.prefix_hit_tokens > 0          # second run really reused
    assert warm_a == cold_a
    assert warm_b == cold_b


# ----------------------------------------------------- query threading --


def test_cross_modal_pruner_receives_prompt_query(vlm):
    """The engine threads the text-prompt embeddings into cross-modal
    pruners: a sparsevlm request's tokens equal a run over the SAME
    visual tokens pre-compressed WITH the query (and the query changes
    which tokens survive, so None would diverge)."""
    from repro.models.layers import embed_tokens

    rng = np.random.RandomState(12)
    prompt = list(rng.randint(1, vlm.cfg.vocab_size, size=9))
    ve = rng.randn(vlm.cfg.num_visual_tokens, vlm.cfg.d_model
                   ).astype(np.float32) * 0.02
    cc = CompressionConfig(token_pruner="sparsevlm", keep_ratio=0.5)
    query = embed_tokens(vlm.params["embed"],
                         jnp.asarray([prompt], jnp.int32))
    _, idx_q, _ = compress_visual_tokens(cc, jnp.asarray(ve)[None],
                                         query=query)
    _, idx_none, _ = compress_visual_tokens(
        cc, jnp.asarray(ve)[None],
        query=jnp.zeros_like(query))
    # the query genuinely conditions the selection at this seed
    assert not np.array_equal(np.asarray(idx_q), np.asarray(idx_none))

    out = vlm.generate(prompt, GenerationConfig(
        decoder="greedy", max_new_tokens=4, compression="sparsevlm-0.5"),
        visual_embeds=ve)
    ve_q, _, _ = compress_visual_tokens(cc, jnp.asarray(ve)[None],
                                        query=query)
    ref = vlm.generate(prompt, GenerationConfig(
        decoder="greedy", max_new_tokens=4, compression="none"),
        visual_embeds=np.asarray(ve_q[0]))
    assert out.tokens == ref.tokens


# ------------------------------------------------- registry & layering --


def test_generation_config_registers_named_default(vlm):
    """GenerationConfig.compression is sugar for a NAMED registered
    strategy; EngineConfig.compression is no longer mutated."""
    rng = np.random.RandomState(2)
    reqs = [Request(rid=0, tokens=list(rng.randint(1, 512, size=8)),
                    max_new_tokens=2)]
    rep = vlm.serve(reqs, EngineConfig(max_batch=1, cache_len=64),
                    gen=GenerationConfig(decoder="greedy", max_new_tokens=2,
                                         compression="fastv-0.5"))
    eng = rep.engine
    assert eng._default_comp_name == "fastv-0.5"
    assert "fastv-0.5" in eng._compressors
    assert eng.ec.compression == CompressionConfig()   # untouched


def test_custom_strategy_via_engine_registry(vlm):
    """A duck-typed custom strategy registers under Engine(compressors=)
    and serves requests that name it."""
    class KeepHalf:
        name = "keep-half"
        encoder_active = True

        def compress_prefill(self, embeds, *, query=None, scores=None):
            keep = embeds.shape[1] // 2
            return embeds[:, :keep], None, {"method": "keep-half"}

        def compressed_token_count(self, n):
            return n // 2

    rng = np.random.RandomState(4)
    ve = rng.randn(vlm.cfg.num_visual_tokens, vlm.cfg.d_model
                   ).astype(np.float32) * 0.02
    eng = Engine(vlm.model, vlm.params,
                 EngineConfig(max_batch=1, cache_len=64),
                 compressors={"keep-half": KeepHalf()})
    r = Request(rid=0, tokens=list(rng.randint(1, 512, size=8)),
                max_new_tokens=2, visual_embeds=ve,
                compression="keep-half")
    eng.submit(r)
    assert eng.kv_request_tokens(r) == 32     # 8 + 8 + 2 -> block 32
    eng.run()
    assert eng.slot_nv[0] == vlm.cfg.num_visual_tokens // 2
    assert len(r.generated) == 2


def test_per_request_kv_compaction_needs_compacting_engine(vlm):
    """A per-request KV-compacting strategy on a non-compacting engine is
    a clean ValueError at submit, not cache corruption."""
    eng = Engine(vlm.model, vlm.params,
                 EngineConfig(max_batch=1, cache_len=64))
    with pytest.raises(ValueError, match="compact"):
        eng.submit(Request(rid=0, tokens=list(range(1, 9)),
                           max_new_tokens=2, compression="streaming-kv"))


def test_unknown_compression_name_rejected(vlm):
    eng = Engine(vlm.model, vlm.params,
                 EngineConfig(max_batch=1, cache_len=64))
    with pytest.raises(ValueError, match="unknown compression"):
        eng.submit(Request(rid=0, tokens=[1, 2, 3], max_new_tokens=2,
                           compression="quantum-entangle-0.5"))
