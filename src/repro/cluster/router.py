"""``Router``: one submit surface over N ``AsyncLVLMServer`` replicas.

The router keeps the server's contract -- ``async for tok in
router.submit(req)`` -- while dispatching each request to a replica via a
routing policy (round-robin / least-KV / prefix-affinity), so a fleet of
engines (possibly heterogeneous: different compression presets, decoder
defaults, draft models per replica) serves one open-loop request stream:

    router = lvlm.serve_cluster(replicas=2, routing="prefix_affinity")
    async with router:
        async for tok in router.submit(req):
            ...

Lifecycle:

  * healthy   -- takes new work.
  * draining  -- ``router.drain(i)``: finishes its in-flight streams but
                 the policy never offers it new requests (``undrain``
                 reverses it while the pump is still alive).
  * dead      -- the replica's pump raised. Its queued-but-UNSTARTED
                 requests (nothing generated yet: parked at the admission
                 gate or still waiting/prefilling in the engine) FAIL OVER
                 to a healthy sibling transparently -- the consumer's
                 ``async for`` never sees the failure. Requests that had
                 already streamed tokens re-raise to their consumer (the
                 tokens cannot be un-sent); the router never re-runs a
                 request that may have observable output.

Failover is consumer-driven: the pump failure surfaces on the stream's
next ``__anext__``, the ``RouterStream`` catches it, resets the request's
runtime state, and re-dispatches among the survivors. Everything is
event-loop-confined, like the serving layer underneath.
"""
from __future__ import annotations

import asyncio
from typing import Dict, List, Optional, Sequence

from repro.core.serving.request import Request, State
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.policies import make_policy
from repro.serving.server import AsyncLVLMServer, TokenStream


class Replica:
    """One ``AsyncLVLMServer`` plus its fleet-facing state and counters."""

    def __init__(self, index: int, server: AsyncLVLMServer):
        self.index = index
        self.server = server
        self.draining = False
        self.dispatched = 0           # requests routed here (incl. retries)
        self.completed = 0            # streams finished here (not aborted)
        self.inflight: Dict[int, Request] = {}   # rid -> assigned request

    # ------------------------------------------------------------ health --
    @property
    def dead(self) -> bool:
        return self.server._pump_error is not None

    @property
    def state(self) -> str:
        if self.dead:
            return "dead"
        return "draining" if self.draining else "ok"

    @property
    def error(self) -> Optional[BaseException]:
        return self.server._pump_error

    # ------------------------------------------------- policy observables --
    def kv_load(self) -> float:
        """KV-reservation fraction of every live request ASSIGNED here --
        admitted or not (a dispatched request will commit its reservation
        the moment its consumer starts, so a join-the-shortest-queue
        policy must see it immediately, not after first ``__anext__``)."""
        eng = self.server.engine
        need = sum(eng.kv_request_tokens(r) for r in self.inflight.values()
                   if r.state is not State.DONE)
        return need / max(1, eng.kv_capacity_tokens)

    def queue_depth(self) -> int:
        return self.server.admission.queue_depth

    def prefix_block(self) -> int:
        return self.server.engine.ec.prefix_block

    def cached_prefix_len(self, tokens: Sequence[int],
                          compression: Optional[str] = None) -> int:
        """Longest block-aligned prefix of ``tokens`` this replica's
        engine caches UNDER the request's compression variant (None ->
        the replica's default strategy). Pure probe (``touch=False``): no
        LRU refresh -- only a real prefill hit should touch recency."""
        eng = self.server.engine
        if not eng.ec.prefix_cache:
            return 0
        k, _hit = eng._prefix_lookup([int(x) for x in tokens], touch=False,
                                     variant=compression)
        return k


class RouterStream:
    """One routed request's token channel: the ``TokenStream`` contract
    (async iteration, ``cancel()``, ``tokens``, ``aborted``) plus
    transparent failover while the request is still unstarted."""

    def __init__(self, router: "Router", request: Request):
        self._router = router
        self.request = request
        self.replica: Optional[Replica] = None
        self._inner: Optional[TokenStream] = None
        self._done = False
        self.failovers = 0            # times THIS request was re-dispatched

    @property
    def tokens(self) -> List[int]:
        return list(self.request.generated)

    @property
    def aborted(self) -> bool:
        return self._inner is not None and self._inner.aborted

    def cancel(self) -> bool:
        self._router._streams.pop(self.request.rid, None)
        if self.replica is not None:
            self.replica.inflight.pop(self.request.rid, None)
        self._done = True
        return self._inner.cancel() if self._inner is not None else False

    def __aiter__(self) -> "RouterStream":
        return self

    async def __anext__(self) -> int:
        while True:
            try:
                return await self._inner.__anext__()
            except StopAsyncIteration:
                self._retire()
                raise
            except asyncio.CancelledError:
                # the consumer task was cancelled (client went away): free
                # the engine-side resources AND the router bookkeeping, or
                # the rid / Replica.inflight entry would leak forever and
                # least_kv would keep counting a request nobody runs
                if not self._done:
                    self.cancel()
                raise
            except Exception as exc:
                if not self._failover_eligible():
                    self._retire(failed=True)
                    raise
                self.failovers += 1
                self._router.failovers += 1
                try:
                    self._router._redispatch(self, exc)
                except BaseException:
                    self._retire(failed=True)   # no sibling: free the rid
                    raise
                # loop: continue consuming from the new replica's stream

    def _failover_eligible(self) -> bool:
        """Retry only when the dead replica produced NOTHING observable:
        the pump died and this request never emitted a token."""
        return (self.replica is not None and self.replica.dead
                and not self.request.generated)

    def _retire(self, failed: bool = False) -> None:
        if self._done:
            return
        self._done = True
        self._router._streams.pop(self.request.rid, None)
        if self.replica is not None:
            self.replica.inflight.pop(self.request.rid, None)
            if not failed and not self._inner.aborted:
                self.replica.completed += 1


class Router:
    """Multi-engine front: routing policy + replica lifecycle + fleet
    metrics over N ``AsyncLVLMServer`` replicas (see module docstring).

    Build via ``LVLM.serve_cluster``; construct directly to mix replicas
    of DIFFERENT models or hand-built servers.
    """

    def __init__(self, servers: Sequence[AsyncLVLMServer],
                 routing="round_robin"):
        if not servers:
            raise ValueError("Router needs at least one replica")
        self.replicas = [Replica(i, s) for i, s in enumerate(servers)]
        self.policy = make_policy(routing)
        self.metrics = ClusterMetrics(self)
        self._streams: Dict[int, RouterStream] = {}
        self.failovers = 0
        for rep in self.replicas:
            # server-initiated aborts (disconnect timeouts fire inside the
            # replica pump, no consumer will ever retire the stream) must
            # drop the router's bookkeeping too, or the rid leaks forever
            rep.server.on_abort = self._on_server_abort

    # -------------------------------------------------------- lifecycle --
    async def start(self) -> "Router":
        for rep in self.replicas:
            await rep.server.start()
        return self

    async def stop(self, drain: bool = True) -> None:
        """Stop every replica. A replica whose pump already died does not
        re-raise here: its failure either failed over or surfaced on the
        affected streams, and is kept on ``Replica.error`` for reports."""
        for rep in self.replicas:
            try:
                await rep.server.stop(drain=drain)
            except BaseException:
                if not rep.dead:      # pragma: no cover - defensive
                    raise

    async def __aenter__(self) -> "Router":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=not any(exc))

    def drain(self, index: int) -> None:
        """Take replica ``index`` out of rotation: in-flight streams
        finish, new requests route elsewhere."""
        self.replicas[index].draining = True

    def undrain(self, index: int) -> None:
        self.replicas[index].draining = False

    # ----------------------------------------------------------- intake --
    def _candidates(self) -> List[Replica]:
        cands = [rep for rep in self.replicas if rep.state == "ok"]
        if not cands:
            raise RuntimeError("no healthy replica (all draining or dead)")
        return cands

    def submit(self, request: Request) -> RouterStream:
        """Route ``request`` to a replica and return its stream. Like the
        single-server ``submit``: never blocks (replica admission gates on
        the stream's first ``__anext__``); rids are fleet-unique."""
        if request.rid in self._streams:
            raise ValueError(f"request id {request.rid} already streaming")
        stream = RouterStream(self, request)
        self._dispatch(stream)
        self._streams[request.rid] = stream
        return stream

    def _dispatch(self, stream: RouterStream) -> None:
        rep = self.policy.pick(stream.request, self._candidates())
        rep.dispatched += 1
        rep.inflight[stream.request.rid] = stream.request
        stream.replica = rep
        stream._inner = rep.server.submit(stream.request)

    def _redispatch(self, stream: RouterStream, cause: BaseException) -> None:
        """Failover: the request never started on the dead replica, so its
        runtime state resets to a fresh submit and a sibling takes it."""
        if stream.replica is not None:
            stream.replica.inflight.pop(stream.request.rid, None)
        _reset_for_retry(stream.request)
        try:
            self._dispatch(stream)
        except (RuntimeError, ValueError) as exc:
            raise RuntimeError(
                f"request {stream.request.rid}: replica "
                f"{stream.replica.index} died and no healthy sibling "
                "remains") from cause

    def abort(self, rid: int) -> bool:
        stream = self._streams.get(rid)
        return stream.cancel() if stream is not None else False

    def _on_server_abort(self, rid: int) -> None:
        """A replica aborted ``rid`` on its own (disconnect timeout,
        direct ``server.abort``): retire the router stream so the rid
        frees up. A consumer that comes back can still drain the tokens
        already fanned out (the inner channel keeps them)."""
        stream = self._streams.get(rid)
        if stream is not None and stream._inner is not None \
                and stream._inner.aborted:
            stream._retire()

    # ---------------------------------------------------------- reports --
    def summary(self) -> Dict:
        """Fleet-wide merged metrics (see ``ClusterMetrics.summary``)."""
        return self.metrics.summary()


def _reset_for_retry(req: Request) -> None:
    """Return a never-started request to its pre-submit state so a sibling
    replica can run it from scratch (failover path; the caller guarantees
    ``req.generated`` is empty)."""
    from repro.core.serving.request import State

    assert not req.generated, "cannot retry a request with emitted tokens"
    req.state = State.WAITING
    req.prefill_done = 0
    req.aborted = False
    req.first_token_time = None
    req.finish_time = None
    req.served_tokens = 0
    # the sibling re-resolves the compression strategy (its registry /
    # default may differ), so the stamped post-compression count resets
    req.nv_compressed = None
    for attr in ("_slot", "_ve", "_prefix_pin", "_needs_ttft",
                 "_gate_clock", "_comp_name"):
        if hasattr(req, attr):
            delattr(req, attr)
