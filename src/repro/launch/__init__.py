# launchers: mesh.py (production meshes), dryrun.py (lower+compile grid),
# train.py / serve.py CLI drivers.
