"""Granite-34B-Code (dense llama-arch, MQA kv=1). [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,             # MQA: single KV head
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    activation="swiglu",
    rope_theta=1.0e4,
    tie_embeddings=True,
    sliding_window=16384,       # long_500k variant
)

SMOKE_CONFIG = CONFIG.with_(
    name="granite-smoke",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=1, head_dim=32,
    d_ff=512, vocab_size=512, sliding_window=64, dtype="float32",
)
