"""``repro.cluster`` -- multi-engine routing: one submit surface, N engines.

One KV pool and one FIFO cannot serve heavy open-loop traffic; at fleet
scale, scheduling and cache-affinity decisions dominate tail latency.
This layer fronts N ``AsyncLVLMServer`` replicas (possibly heterogeneous
-- different compression presets, decoder defaults, draft models) behind
the exact serving contract clients already use:

    router = lvlm.serve_cluster(replicas=2, routing="prefix_affinity")
    async with router:
        async for tok in router.submit(req):
            ...
    print(router.summary())           # fleet-wide percentiles + routing

Three planes over the serving layer:

  router.py    ``Router`` / ``Replica`` / ``RouterStream`` -- dispatch,
               replica health (ok / draining / dead), drain lifecycle,
               and consumer-transparent FAILOVER: a dead pump's
               queued-but-unstarted requests re-dispatch to a sibling
               (started streams re-raise; emitted tokens are never
               re-run).
  policies.py  ``ROUTING_POLICIES`` -- round_robin, least_kv (KV
               reservations of every assigned request, the PR 3
               ``kv_request_tokens`` accounting), and prefix_affinity
               (longest cached block-aligned prefix wins; cold prefixes
               consistent-hash so affinity builds).
  metrics.py   ``ClusterMetrics`` -- merges per-replica registries into
               fleet-wide TTFT/TPOT percentiles, SLO attainment, fleet
               throughput vs the slowest replica's clock, per-replica
               dispatch/health, aggregate prefix hits.

Disaggregated serving (roles): ``serve_cluster(..., roles=["prefill",
"decode"])`` splits the fleet -- prefill replicas run the vision encoder
+ chunked prefill and hand the post-compression KV to decode replicas
over the modeled KV link (``prefix_tier.py`` adds the cluster-shared
radix prefix tier so a prefix cached anywhere short-circuits prefill
everywhere); ``Router.drain`` migrates live KV the same way instead of
merely refusing new work.

SLO-aware dispatch composes from the serving layer: give each replica
``AdmissionConfig(order="slack")`` and its deferred queue drains
earliest-TTFT-deadline-first (deadline minus the live expected TTFT from
``MetricsRegistry``) instead of strict FIFO -- starvation-free because
parked deadlines are fixed while new arrivals' deadlines recede.

With one replica the router is a transparent shim: ``Router.submit``
streams are bit-identical to the bare server at temperature 0
(``tests/test_cluster.py``).
"""
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.policies import (LeastKVPolicy, PrefixAffinityPolicy,
                                    ROUTING_POLICIES, RoundRobinPolicy,
                                    make_policy)
from repro.cluster.prefix_tier import SharedPrefixTier
from repro.cluster.router import ROLES, Replica, Router, RouterStream

__all__ = [
    "Router", "Replica", "RouterStream", "ClusterMetrics",
    "ROLES", "SharedPrefixTier",
    "ROUTING_POLICIES", "make_policy",
    "RoundRobinPolicy", "LeastKVPolicy", "PrefixAffinityPolicy",
]
