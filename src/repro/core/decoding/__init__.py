from repro.core.decoding.sampling import (
    sample_token, greedy, temperature_sample, top_k_sample, top_p_sample)
from repro.core.decoding.speculative import (
    SpecStats, speculative_generate, acceptance_rate)
from repro.core.decoding.early_exit import (
    early_exit_decode_step, layer_confidences)
