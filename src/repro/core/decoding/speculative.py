"""Multimodal speculative decoding (survey dim 4a): draft-then-verify.

Reproduces the surveyed pipeline:

  * Gagrani et al. [CVPR'24w]: a small LANGUAGE-ONLY draft model speculates
    for a multimodal target -- the draft never sees the visual embeddings
    (its prompt is the text tokens only), the target verifies with full
    multimodal context. We implement exactly that asymmetry: the target's
    cache is built over [visual | text], the draft's over text only, and the
    two position streams are reconciled by the visual offset.
  * standard Leviathan/Chen rejection sampling: accept draft token x with
    prob min(1, p_target(x)/p_draft(x)); on rejection resample from
    norm(max(0, p_t - p_d)); if the whole block survives, sample one bonus
    token from the target's last logits.
  * LANTERN [ICLR'25] relaxed acceptance: visual AR models spread mass over
    many semantically-equivalent tokens ("token selection ambiguity"), so
    LANTERN aggregates target probability over the draft token's latent
    neighbourhood B_k(x) before the acceptance test:
        accept with prob min(1, sum_{y in B_k(x)} p_t(y) / p_d(x))
    bounded by a total-variation budget delta. ``lantern_k`` > 0 enables it;
    the neighbourhood is cosine-kNN in the target's unembedding space.

Verification is ONE ``model.extend`` call (gamma+1 logits in a single pass)
against the target cache -- the memory-bound decode loop is replaced by a
compute-dense block scoring, which is the entire point of the technique.
Cache rollback is implicit: the next extend overwrites the rejected slots,
and causal masking hides stale positions (q_pos < k_pos) meanwhile.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decoding.sampling import sample_probs


@dataclasses.dataclass
class SpecStats:
    proposed: int = 0
    accepted: int = 0
    bonus: int = 0
    target_calls: int = 0
    draft_calls: int = 0

    @property
    def tokens_emitted(self) -> int:
        return self.accepted + self.bonus + self.rejected_resamples

    @property
    def rejected_resamples(self) -> int:
        # every target call emits at least one token (resample or bonus)
        return self.target_calls - self.bonus

    def mean_accepted_per_call(self) -> float:
        return (self.accepted + self.target_calls) / max(self.target_calls, 1)


def acceptance_rate(stats: SpecStats) -> float:
    return stats.accepted / max(stats.proposed, 1)


def _lantern_neighbourhood(embed_w: np.ndarray, k: int):
    """Precompute cosine-kNN token neighbourhoods in unembedding space."""
    w = np.asarray(embed_w, np.float32)
    w = w / (np.linalg.norm(w, axis=1, keepdims=True) + 1e-6)
    sims = w @ w.T
    return np.argsort(-sims, axis=1)[:, :k]        # [V, k], col 0 == self


def lantern_neighbourhood_from_params(t_params, k: int):
    """Build the LANTERN kNN table from a target param tree (embed/unembed)."""
    ew = t_params["embed"]
    w = ew["unembed"].T if "unembed" in ew else ew["tok"]
    return _lantern_neighbourhood(np.asarray(w, np.float32), k)


def draft_block(d_extend, d_decode, d_params, d_cache, lead_toks, start, *,
                gamma: int, temperature: float, key,
                stats: Optional[SpecStats] = None):
    """Draft ``gamma`` tokens autoregressively.

    ``lead_toks`` (list[int], len >= 1) are the committed tokens the draft
    cache has not scored yet, ending with the current last token; they are
    scored in ONE ``extend`` at position ``start`` before drafting begins.
    The lead is how the caller back-fills the draft-cache hole left by a
    fully-accepted block: the last accepted draft token was proposed but
    never written to the draft's KV cache, so the next round must replay it
    (target and draft caches stay position-consistent).

    Returns (draft_toks, draft_ps, d_cache, key). Shared by the standalone
    ``speculative_generate`` driver and the engine-side decoder strategy in
    ``repro.api.decoders`` so both follow the same proposal distribution.
    """
    draft_toks, draft_ps = [], []
    cur = jnp.asarray([lead_toks], jnp.int32)          # [1, k]
    d_len = start + len(lead_toks)
    for g in range(gamma):
        if g == 0:
            lg, d_cache = d_extend(d_params, d_cache, cur, jnp.int32(start))
            lg = lg[:, -1]
        else:
            lg, d_cache = d_decode(d_params, d_cache, cur,
                                   jnp.int32(d_len - 1))
        if stats is not None:
            stats.draft_calls += 1
        pd = sample_probs(lg, temperature=temperature)
        key, kk = jax.random.split(key)
        nxt = (jnp.argmax(pd, -1) if temperature <= 0
               else jax.random.categorical(kk, jnp.log(pd + 1e-30))
               ).astype(jnp.int32)
        draft_toks.append(int(nxt[0]))
        draft_ps.append(pd[0])
        cur = nxt[:, None]
        d_len += 1
    return draft_toks, draft_ps, d_cache, key


def batched_draft_block(d_extend, d_decode, d_params, d_pool, lead2, starts,
                        pos0, *, gamma: int, temperature: float, key,
                        scratch_pos: int, stats: Optional[SpecStats] = None,
                        n_slots: int = 1):
    """Draft ``gamma`` tokens for MANY slot rows in fixed-shape jitted calls
    (the engine's batched counterpart of ``draft_block``).

    ``lead2 [B,2]`` holds, per row, the last two committed text tokens
    ``[c_{t-1}, c_t]`` and ``starts [B] = t-1``: rewriting position ``t-1``
    with the token/position pair it already holds is a KV no-op, so ONE
    fixed-shape 2-token ``extend`` uniformly covers both the
    post-full-accept draft-cache hole (where ``t-1`` was never written) and
    the ordinary case -- no per-row ragged lead. ``pos0 [B] = t`` is each
    row's current last-token position; draft token ``j`` is then scored at
    ``t+1+j`` by one batched ``decode_step`` per step. Inactive rows are
    routed to the draft pool's scratch tail (``scratch_pos``); their cache
    rows are per-row garbage by construction.

    Returns ``(draft_toks [B, gamma] np.int32, draft_ps: gamma x [B, V],
    d_pool, key)``. Row-sliced outputs feed the same ``accept_block`` as
    the batch-1 driver, so batched and standalone speculative follow the
    same proposal distribution.
    """
    B = lead2.shape[0]
    draft_toks = np.zeros((B, gamma), np.int32)
    draft_ps = []
    if gamma <= 0:
        return draft_toks, draft_ps, d_pool, key
    lg, d_pool = d_extend(d_params, d_pool, jnp.asarray(lead2, jnp.int32),
                          jnp.asarray(starts, jnp.int32))
    lg = lg[:, -1]
    pos0 = jnp.asarray(pos0, jnp.int32)
    cur = None
    for g in range(gamma):
        if g > 0:
            pos = jnp.minimum(pos0 + g, scratch_pos)
            lg, d_pool = d_decode(d_params, d_pool, cur, pos)
        if stats is not None:
            stats.draft_calls += n_slots
        pd = sample_probs(lg, temperature=temperature)
        key, kk = jax.random.split(key)
        nxt = (jnp.argmax(pd, -1) if temperature <= 0
               else jax.random.categorical(kk, jnp.log(pd + 1e-30))
               ).astype(jnp.int32)
        draft_toks[:, g] = np.asarray(nxt)
        draft_ps.append(pd)
        cur = nxt[:, None]
    return draft_toks, draft_ps, d_pool, key


def accept_block(key, t_logits, draft_toks, draft_ps, *, temperature: float,
                 limit: int, nbhd=None, lantern_delta: float = 0.2):
    """Leviathan/Chen acceptance (+ optional LANTERN relaxation) over ONE
    verified block.

    ``t_logits`` [1, gamma+1, V] are the target logits for
    [committed_tok, draft_0, ..., draft_{gamma-1}]; ``limit`` caps how many
    tokens this round may emit. Returns (emitted, n_accepted, bonus, key):
    ``emitted`` lists the round's output tokens (accepted drafts plus either
    the rejection resample or the whole-block bonus token).
    """
    gamma = len(draft_toks)
    emitted = []
    n_acc = 0
    emitted_reject = False
    for g in range(gamma):
        pt = sample_probs(t_logits[:, g], temperature=temperature)[0]
        pd = draft_ps[g]
        x = draft_toks[g]
        p_acc_num = float(pt[x])
        if nbhd is not None:
            # LANTERN: aggregate target mass over the latent
            # neighbourhood of x, capped by the TV budget delta
            extra = float(jnp.sum(pt[nbhd[x]])) - float(pt[x])
            p_acc_num = min(p_acc_num + max(extra, 0.0),
                            p_acc_num + lantern_delta)
        ratio = p_acc_num / max(float(pd[x]), 1e-30)
        key, ku = jax.random.split(key)
        u = float(jax.random.uniform(ku)) if temperature > 0 else 0.5
        if ratio >= 1.0 or u < ratio:
            n_acc += 1
            emitted.append(x)
            if len(emitted) >= limit:
                break
        else:
            # rejection: resample from norm(max(0, p_t - p_d))
            resid = jnp.clip(pt - pd, 0.0)
            tot = float(jnp.sum(resid))
            if tot <= 1e-9:
                resid = pt
                tot = float(jnp.sum(resid))
            key, kr = jax.random.split(key)
            emitted.append(int(jax.random.categorical(
                kr, jnp.log(resid / tot + 1e-30))))
            emitted_reject = True
            break
    bonus = False
    if not emitted_reject and len(emitted) < limit and n_acc == gamma:
        # whole block accepted: bonus token from the last target logits
        pt = sample_probs(t_logits[:, gamma], temperature=temperature)[0]
        key, kb = jax.random.split(key)
        y = (int(jnp.argmax(pt)) if temperature <= 0
             else int(jax.random.categorical(kb, jnp.log(pt + 1e-30))))
        emitted.append(y)
        bonus = True
    return emitted, n_acc, bonus, key


def speculative_generate(target, draft, t_params, d_params, prompt,
                         *, max_new_tokens: int, gamma: int = 4,
                         temperature: float = 0.0,
                         lantern_k: int = 0, lantern_delta: float = 0.2,
                         visual_embeds: Optional[jax.Array] = None,
                         key: Optional[jax.Array] = None,
                         cache_margin: int = 8):
    """Generate with draft-then-verify. Returns (tokens [T], SpecStats).

    target/draft: Model instances (same vocab). ``prompt`` [S] int32.
    ``visual_embeds`` [Nv, d_target] goes ONLY to the target (language-only
    drafting per Gagrani et al.).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    stats = SpecStats()
    prompt = jnp.asarray(prompt, jnp.int32)[None]          # [1, S]
    s = int(prompt.shape[1])
    nv = 0 if visual_embeds is None else int(visual_embeds.shape[0])
    budget = s + nv + max_new_tokens + gamma + cache_margin

    # --- prefill both models -------------------------------------------
    t_batch = {"tokens": prompt}
    if visual_embeds is not None:
        t_batch["visual_embeds"] = visual_embeds[None]
    t_logits, t_cache = jax.jit(
        lambda p, b: target.prefill(p, b, cache_len=budget))(t_params, t_batch)
    d_logits, d_cache = jax.jit(
        lambda p, b: draft.prefill(p, b, cache_len=budget))(d_params,
                                                            {"tokens": prompt})
    stats.target_calls += 1
    stats.draft_calls += 1

    t_extend = jax.jit(target.extend, static_argnames=())
    d_extend = jax.jit(draft.extend)
    d_decode = jax.jit(draft.decode_step)

    nbhd = None
    if lantern_k > 1:
        nbhd = lantern_neighbourhood_from_params(t_params, lantern_k)

    out = []
    # sample the first token from the prefill logits
    p0 = sample_probs(t_logits[:, -1], temperature=temperature)
    key, k0 = jax.random.split(key)
    tok = (jnp.argmax(p0, -1) if temperature <= 0
           else jax.random.categorical(k0, jnp.log(p0 + 1e-30))).astype(
               jnp.int32)
    out.append(int(tok[0]))

    t_len = s          # text tokens scored so far (target pos = nv + t_len)
    d_valid = s        # draft-cache committed prefix (see draft_block lead)
    while len(out) < max_new_tokens:
        # --- draft gamma tokens autoregressively -----------------------
        # (draft cache rollback is implicit: drafting restarts from the
        # target's committed length t_len each round; the lead replays any
        # committed tokens the draft cache is missing)
        committed = prompt[0].tolist() + out      # text stream, pos i
        lead = committed[d_valid:t_len + 1]
        draft_toks, draft_ps, d_cache, key = draft_block(
            d_extend, d_decode, d_params, d_cache, lead, d_valid,
            gamma=gamma, temperature=temperature, key=key, stats=stats)

        # --- verify: ONE target pass over [tok, draft block] -----------
        block = jnp.asarray([int(tok[0])] + draft_toks, jnp.int32)[None]
        t_logits, t_cache = t_extend(t_params, t_cache, block,
                                     jnp.int32(nv + t_len))
        stats.target_calls += 1
        stats.proposed += gamma

        emitted, n_acc, bonus, key = accept_block(
            key, t_logits, draft_toks, draft_ps, temperature=temperature,
            limit=max_new_tokens - len(out), nbhd=nbhd,
            lantern_delta=lantern_delta)
        out.extend(emitted)
        stats.accepted += n_acc
        stats.bonus += int(bonus)

        t_len += 1 + n_acc          # target consumed tok + accepted drafts
        # draft cache holds committed tokens through t_len-1, EXCEPT after a
        # whole-block accept: the last accepted draft was proposed, never
        # written -- the next round's lead replays it
        d_valid = t_len - (1 if (gamma > 0 and n_acc == gamma) else 0)
        tok = jnp.asarray([out[-1]], jnp.int32)

    return out[:max_new_tokens], stats
