from repro.training.optimizer import (
    OptimizerConfig, adamw_init, adamw_update, cosine_lr, global_norm)
from repro.training.data import SyntheticDataConfig, synthetic_batches
from repro.training.checkpoint import save_checkpoint, load_checkpoint
from repro.training.loop import TrainState, make_train_step, train_loop
