"""End-to-end training driver: a ~100M-param dense LM for a few hundred
steps on the synthetic pipeline, with checkpointing.

    PYTHONPATH=src python examples/train_micro.py              # full run
    PYTHONPATH=src python examples/train_micro.py --steps 20   # quick look

The config is the phi4-mini family scaled to ~100M (the assignment's
"train ~100M model for a few hundred steps" end-to-end driver).
"""
import argparse

from repro.configs import get_config
from repro.models import build
from repro.training import (OptimizerConfig, SyntheticDataConfig,
                            train_loop)


def micro_config():
    return get_config("phi4-mini-3.8b").with_(
        name="phi4-micro-100m",
        num_layers=8, d_model=640, num_heads=10, num_kv_heads=2,
        head_dim=64, d_ff=1792, vocab_size=50304, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_micro_ckpt")
    args = ap.parse_args()

    cfg = micro_config()
    model = build(cfg)
    n = cfg.param_count()
    print(f"{cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")
    out = train_loop(
        model,
        oc=OptimizerConfig(lr=6e-4, warmup_steps=args.steps // 10,
                           total_steps=args.steps, weight_decay=0.1),
        dc=SyntheticDataConfig(batch=args.batch, seq_len=args.seq),
        num_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(args.steps // 3, 1), log_every=10)
    print(f"DONE loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
          f"({out['steps']} steps, {out['wall_s']:.0f}s, "
          f"{out['steps'] * args.batch * args.seq / out['wall_s']:.0f} "
          f"tok/s)")


if __name__ == "__main__":
    main()
