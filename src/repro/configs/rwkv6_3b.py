"""RWKV-6 "Finch" 3B (attention-free, data-dependent decay). [arXiv:2404.05892]

No KV cache: decode state is O(1) per layer (time-mix shift + per-head wkv
state). The survey's attention-score-based compression is inapplicable
(DESIGN.md §3); L2/diversity pruners still apply pre-backbone.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    num_heads=40,              # d_model / ssm_head_dim
    num_kv_heads=0,            # attention-free
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    activation="relu2",        # RWKV channel-mix uses squared ReLU
    norm="layernorm",
    ssm_state_dim=64,          # wkv state is (heads, 64, 64)
    ssm_head_dim=64,
)

SMOKE_CONFIG = CONFIG.with_(
    name="rwkv6-smoke",
    num_layers=2, d_model=128, num_heads=4, head_dim=32,
    d_ff=256, vocab_size=512, ssm_state_dim=32, ssm_head_dim=32,
    dtype="float32",
)
