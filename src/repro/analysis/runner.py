"""Analysis runner: walk paths, parse, run rules, waive, baseline.

``analyze_source`` is the test-friendly entry (lint a source string as
if it lived at a given repo-relative path); ``run_analysis`` is the CLI
core (walk the default tree, apply the committed baseline, report).
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Sequence

from repro.analysis.findings import (Baseline, Finding, apply_waivers)
from repro.analysis.registry import select_rules

# The trees the architecture rules govern (repo-relative).
DEFAULT_PATHS = ("src", "benchmarks", "examples", "scripts")
DEFAULT_BASELINE = "analysis_baseline.json"

_SKIP_DIRS = {"__pycache__", ".git", ".github", "node_modules"}


def _norm(path: str, root: Optional[str]) -> str:
    """Repo-relative forward-slash path (rule scoping keys off it)."""
    if root:
        try:
            path = os.path.relpath(path, root)
        except ValueError:
            pass
    return path.replace(os.sep, "/")


def analyze_source(src: str, path: str,
                   rules=None) -> List[Finding]:
    """Lint one source string as if it lived at repo-relative ``path``.
    Waiver comments in ``src`` are honored; the baseline is NOT applied
    (that is a run-level concern)."""
    selected = select_rules(rules)
    try:
        tree = ast.parse(src)
    except SyntaxError as exc:
        return [Finding(path=path, line=exc.lineno or 1, rule="E000",
                        severity="error",
                        message=f"syntax error: {exc.msg}")]
    findings: List[Finding] = []
    for rule in selected.values():
        if rule.applies(path):
            findings.extend(rule.check(tree, src, path))
    return sorted(apply_waivers(findings, src))


def analyze_file(path: str, rules=None,
                 root: Optional[str] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    return analyze_source(src, _norm(path, root), rules=rules)


def _iter_py(paths: Sequence[str], root: str) -> Iterable[str]:
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            yield ap
            continue
        for dirpath, dirnames, filenames in os.walk(ap):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in _SKIP_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


@dataclasses.dataclass
class AnalysisReport:
    findings: List[Finding]            # non-baselined (the regressions)
    baselined: List[Finding]           # waived by the committed baseline
    files_checked: int

    @property
    def ok(self) -> bool:
        return not self.findings

    def render(self) -> str:
        lines = [f.render() for f in self.findings]
        lines.append(
            f"repro.analysis: {len(self.findings)} finding(s) "
            f"({len(self.baselined)} baselined) across "
            f"{self.files_checked} file(s)")
        return "\n".join(lines)


def run_analysis(paths: Optional[Sequence[str]] = None, rules=None,
                 baseline: Optional[str] = DEFAULT_BASELINE,
                 root: Optional[str] = None) -> AnalysisReport:
    """Run the selected rules over ``paths`` (default: the governed
    trees) relative to ``root`` (default: cwd, or the repo root inferred
    from this file when cwd has no ``src/repro``)."""
    root = root or _infer_root()
    paths = list(paths) if paths else [p for p in DEFAULT_PATHS
                                       if os.path.isdir(
                                           os.path.join(root, p))]
    base = Baseline()
    if baseline:
        bp = baseline if os.path.isabs(baseline) \
            else os.path.join(root, baseline)
        if os.path.exists(bp):
            base = Baseline.load(bp)
    all_findings: List[Finding] = []
    n_files = 0
    for fp in _iter_py(paths, root):
        n_files += 1
        all_findings.extend(analyze_file(fp, rules=rules, root=root))
    fresh = base.filter(all_findings)
    waived = [f for f in all_findings if f not in fresh]
    return AnalysisReport(findings=sorted(fresh), baselined=waived,
                          files_checked=n_files)


def _infer_root() -> str:
    cwd = os.getcwd()
    if os.path.isdir(os.path.join(cwd, "src", "repro")):
        return cwd
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))
