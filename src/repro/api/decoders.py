"""Decoder strategies: the four survey dim-4 decode paths behind one hook.

Each strategy implements the engine decoder protocol (duck-typed; see
``SamplingEngineDecoder`` in core/serving/engine.py for the contract):

    engine_decode(engine, reqs) -> {slot: [emitted tokens]}
    validate(engine)            -- optional, run when the strategy is first
                                   resolved for a request (or at Engine
                                   construction for the default)
    stats()                     -- strategy-specific counters for reports
    lookahead_tokens            -- optional int attr: extra KV positions a
                                   slot of this strategy may write past the
                                   committed stream (speculative: gamma)

All four strategies are now first-class BATCHED slot strategies: the engine
groups decode-phase slots by each request's resolved strategy
(``Request.decoder`` or the engine default) every iteration and hands each
decoder its whole group, so one Engine serves greedy, sampling,
speculative, and early-exit requests concurrently.

``greedy`` / ``sampling`` reuse the engine's fixed-shape jitted decode
step. ``speculative`` keeps per-slot draft KV caches in a SECOND slot pool
and runs one round per iteration over ALL its slots at once: a fixed-shape
2-token lead ``extend`` plus per-step batched draft ``decode_step``s
propose gamma tokens per slot, then ONE ``model.extend`` with per-row
starts block-verifies every slot's draft against the engine pool
(``batched_draft_block`` in core/decoding/speculative.py). ``early_exit``
slices each of its slots to a batch-1 cache for the host-side
unstacked-layer loop (skipped layers are truly never executed) -- the exit
decision stays per-request, uncontaminated by other slots. All strategies
share their round primitives with the standalone drivers in
``repro.core.decoding``, so engine-integrated and library-level decoding
follow the same math (and, at temperature 0, the same tokens).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decoding.early_exit import early_exit_decode_step
from repro.core.decoding.sampling import sample_token
from repro.core.decoding.speculative import (
    SpecStats, accept_block, acceptance_rate, batched_draft_block,
    lantern_neighbourhood_from_params)
from repro.core.serving.engine import (
    SamplingEngineDecoder, _slot_get, _slot_set)


class GreedyDecoder(SamplingEngineDecoder):
    """Argmax decoding (temperature forced to 0, any batch size)."""
    name = "greedy"

    def __init__(self):
        super().__init__(greedy=True)


class SamplingDecoder(SamplingEngineDecoder):
    """Temperature / top-k / top-p sampling from EngineConfig (any batch)."""
    name = "sampling"

    def __init__(self):
        super().__init__(greedy=False)


class EarlyExitDecoder:
    """AdaInfer-style adaptive-depth decoding inside the engine (dim 4b).

    Mixed-batch capable: each request's slot cache is sliced out to a
    batch-1 view for the host-side unstacked-layer loop (a real ``break``
    -- skipped layers never execute) and written back, so the per-request
    exit decision is never poisoned by other slots' logit-lens confidence
    and early-exit requests coexist with any other strategy in one engine.
    """
    name = "early_exit"

    def __init__(self, threshold: float = 0.9, patience: int = 2,
                 min_layers: int = 2):
        self.threshold = threshold
        self.patience = patience
        self.min_layers = min_layers
        self.layers_used: List[int] = []
        self.exits = 0

    def validate(self, eng) -> None:
        if eng.compacting:
            raise ValueError("early_exit is incompatible with live KV "
                             "compaction (needs the non-windowed cache)")
        if eng.cfg.family not in ("dense", "vlm", "moe") or eng.cfg.use_mla:
            raise ValueError("early_exit targets non-MLA attention families")

    def stats(self) -> Dict:
        n = max(len(self.layers_used), 1)
        return {"layers_used": list(self.layers_used),
                "layers_used_mean": sum(self.layers_used) / n,
                "exit_rate": self.exits / n}

    def engine_decode(self, eng, reqs) -> Dict[int, List[int]]:
        emitted: Dict[int, List[int]] = {}
        cost = 0.0
        for r in reqs:
            s = r._slot
            ctx = float(eng.slot_pos[s])
            toks = jnp.asarray([[int(eng.slot_last_tok[s])]], jnp.int32)
            one = _slot_get(eng.pool, s)
            logits, one, info = early_exit_decode_step(
                eng.model, eng.params, one, toks,
                int(eng.slot_pos[s]), threshold=self.threshold,
                patience=self.patience, min_layers=self.min_layers)
            eng.pool = _slot_set(eng.pool, s, one)
            self.layers_used.append(int(info["layers_used"]))
            self.exits += int(info["exited"])
            # virtual clock sees the FLOPs actually spent: a decode step
            # scaled by the fraction of layers executed
            cost += (eng.ec.cost.decode_step_time(1, ctx)
                     * info["flops_frac"])
            eng.key, k1 = jax.random.split(eng.key)
            tok = int(sample_token(k1, logits,
                                   temperature=eng.ec.temperature,
                                   top_k=eng.ec.top_k,
                                   top_p=eng.ec.top_p)[0])
            eng.slot_last_tok[s] = tok
            eng.slot_pos[s] += 1
            emitted[s] = [tok]
        eng._iter_decode_cost = cost
        return emitted


class SpeculativeDecoder:
    """Draft-then-verify decoding inside the engine (dim 4a), BATCHED.

    A first-class slot strategy: per engine iteration, ONE round over all
    speculative slots at once. Per-slot draft KV caches live in a second
    slot pool mirroring the engine's (text-only positions -- Gagrani-style
    language-only drafting: the draft never sees the visual embeddings).
    Each round runs fixed-shape jitted calls over the WHOLE draft pool
    (a 2-token lead ``extend`` with per-row starts, then one batched
    ``decode_step`` per draft token -- ``batched_draft_block``), then ONE
    ``model.extend`` with per-row starts over the engine pool scores every
    slot's ``[last_tok | draft block]`` in a single compute-dense pass.
    Leviathan/Chen acceptance (optionally LANTERN-relaxed) runs per slot on
    the row-sliced logits and emits 1..gamma+1 tokens per request.

    Acceptance math and the proposal distribution are shared with the
    standalone ``speculative_generate`` driver, so engine-batched and
    library-level speculative emit bit-identical tokens at temperature 0.
    ``draft=None`` self-drafts with the target (acceptance upper bound).

    The virtual clock charges the group its true amortized cost: one
    (1+gamma)-tokens-per-slot block verify (prefill-shaped) plus gamma
    draft steps whose decode cost is PER CALL, not per slot -- the
    batching win the survey's serving sections call out.
    """
    name = "speculative"

    def __init__(self, draft=None, d_params=None, *, gamma: int = 4,
                 lantern_k: int = 0, lantern_delta: float = 0.2):
        if (draft is None) != (d_params is None):
            raise ValueError("pass draft model AND params, or neither")
        self.draft_model = draft
        self.d_params = d_params
        self.gamma = gamma
        self.lantern_k = lantern_k
        self.lantern_delta = lantern_delta
        self.stats_ = SpecStats()
        self.group_sizes: List[int] = []    # slots per jitted round
        self._slot_req: Dict[int, object] = {}   # slot -> bound Request
        self._d_pool = None
        self._bound = False

    @property
    def lookahead_tokens(self) -> int:
        """KV slack per slot: verify writes up to gamma positions past the
        committed stream (the engine reserves it at submit)."""
        return self.gamma

    def validate(self, eng) -> None:
        if eng.compacting:
            raise ValueError("speculative verify (extend) is incompatible "
                             "with live KV compaction")
        if eng.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("speculative needs extend(); attention "
                             "families only")

    def bound_slots(self) -> set:
        """Draft-pool slots currently bound to a live request (abort and
        retire release them via ``release_slot``)."""
        return set(self._slot_req)

    def release_slot(self, slot: int) -> None:
        """Engine lifecycle hook: drop the draft-pool binding for a slot
        whose request retired or was aborted. The next request on this
        slot re-prefills its draft row (stale tail entries stay hidden by
        causal masking until overwritten)."""
        self._slot_req.pop(slot, None)

    def stats(self) -> Dict:
        st = self.stats_
        return {"acceptance": acceptance_rate(st),
                "proposed": st.proposed, "accepted": st.accepted,
                "bonus": st.bonus, "target_calls": st.target_calls,
                "draft_calls": st.draft_calls,
                "mean_accepted_per_call": st.mean_accepted_per_call(),
                "spec_rounds": len(self.group_sizes),
                "max_slots_per_round": max(self.group_sizes, default=0)}

    def _bind(self, eng) -> None:
        if self._bound:
            if eng is not self._engine:
                # the draft pool is shaped/paramed for ONE engine; silent
                # reuse would index a wrong-sized pool or draft with stale
                # weights -- make the one-engine assumption explicit
                raise ValueError("SpeculativeDecoder instances are "
                                 "engine-specific once bound; build one "
                                 "per Engine")
            return
        self._engine = eng
        draft = self.draft_model if self.draft_model is not None \
            else eng.model
        self._dp = self.d_params if self.draft_model is not None \
            else eng.params
        # draft positions run text-only; headroom for the deepest round,
        # last position reserved as the inactive-row scratch
        self._d_cache_len = eng.ec.cache_len + self.gamma + 8
        self._d_pool = draft.init_cache(eng.ec.max_batch, self._d_cache_len)
        self._d_prefill = jax.jit(
            lambda p, b: draft.prefill(p, b, cache_len=self._d_cache_len))
        self._d_extend = jax.jit(draft.extend)
        self._d_decode = jax.jit(draft.decode_step)
        self._nbhd = None
        if self.lantern_k > 1:
            self._nbhd = lantern_neighbourhood_from_params(
                eng.params, self.lantern_k)
        # cost-model scale for the draft's forward passes (virtual clock)
        try:
            self._draft_cost_ratio = (draft.cfg.active_param_count()
                                      / max(1, eng.model.cfg
                                            .active_param_count()))
        except Exception:
            self._draft_cost_ratio = 1.0
        self._bound = True

    def engine_decode(self, eng, reqs) -> Dict[int, List[int]]:
        self._bind(eng)
        ec = eng.ec
        B = ec.max_batch
        # (re)prefill draft rows for slots newly bound to a request
        # (slot reuse overwrites the row; stale tail entries are hidden by
        # causal masking until overwritten, same as the engine pool)
        for r in reqs:
            s = r._slot
            if self._slot_req.get(s) is not r:
                prompt = jnp.asarray(r.tokens, jnp.int32)[None]
                _, one = self._d_prefill(self._dp, {"tokens": prompt})
                self._d_pool = _slot_set(self._d_pool, s, one)
                self.stats_.draft_calls += 1
                self._slot_req[s] = r

        # group gamma: submit-time lookahead reservation keeps every slot's
        # verify writes clear of the scratch position, so this min() is a
        # belt-and-braces clamp that normally equals self.gamma
        g = self.gamma
        for r in reqs:
            g = min(g, ec.cache_len - 2 - int(eng.slot_pos[r._slot]))
        g = max(0, g)

        # --- batched draft: 2-token lead + (g-1) decode steps ------------
        d_scr = self._d_cache_len - 1
        lead2 = np.zeros((B, 2), np.int32)
        starts = np.full(B, d_scr - 1, np.int64)
        pos0 = np.full(B, d_scr, np.int64)
        for r in reqs:
            s = r._slot
            t_len = int(eng.slot_pos[s]) - int(eng.slot_nv[s])
            committed = list(r.tokens) + list(r.generated)   # text stream
            lead2[s] = committed[t_len - 1:t_len + 1]
            starts[s] = t_len - 1
            pos0[s] = t_len
        eng.key, k_draft = jax.random.split(eng.key)
        draft_toks, draft_ps, self._d_pool, _ = batched_draft_block(
            self._d_extend, self._d_decode, self._dp, self._d_pool,
            lead2, starts, pos0, gamma=g, temperature=ec.temperature,
            key=k_draft, scratch_pos=d_scr, stats=self.stats_,
            n_slots=len(reqs))

        # --- batched verify: ONE extend, per-row starts ------------------
        blk = np.zeros((B, 1 + g), np.int32)
        vstarts = np.full(B, ec.cache_len - 1, np.int64)
        for r in reqs:
            s = r._slot
            blk[s, 0] = eng.slot_last_tok[s]
            blk[s, 1:] = draft_toks[s]
            vstarts[s] = eng.slot_pos[s]
        t_logits, eng.pool = eng._jit_extend(
            eng.params, eng.pool, jnp.asarray(blk), jnp.asarray(vstarts))
        self.stats_.target_calls += len(reqs)
        self.stats_.proposed += g * len(reqs)
        self.group_sizes.append(len(reqs))

        # --- per-slot acceptance on row-sliced logits --------------------
        eng.key, k_acc = jax.random.split(eng.key)
        keys = jax.random.split(k_acc, max(len(reqs), 1))
        emitted_map: Dict[int, List[int]] = {}
        for r, k_r in zip(reqs, keys):
            s = r._slot
            emitted, n_acc, bonus, _ = accept_block(
                k_r, t_logits[s:s + 1],
                [int(t) for t in draft_toks[s, :g]],
                [draft_ps[j][s] for j in range(g)],
                temperature=ec.temperature,
                limit=r.max_new_tokens - len(r.generated),
                nbhd=self._nbhd, lantern_delta=self.lantern_delta)
            self.stats_.accepted += n_acc
            self.stats_.bonus += int(bonus)
            eng.slot_pos[s] += 1 + n_acc         # tok + accepted drafts
            eng.slot_last_tok[s] = emitted[-1]
            emitted_map[s] = emitted
            # NOTE: a whole-block accept leaves the last accepted draft
            # unwritten in the draft cache; the next round's fixed 2-token
            # lead rewrites [c_{t-1}, c_t] and thereby replays it.

        # virtual clock: one (1+g)-token-per-slot compute-dense block
        # verify, plus the draft's lead extend and g-1 decode steps --
        # decode steps are charged PER CALL (batched over the group), which
        # is exactly the amortization batched speculative buys
        n = len(reqs)
        ctx = float(np.mean([eng.slot_pos[r._slot] for r in reqs])) \
            if reqs else 0.0
        eng._iter_decode_cost = (
            ec.cost.prefill_time((1 + g) * n)
            + self._draft_cost_ratio
            * (ec.cost.prefill_time(2 * n)
               + max(0, g - 1) * ec.cost.decode_step_time(n, ctx)))
        return emitted_map


DECODERS = {
    "greedy": GreedyDecoder,
    "sampling": SamplingDecoder,
    "speculative": SpeculativeDecoder,
    "early_exit": EarlyExitDecoder,
}


def make_decoder(name: str, gen=None, *, draft=None, d_params=None):
    """Build a decoder strategy, optionally parameterized by a
    ``GenerationConfig`` and (for speculative) a draft model."""
    if name not in DECODERS:
        raise ValueError(f"unknown decoder {name!r}; known: "
                         f"{sorted(DECODERS)}")
    if name == "early_exit":
        if gen is None:
            return EarlyExitDecoder()
        return EarlyExitDecoder(threshold=gen.exit_threshold,
                                patience=gen.exit_patience,
                                min_layers=gen.exit_min_layers)
    if name == "speculative":
        if gen is None:
            return SpeculativeDecoder(draft, d_params)
        return SpeculativeDecoder(draft, d_params, gamma=gen.gamma,
                                  lantern_k=gen.lantern_k,
                                  lantern_delta=gen.lantern_delta)
    return DECODERS[name]()
