"""Hot-path site report over a ``Profiler.write_json`` document.

    prof = Profiler()
    lvlm.serve_cluster(..., profile=prof)
    ...
    prof.write_json("profile.json")
    PYTHONPATH=src python scripts/profile_report.py profile.json \
        --collapsed profile.folded

Prints a per-site table -- call count, wall total/self seconds, self
share, modeled virtual seconds -- sorted by self wall time (where an
optimization pays off first), and optionally writes the collapsed-stack
lines (``outer;inner <usec>``) any flamegraph renderer consumes
(flamegraph.pl, speedscope, inferno).
"""
from __future__ import annotations

import argparse
import json
import sys


def load_profile(path):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if "sites" not in doc:
        raise ValueError(f"{path}: not a profile document (no 'sites')")
    return doc


def report(doc, out=sys.stdout) -> int:
    sites = doc.get("sites", {})
    if not sites:
        print("no profiled sites in the document", file=out)
        return 1
    total_self = sum(s["wall_self_s"] for s in sites.values()) or 1.0
    print(f"profile_report: {len(sites)} site(s), "
          f"{sum(s['count'] for s in sites.values())} calls, "
          f"{total_self:.6f}s self wall", file=out)
    print(f"{'site':>22} {'count':>7} {'wall_total_s':>13} "
          f"{'wall_self_s':>12} {'self%':>7} {'virtual_s':>10}", file=out)
    order = sorted(sites.items(),
                   key=lambda kv: kv[1]["wall_self_s"], reverse=True)
    for name, s in order:
        print(f"{name:>22} {s['count']:>7} {s['wall_total_s']:>13.6f} "
              f"{s['wall_self_s']:>12.6f} "
              f"{s['wall_self_s'] / total_self:>6.1%} "
              f"{s['virtual_s']:>10.6f}", file=out)
    return 0


def write_collapsed(doc, path) -> int:
    """Collapsed-stack lines from the document's ``collapsed`` map
    (path -> self seconds), in integer microseconds."""
    collapsed = doc.get("collapsed", {})
    n = 0
    with open(path, "w", encoding="utf-8") as f:
        for stack, secs in sorted(collapsed.items()):
            f.write(f"{stack} {max(1, int(round(secs * 1e6)))}\n")
            n += 1
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("profile", help="JSON written by Profiler.write_json")
    ap.add_argument("--collapsed", metavar="PATH",
                    help="also write flamegraph-compatible collapsed "
                         "stacks to PATH")
    args = ap.parse_args(argv)
    doc = load_profile(args.profile)
    rc = report(doc)
    if args.collapsed:
        n = write_collapsed(doc, args.collapsed)
        print(f"wrote {n} collapsed stack(s) to {args.collapsed}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
