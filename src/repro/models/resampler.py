"""Perceiver resampler (survey dim 3a: cross-modal projector/resampler).

Flamingo's design: a small set of learned latent queries cross-attends to
the (variable-length) visual patch stream, emitting a FIXED number of
visual tokens regardless of input resolution -- the architectural
alternative to post-hoc token pruning (dim 1). NVILA's "compress late"
strategy is this applied after full-detail encoding.

Selectable on VLM configs via ``projector="perceiver"`` (default "mlp").
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, spec


def resampler_specs(cfg, num_latents: int = 64,
                    num_heads: int = 8) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    hd = d // num_heads
    return {
        "latents": spec((num_latents, d), (None, "embed"), scale=0.02),
        "wq": spec((d, num_heads, hd), ("embed", "heads", None)),
        "wk": spec((d, num_heads, hd), ("embed", "heads", None)),
        "wv": spec((d, num_heads, hd), ("embed", "heads", None)),
        "wo": spec((num_heads, hd, d), ("heads", None, "embed")),
        "ln_q": spec((d,), ("embed",), init="ones"),
        "ln_kv": spec((d,), ("embed",), init="ones"),
        "mlp_wi": spec((d, 4 * d), ("embed", "ffn")),
        "mlp_wo": spec((4 * d, d), ("ffn", "embed")),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def apply_resampler(p, patches) -> jax.Array:
    """patches [B, N, d] (any N) -> [B, num_latents, d].

    One cross-attention block (latents query the patches) + MLP, residual
    around both -- Flamingo uses a stack of these; one layer suffices for
    the fixed-budget compression semantics.
    """
    b, n, d = patches.shape
    lat = jnp.broadcast_to(p["latents"][None], (b,) + p["latents"].shape
                           ).astype(patches.dtype)
    q_in = _rms(lat, p["ln_q"])
    kv_in = _rms(patches, p["ln_kv"])
    nh, hd = p["wq"].shape[1], p["wq"].shape[2]
    q = jnp.einsum("bld,dhe->blhe", q_in, p["wq"],
                   preferred_element_type=jnp.float32)
    k = jnp.einsum("bnd,dhe->bnhe", kv_in, p["wk"],
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("bnd,dhe->bnhe", kv_in, p["wv"],
                   preferred_element_type=jnp.float32)
    s = jnp.einsum("blhe,bnhe->bhln", q, k) / (hd ** 0.5)
    a = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhln,bnhe->blhe", a, v)
    lat = lat + jnp.einsum("blhe,hed->bld", o, p["wo"],
                           preferred_element_type=jnp.float32
                           ).astype(patches.dtype)
    h = _rms(lat, p["ln_q"])
    h = jnp.einsum("bld,df->blf", h, p["mlp_wi"],
                   preferred_element_type=jnp.float32)
    h = jax.nn.gelu(h).astype(patches.dtype)
    lat = lat + jnp.einsum("blf,fd->bld", h, p["mlp_wo"],
                           preferred_element_type=jnp.float32
                           ).astype(patches.dtype)
    return lat
