"""Property-based engine invariants under mixed-strategy workloads.

Random request mixes (arrival times, prompt lengths, per-request decoders,
eos placement, scheduler, temperature) must never:

  * overflow the slot cache (active writes stay <= cache_len-1, with the
    speculative lookahead margin respected),
  * double-free / double-assign a slot,
  * strand a request (every submit retires exactly once, with monotone
    arrival <= first_token_time <= finish_time),
  * append tokens past an emitted eos.

Runs under the real jitted smoke model via ``tests/_hypothesis_compat``:
the real ``hypothesis`` library when installed, its seeded random-draw
shim otherwise (CI exercises both).
"""
import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.serving import Engine, EngineConfig, Request
from repro.models import build

MAX_BATCH = 3
CACHE_LEN = 48
GAMMA = 2
VOCAB = 32          # tiny vocab so random eos ids actually fire
_MODEL = {}


def small_model():
    if not _MODEL:
        cfg = get_config("phi4-mini-3.8b", smoke=True).with_(
            vocab_size=VOCAB)
        model = build(cfg)
        _MODEL["m"] = (model, model.init(jax.random.PRNGKey(0)))
    return _MODEL["m"]


REQ = st.tuples(
    st.sampled_from((3, 5, 8)),                          # prompt length
    st.integers(1, 5),                                   # max_new_tokens
    st.floats(0.0, 0.02),                                # arrival
    st.sampled_from((None, "greedy", "sampling",
                     "speculative", "early_exit")),      # per-request decoder
)


def _run_and_check(reqspecs, scheduler, temperature, eos_id, seed):
    model, params = small_model()
    eng = Engine(model, params, EngineConfig(
        max_batch=MAX_BATCH, cache_len=CACHE_LEN, scheduler=scheduler,
        chunk_size=4, token_budget=16, temperature=temperature,
        eos_id=eos_id, seed=seed, decoder="greedy"))
    # parameterize the lazily-resolved speculative strategy via registry
    from repro.api.decoders import SpeculativeDecoder
    eng._decoders["speculative"] = SpeculativeDecoder(gamma=GAMMA)
    rng = np.random.RandomState(seed)
    reqs = []
    for i, (plen, new, arrival, dec) in enumerate(reqspecs):
        reqs.append(Request(
            rid=i, tokens=list(rng.randint(1, VOCAB, size=plen)),
            max_new_tokens=new, arrival=arrival, decoder=dec))
        eng.submit(reqs[-1])
    steps = 0
    while True:
        alive = eng.step()
        steps += 1
        assert steps < 2000, "engine failed to drain"
        # -- slot-assignment invariants (checked EVERY iteration) ----------
        active_slots = [r._slot for r in eng.running]
        assert len(active_slots) == len(set(active_slots)), \
            "two running requests share a slot"
        for r in eng.running:
            s = r._slot
            assert eng.slot_req[s] is r, "slot map out of sync"
            # cache-overflow invariant: the next write (plus speculative
            # lookahead) stays clear of the end; position cache_len-1 is
            # the reserved inactive-slot scratch
            assert int(eng.slot_pos[s]) + r.lookahead <= CACHE_LEN - 1, \
                (r.rid, int(eng.slot_pos[s]), r.lookahead)
        if not alive:
            break
    # -- retirement invariants ---------------------------------------------
    assert len(eng.finished) == len(reqs), "request stranded or duplicated"
    rids = [r.rid for r in eng.finished]
    assert sorted(rids) == sorted(r.rid for r in reqs)
    assert len(set(rids)) == len(rids), "double-retire (slot double-free)"
    assert all(sr is None for sr in eng.slot_req), "slot leaked"
    for r in eng.finished:
        assert 1 <= len(r.generated) <= r.max_new_tokens
        assert r.first_token_time is not None
        assert r.finish_time is not None
        assert r.arrival <= r.first_token_time <= r.finish_time, \
            (r.rid, r.arrival, r.first_token_time, r.finish_time)
        if eos_id >= 0:
            # nothing may be appended past an emitted eos
            assert eos_id not in r.generated[:-1], (r.rid, r.generated)


@settings(max_examples=5, deadline=None, derandomize=True)
@given(reqspecs=st.lists(REQ, min_size=1, max_size=4),
       scheduler=st.sampled_from(("continuous", "chunked", "mlfq",
                                  "static")),
       temperature=st.sampled_from((0.0, 0.7)),
       eos_id=st.sampled_from((-1, 5)),
       seed=st.integers(0, 10_000))
def test_engine_invariants_random_mixes(reqspecs, scheduler, temperature,
                                        eos_id, seed):
    _run_and_check(reqspecs, scheduler, temperature, eos_id, seed)


def test_engine_invariants_all_speculative_eos():
    """Deterministic corner: an all-speculative batch with an eos id that
    fires inside accepted blocks still satisfies every invariant."""
    _run_and_check([(5, 5, 0.0, "speculative"),
                    (8, 4, 0.0, "speculative"),
                    (3, 5, 0.001, "speculative")],
                   "continuous", 0.0, 5, 3)


def test_submit_rejects_overflowing_lookahead():
    model, params = small_model()
    eng = Engine(model, params, EngineConfig(max_batch=1,
                                             cache_len=CACHE_LEN,
                                             decoder="greedy"))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, tokens=list(range(1, CACHE_LEN - 8)),
                           max_new_tokens=8, decoder="speculative"))
