"""Unified generation config for the ``repro.api`` facade.

``GenerationConfig`` absorbs the knobs that used to be scattered across
``EngineConfig`` (temperature / eos / max_new_tokens), the
``speculative_generate`` signature (gamma / LANTERN), and
``early_exit_decode_step`` (threshold / patience / min_layers), plus NAMED
compression presets so an EffiVLM-BENCH-style sweep is a one-line loop:

    for preset in ("none", "fastv-0.5", "divprune-0.5", "streaming-kv"):
        lvlm.generate(prompts, GenerationConfig(compression=preset))
"""
from __future__ import annotations

import dataclasses
from typing import Union

from repro.configs.base import CompressionConfig
from repro.core.token_compression import PRUNERS
from repro.core.token_compression.policy import LIVE_KV_SELECTORS

DECODER_NAMES = ("greedy", "sampling", "speculative", "early_exit")

# mergers accepted by CompressionConfig.token_merger (policy.py dispatch)
_MERGERS = ("tome", "framefusion")

#: Named compression presets (taxonomy dims 1 and 2a). Parametric names of
#: the form "<pruner|merger>-<keep_ratio>" (e.g. "fastv-0.25") also resolve.
COMPRESSION_PRESETS = {
    "none": CompressionConfig(),
    # dim 1: visual token pruning / merging before prefill
    "fastv-0.5": CompressionConfig(token_pruner="fastv", keep_ratio=0.5),
    "divprune-0.5": CompressionConfig(token_pruner="divprune",
                                      keep_ratio=0.5),
    "cdpruner-0.5": CompressionConfig(token_pruner="cdpruner",
                                      keep_ratio=0.5),
    "tome-0.5": CompressionConfig(token_merger="tome", keep_ratio=0.5),
    "framefusion-0.25": CompressionConfig(token_merger="framefusion",
                                          keep_ratio=0.25),
    # dim 2a: live KV-cache compaction in the engine (attention-free
    # selectors; attention-score selectors stay library-level)
    "streaming-kv": CompressionConfig(kv_selector="streaming", kv_budget=64),
    "l2-kv": CompressionConfig(kv_selector="l2", kv_budget=64),
}


def resolve_compression(
        spec: Union[str, CompressionConfig, None]) -> CompressionConfig:
    """Resolve a preset name / parametric name / explicit config.

    Parametric grammars beyond the preset table:
      "<pruner|merger>-<keep>"      e.g. "fastv-0.25", "tome-0.75"
      "<selector>-kv-<budget>"      e.g. "streaming-kv-128", "l2-kv-256"
    """
    if spec is None:
        return CompressionConfig()
    if isinstance(spec, CompressionConfig):
        return spec
    if spec in COMPRESSION_PRESETS:
        return COMPRESSION_PRESETS[spec]
    head, sep, tail = spec.rpartition("-")
    if sep:
        for sel in LIVE_KV_SELECTORS:
            if head == f"{sel}-kv" and tail.isdigit() and int(tail) > 0:
                return CompressionConfig(kv_selector=sel,
                                         kv_budget=int(tail))
        try:
            keep = float(tail)
        except ValueError:
            keep = None
        if keep is not None and 0.0 < keep <= 1.0:
            if head in PRUNERS:
                return CompressionConfig(token_pruner=head, keep_ratio=keep)
            if head in _MERGERS:
                return CompressionConfig(token_merger=head, keep_ratio=keep)
    known = (sorted(COMPRESSION_PRESETS)
             + [f"<{p}>-<keep>"
                for p in sorted(list(PRUNERS) + list(_MERGERS))]
             + [f"<{s}>-kv-<budget>" for s in LIVE_KV_SELECTORS])
    raise ValueError(f"unknown compression preset {spec!r}; known: {known}")


@dataclasses.dataclass
class GenerationConfig:
    """Everything ``LVLM.generate`` needs beyond the prompts themselves.

    ``decoder`` sets the DEFAULT strategy; individual requests passed to
    ``LVLM.serve`` may override it per-request via ``Request.decoder``
    (the engine groups decode slots by strategy each iteration, so one run
    mixes all four). Every strategy is batched -- speculative runs all its
    slots per jitted draft/verify round and reserves ``gamma`` extra KV
    positions per slot (draft-slot lookahead) on top of
    ``prompt + max_new_tokens``.
    """
    max_new_tokens: int = 32
    decoder: str = "greedy"          # greedy | sampling | speculative | early_exit
    # sampling warp (ignored by the greedy decoder)
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 0.0
    eos_id: int = -1                 # -1 = never stop on eos
    seed: int = 0
    # taxonomy dims 1 / 2a: preset name, parametric name, or explicit config
    compression: Union[str, CompressionConfig] = "none"
    # speculative decoding (dim 4a); the draft model itself is passed to
    # generate(..., draft=...) -- None means self-draft (acceptance upper
    # bound; useful for exactness checks and wiring tests)
    gamma: int = 4
    lantern_k: int = 0               # >1 enables LANTERN relaxed acceptance
    lantern_delta: float = 0.2
    # early exit (dim 4b)
    exit_threshold: float = 0.9
    exit_patience: int = 2
    exit_min_layers: int = 2

    def __post_init__(self):
        if self.decoder not in DECODER_NAMES:
            raise ValueError(f"unknown decoder {self.decoder!r}; "
                             f"known: {DECODER_NAMES}")

    @property
    def effective_temperature(self) -> float:
        """Temperature the DEFAULT strategy samples at (greedy pins 0).

        The engine now receives the raw ``temperature`` -- greedy groups
        force 0 themselves so per-request overrides keep sampling -- but
        this remains the right number to report/log for a uniform run.
        """
        return 0.0 if self.decoder == "greedy" else self.temperature

    def resolved_compression(self) -> CompressionConfig:
        return resolve_compression(self.compression)

    def with_(self, **kw) -> "GenerationConfig":
        return dataclasses.replace(self, **kw)
