"""Video token compression walkthrough (survey dim 1-2): a synthetic
"video" with static background + moving object, compressed by each
strategy, reporting token counts and reconstruction quality.

The generic reductions run through the FACADE compression API
(``repro.api.compressors.make_compressor`` -- the same strategy objects
``Request.compression`` selects per request in the serving engine), so
what this example prints is exactly what a served request experiences;
the video-specific schedulers (temporal merge, DyCoke, Dynamic-VLM)
remain library-level.

    PYTHONPATH=src python examples/compress_video.py
"""
import jax.numpy as jnp
import numpy as np

from repro.api.compressors import make_compressor
from repro.api import video as V


def synthetic_video(frames=16, patches=64, d=32, seed=0):
    """Static background (identical across frames) + small moving blob."""
    rng = np.random.RandomState(seed)
    bg = rng.randn(patches, d) * 0.3
    vid = np.tile(bg, (frames, 1, 1))
    blob = rng.randn(d) * 2.0
    for f in range(frames):
        p = (f * 3) % patches
        vid[f, p] = blob + 0.1 * rng.randn(d)
    return jnp.asarray(vid[None], jnp.float32)


def main():
    vid = synthetic_video()
    b, f, p, d = vid.shape
    total = f * p
    print(f"video: {f} frames x {p} patches = {total} tokens")

    sims = V.frame_similarity(vid)
    print(f"adjacent-frame similarity: mean={float(sims.mean()):.3f} "
          f"(temporal redundancy)")

    merged, info = V.temporal_merge(vid, num_segments=4)
    print(f"Chat-UniVi temporal merge : {total} -> "
          f"{merged.shape[1] * merged.shape[2]} tokens")

    two, info = V.llama_vid_compress(vid)
    print(f"LLaMA-VID 2-token/frame   : {total} -> {two.shape[1]} tokens")

    ratios = V.dycoke_ratio(vid)
    print(f"DyCoke per-frame ratios   : min={float(ratios.min()):.2f} "
          f"max={float(ratios.max()):.2f} "
          f"(moving-object frames get more budget)")

    comp, info = V.dynamic_compress(vid, token_budget=96)
    print(f"Dynamic-VLM budget=96     : {total} -> {comp.shape[1]} tokens")

    # generic strategies via the facade: the SAME objects a serving
    # request selects with Request.compression="framefusion-0.0625" etc.;
    # compressed_token_count is the shape-only count the engine's KV
    # accounting (admission watermarks, least_kv routing) reserves
    flat = vid.reshape(1, total, d)
    for preset in ("framefusion-0.0625", "fastv-0.25", "tome-0.5"):
        strat = make_compressor(preset)
        out, _idx, _info = strat.compress_prefill(flat)
        accounted = strat.compressed_token_count(total)
        assert out.shape[1] == accounted, (preset, out.shape, accounted)
        print(f"{preset:26s}: {total} -> {out.shape[1]} tokens "
              f"(KV accounting reserves {accounted})")

    # the blob (the only moving content) must survive dynamic compression
    blob_tok = vid[0, 0, 0]
    sims_to_blob = jnp.einsum("d,btd->bt", blob_tok / jnp.linalg.norm(
        blob_tok), comp / jnp.linalg.norm(comp, axis=-1, keepdims=True))
    print(f"moving-object preserved   : max cos sim "
          f"{float(sims_to_blob.max()):.3f}")


if __name__ == "__main__":
    main()
