"""Mistral-Large-Instruct-2407 (123B dense). [hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    activation="swiglu",
    rope_theta=1.0e6,
    # long_500k runs via the sliding-window variant (see DESIGN.md §4).
    sliding_window=16384,
)

SMOKE_CONFIG = CONFIG.with_(
    name="mistral-large-smoke",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512, sliding_window=64, dtype="float32",
)
