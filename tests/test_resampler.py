"""Perceiver resampler (survey dim 3a): fixed-budget visual projection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.models.layers import init_params
from repro.models.resampler import apply_resampler, resampler_specs


def test_resampler_fixed_output_any_input_length():
    cfg = get_config("qwen2-vl-2b", smoke=True)
    specs = resampler_specs(cfg, num_latents=8)
    params = init_params(specs, jax.random.PRNGKey(0), "float32")
    for n in (4, 16, 57):
        patches = jax.random.normal(jax.random.PRNGKey(n), (2, n,
                                                            cfg.d_model))
        out = apply_resampler(params, patches)
        assert out.shape == (2, 8, cfg.d_model)
        assert np.isfinite(np.asarray(out)).all()


def test_vlm_with_perceiver_projector_end_to_end():
    cfg = get_config("qwen2-vl-2b", smoke=True).with_(
        projector="perceiver", num_latents=8)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                     cfg.vocab_size),
        "visual_embeds": jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.num_visual_tokens, cfg.d_model)),
    }
    logits, _ = jax.jit(model.forward)(params, batch)
    # sequence = num_latents (NOT num_visual_tokens) + text
    assert logits.shape == (b, 8 + s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # the fixed budget is the whole point: 16 patches -> 8 latents
    assert 8 < cfg.num_visual_tokens


def test_resampler_attends_to_content():
    """Latent outputs must change when the patches change (not a no-op)."""
    cfg = get_config("qwen2-vl-2b", smoke=True)
    specs = resampler_specs(cfg, num_latents=4)
    params = init_params(specs, jax.random.PRNGKey(0), "float32")
    p1 = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    p2 = jax.random.normal(jax.random.PRNGKey(2), (1, 16, cfg.d_model))
    o1 = apply_resampler(params, p1)
    o2 = apply_resampler(params, p2)
    assert float(jnp.abs(o1 - o2).max()) > 1e-3
