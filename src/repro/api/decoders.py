"""Decoder strategies: the four survey dim-4 decode paths behind one hook.

Each strategy implements the engine decoder protocol (duck-typed; see
``SamplingEngineDecoder`` in core/serving/engine.py for the contract):

    engine_decode(engine, reqs) -> {slot: [emitted tokens]}
    validate(engine)            -- optional, run at Engine construction
    stats()                     -- strategy-specific counters for reports

``greedy`` / ``sampling`` reuse the engine's fixed-shape jitted decode step
and work at any batch size. ``speculative`` and ``early_exit`` are batch-1
introspection paths: speculative replaces the memory-bound decode loop with
draft-then-verify rounds against the slot cache (one ``model.extend`` per
round), early exit runs the host-side unstacked-layer loop so skipped layers
are truly never executed. Both share their round primitives with the
standalone drivers in ``repro.core.decoding``, so engine-integrated and
library-level decoding follow the same math.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.decoding.early_exit import early_exit_decode_step
from repro.core.decoding.sampling import sample_token
from repro.core.decoding.speculative import (
    SpecStats, accept_block, acceptance_rate, draft_block,
    lantern_neighbourhood_from_params)
from repro.core.serving.engine import (
    SamplingEngineDecoder, _slot_get, _slot_set)


class GreedyDecoder(SamplingEngineDecoder):
    """Argmax decoding (temperature forced to 0, any batch size)."""
    name = "greedy"

    def __init__(self):
        super().__init__(greedy=True)


class SamplingDecoder(SamplingEngineDecoder):
    """Temperature / top-k / top-p sampling from EngineConfig (any batch)."""
    name = "sampling"

    def __init__(self):
        super().__init__(greedy=False)


class EarlyExitDecoder:
    """AdaInfer-style adaptive-depth decoding inside the engine (dim 4b).

    Batch-1: the logit-lens confidence of garbage (inactive) slots would
    poison the joint exit decision, so the strategy requires max_batch=1.
    """
    name = "early_exit"

    def __init__(self, threshold: float = 0.9, patience: int = 2,
                 min_layers: int = 2):
        self.threshold = threshold
        self.patience = patience
        self.min_layers = min_layers
        self.layers_used: List[int] = []
        self.exits = 0

    def validate(self, eng) -> None:
        if eng.ec.max_batch != 1:
            raise ValueError("early_exit is a batch-1 introspection path; "
                             "use max_batch=1")
        if eng.compacting:
            raise ValueError("early_exit is incompatible with live KV "
                             "compaction (needs the non-windowed cache)")
        if eng.cfg.family not in ("dense", "vlm", "moe") or eng.cfg.use_mla:
            raise ValueError("early_exit targets non-MLA attention families")

    def stats(self) -> Dict:
        n = max(len(self.layers_used), 1)
        return {"layers_used": list(self.layers_used),
                "layers_used_mean": sum(self.layers_used) / n,
                "exit_rate": self.exits / n}

    def engine_decode(self, eng, reqs) -> Dict[int, List[int]]:
        emitted: Dict[int, List[int]] = {}
        cost = 0.0
        for r in reqs:
            s = r._slot
            ctx = float(eng.slot_pos[s])
            toks = jnp.asarray([[int(eng.slot_last_tok[s])]], jnp.int32)
            logits, eng.pool, info = early_exit_decode_step(
                eng.model, eng.params, eng.pool, toks,
                int(eng.slot_pos[s]), threshold=self.threshold,
                patience=self.patience, min_layers=self.min_layers)
            self.layers_used.append(int(info["layers_used"]))
            self.exits += int(info["exited"])
            # virtual clock sees the FLOPs actually spent: a decode step
            # scaled by the fraction of layers executed
            cost += (eng.ec.cost.decode_step_time(1, ctx)
                     * info["flops_frac"])
            eng.key, k1 = jax.random.split(eng.key)
            tok = int(sample_token(k1, logits,
                                   temperature=eng.ec.temperature,
                                   top_k=eng.ec.top_k,
                                   top_p=eng.ec.top_p)[0])
            eng.slot_last_tok[s] = tok
            eng.slot_pos[s] += 1
            emitted[s] = [tok]
        eng._iter_decode_cost = cost
        return emitted


class SpeculativeDecoder:
    """Draft-then-verify decoding inside the engine (dim 4a, batch-1).

    Per engine iteration, one round: the draft model proposes ``gamma``
    tokens from its own text-only cache (Gagrani-style language-only
    drafting -- the draft never sees the visual embeddings), then ONE
    ``model.extend`` over the request's slot cache scores the whole block
    and Leviathan/Chen acceptance (optionally LANTERN-relaxed) emits
    1..gamma+1 tokens. Round primitives are shared with
    ``speculative_generate``; ``draft=None`` self-drafts with the target.
    """
    name = "speculative"

    def __init__(self, draft=None, d_params=None, *, gamma: int = 4,
                 lantern_k: int = 0, lantern_delta: float = 0.2):
        if (draft is None) != (d_params is None):
            raise ValueError("pass draft model AND params, or neither")
        self.draft_model = draft
        self.d_params = d_params
        self.gamma = gamma
        self.lantern_k = lantern_k
        self.lantern_delta = lantern_delta
        self.stats_ = SpecStats()
        self._slot_state: Dict[int, Dict] = {}   # slot -> {req, d_cache}
        self._bound = False

    def validate(self, eng) -> None:
        if eng.ec.max_batch != 1:
            raise ValueError("speculative is a batch-1 path inside the "
                             "engine; use max_batch=1")
        if eng.compacting:
            raise ValueError("speculative verify (extend) is incompatible "
                             "with live KV compaction")
        if eng.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("speculative needs extend(); attention "
                             "families only")

    def stats(self) -> Dict:
        st = self.stats_
        return {"acceptance": acceptance_rate(st),
                "proposed": st.proposed, "accepted": st.accepted,
                "bonus": st.bonus, "target_calls": st.target_calls,
                "draft_calls": st.draft_calls,
                "mean_accepted_per_call": st.mean_accepted_per_call()}

    def _bind(self, eng) -> None:
        if self._bound:
            return
        draft = self.draft_model if self.draft_model is not None \
            else eng.model
        self._dp = self.d_params if self.draft_model is not None \
            else eng.params
        # draft positions run text-only; headroom for the deepest round
        d_cache_len = eng.ec.cache_len + self.gamma + 8
        self._d_prefill = jax.jit(
            lambda p, b: draft.prefill(p, b, cache_len=d_cache_len))
        self._d_extend = jax.jit(draft.extend)
        self._d_decode = jax.jit(draft.decode_step)
        self._nbhd = None
        if self.lantern_k > 1:
            self._nbhd = lantern_neighbourhood_from_params(
                eng.params, self.lantern_k)
        # cost-model scale for the draft's forward passes (virtual clock)
        try:
            self._draft_cost_ratio = (draft.cfg.active_param_count()
                                      / max(1, eng.model.cfg
                                            .active_param_count()))
        except Exception:
            self._draft_cost_ratio = 1.0
        self._bound = True

    def engine_decode(self, eng, reqs) -> Dict[int, List[int]]:
        self._bind(eng)
        ec = eng.ec
        emitted_map: Dict[int, List[int]] = {}
        cost = 0.0
        for r in reqs:
            s = r._slot
            st = self._slot_state.get(s)
            if st is None or st["req"] is not r:     # slot reused: re-prefill
                prompt = jnp.asarray(r.tokens, jnp.int32)[None]
                _, d_cache = self._d_prefill(self._dp, {"tokens": prompt})
                self.stats_.draft_calls += 1
                st = {"req": r, "d_cache": d_cache,
                      "d_valid": len(r.tokens)}
                self._slot_state[s] = st
            nv = int(eng.slot_nv[s])
            t_len = int(eng.slot_pos[s]) - nv        # text tokens scored
            tok = int(eng.slot_last_tok[s])
            # verify writes positions slot_pos..slot_pos+g; keep clear of
            # the reserved scratch position cache_len-1
            g = max(0, min(self.gamma,
                           ec.cache_len - 2 - int(eng.slot_pos[s])))
            committed = list(r.tokens) + list(r.generated)  # text stream
            lead = committed[st["d_valid"]:t_len + 1]
            draft_toks, draft_ps, st["d_cache"], eng.key = draft_block(
                self._d_extend, self._d_decode, self._dp, st["d_cache"],
                lead, st["d_valid"], gamma=g, temperature=ec.temperature,
                key=eng.key, stats=self.stats_)
            block = jnp.asarray([[tok] + draft_toks], jnp.int32)
            one = _slot_get(eng.pool, s)
            t_logits, one = eng._jit_extend(eng.params, one, block,
                                            jnp.int32(eng.slot_pos[s]))
            eng.pool = _slot_set(eng.pool, s, one)
            self.stats_.target_calls += 1
            self.stats_.proposed += g
            emitted, n_acc, bonus, eng.key = accept_block(
                eng.key, t_logits, draft_toks, draft_ps,
                temperature=ec.temperature,
                limit=r.max_new_tokens - len(r.generated),
                nbhd=self._nbhd, lantern_delta=self.lantern_delta)
            self.stats_.accepted += n_acc
            self.stats_.bonus += int(bonus)
            eng.slot_pos[s] += 1 + n_acc             # tok + accepted drafts
            # whole-block accept leaves the last accepted draft unwritten in
            # the draft cache; next round's lead replays it
            st["d_valid"] = (t_len + 1 + n_acc
                             - (1 if (g > 0 and n_acc == g) else 0))
            eng.slot_last_tok[s] = emitted[-1]
            emitted_map[s] = emitted
            # virtual clock: the verify pass is a compute-dense (1+g)-token
            # block scoring (prefill-shaped), the draft pays g decode steps
            # scaled by its active-param ratio
            ctx = float(eng.slot_pos[s])
            cost += (ec.cost.prefill_time(1 + g)
                     + self._draft_cost_ratio * g
                     * ec.cost.decode_step_time(1, ctx))
        eng._iter_decode_cost = cost
        return emitted_map


DECODERS = {
    "greedy": GreedyDecoder,
    "sampling": SamplingDecoder,
    "speculative": SpeculativeDecoder,
    "early_exit": EarlyExitDecoder,
}


def make_decoder(name: str, gen=None, *, draft=None, d_params=None):
    """Build a decoder strategy, optionally parameterized by a
    ``GenerationConfig`` and (for speculative) a draft model."""
    if name not in DECODERS:
        raise ValueError(f"unknown decoder {name!r}; known: "
                         f"{sorted(DECODERS)}")
    if name == "early_exit":
        if gen is None:
            return EarlyExitDecoder()
        return EarlyExitDecoder(threshold=gen.exit_threshold,
                                patience=gen.exit_patience,
                                min_layers=gen.exit_min_layers)
    if name == "speculative":
        if gen is None:
            return SpeculativeDecoder(draft, d_params)
        return SpeculativeDecoder(draft, d_params, gamma=gen.gamma,
                                  lantern_k=gen.lantern_k,
                                  lantern_delta=gen.lantern_delta)
    return DECODERS[name]()
