"""KV cache selection (survey dim 2a-i): static + dynamic token retention.

Uniform signature over a single layer's cache:

    select(k, v, *, budget, attn=None, pos=None)
        k, v  : [B, S, H, D]
        attn  : [B, Hq, Sq, S] attention probs (observation window or
                accumulated), required by attention-based selectors
        pos   : [S] absolute positions (default arange)
        -> (k' [B,budget,H,D], v' [B,budget,H,D], kept_pos [B,budget])

  * snapkv     -- observation-window voting, static one-shot post-prefill
  * h2o        -- heavy hitters (accumulated attention) + recent window
  * streaming  -- attention sinks + recent window (position-only)
  * l2         -- low key-L2-norm retention (attention-free)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Out = Tuple[jax.Array, jax.Array, jax.Array]


def _gather(k, v, idx):
    """idx [B, budget] -> gathered caches."""
    k2 = jnp.take_along_axis(k, idx[:, :, None, None], axis=1)
    v2 = jnp.take_along_axis(v, idx[:, :, None, None], axis=1)
    return k2, v2


def _finish(k, v, scores, budget, pos) -> Out:
    _, idx = jax.lax.top_k(scores, budget)
    idx = jnp.sort(idx, axis=-1)
    k2, v2 = _gather(k, v, idx)
    kept_pos = jnp.take_along_axis(
        jnp.broadcast_to(pos[None], scores.shape), idx, axis=1)
    return k2, v2, kept_pos


def _default_pos(s):
    return jnp.arange(s, dtype=jnp.int32)


def select_snapkv(k, v, *, budget, attn, pos=None, obs_window: int = 16,
                  kernel: int = 5) -> Out:
    """SnapKV: votes from the last ``obs_window`` queries, pooled.

    attn [B,Hq,Sq,S]: full-prompt attention; only the final observation
    window's rows vote. 1D pooling smooths the votes so adjacent context
    survives together (as in the paper). The observation window itself is
    always retained (forced +inf score).
    """
    b, s = k.shape[0], k.shape[1]
    pos = _default_pos(s) if pos is None else pos
    votes = attn[:, :, -obs_window:, :].sum(axis=(1, 2))     # [B,S]
    # avg-pool1d smoothing
    pad = kernel // 2
    vp = jnp.pad(votes, ((0, 0), (pad, pad)), mode="edge")
    votes = jnp.stack([vp[:, i:i + s] for i in range(kernel)], 0).mean(0)
    votes = votes.at[:, -obs_window:].set(jnp.inf)
    return _finish(k, v, votes, budget, pos)


def select_h2o(k, v, *, budget, attn, pos=None, recent_frac: float = 0.5
               ) -> Out:
    """H2O: heavy hitters by accumulated attention + recent window.

    Half the budget (recent_frac) is the most recent tokens; the rest are
    the highest accumulated-attention "heavy hitters".
    """
    b, s = k.shape[0], k.shape[1]
    pos = _default_pos(s) if pos is None else pos
    acc = attn.sum(axis=(1, 2))                              # [B,S]
    n_recent = max(1, int(budget * recent_frac))
    scores = acc.at[:, -n_recent:].set(jnp.inf)
    return _finish(k, v, scores, budget, pos)


def select_streaming(k, v, *, budget, attn=None, pos=None, sinks: int = 4
                     ) -> Out:
    """StreamingLLM: attention sinks (first ``sinks`` tokens) + recent.

    Purely positional -- no attention needed; the sink retention encodes
    the paper's "attention sink" stability phenomenon.
    """
    b, s = k.shape[0], k.shape[1]
    pos = _default_pos(s) if pos is None else pos
    rank = jnp.arange(s, dtype=jnp.float32)
    scores = rank[None, :] * jnp.ones((b, 1))                # recency
    scores = scores.at[:, :sinks].set(jnp.inf)               # sinks forced
    return _finish(k, v, scores, budget, pos)


def select_l2(k, v, *, budget, attn=None, pos=None) -> Out:
    """L2Compress: low key-norm ~ high attention (static, attention-free)."""
    b, s = k.shape[0], k.shape[1]
    pos = _default_pos(s) if pos is None else pos
    norms = jnp.linalg.norm(k.astype(jnp.float32), axis=-1).mean(-1)  # [B,S]
    return _finish(k, v, -norms, budget, pos)


SELECTORS = {
    "snapkv": select_snapkv,
    "h2o": select_h2o,
    "streaming": select_streaming,
    "l2": select_l2,
}


def oracle_topk(attn_future, budget) -> jax.Array:
    """Oracle: positions that actually receive the most future attention.
    Used by benchmarks to score selector recall. attn_future [B,Hq,Sq,S]."""
    sc = attn_future.sum(axis=(1, 2))
    _, idx = jax.lax.top_k(sc, budget)
    return jnp.sort(idx, -1)
