"""Iteration-level schedulers (survey dim 2c-i): static batching (baseline),
Orca/vLLM continuous batching, FastServe skip-join MLFQ, and Sarathi-Serve
chunked prefill. Schedulers are pure control planes: each call to ``plan``
returns an IterationPlan -- which requests prefill how many tokens and which
decode one token this iteration -- so the same scheduler drives both the
real engine (engine.py) and the analytic simulator (disaggregation.py /
benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.serving.request import Request, State


@dataclasses.dataclass
class IterationPlan:
    prefill: List[Tuple[Request, int]]      # (request, n_prompt_tokens)
    decode: List[Request]

    @property
    def prefill_tokens(self) -> int:
        return sum(n for _, n in self.prefill)

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + len(self.decode)


class StaticBatcher:
    """Baseline: admit a fixed batch, run it to completion, then the next.

    This is the head-of-line-blocking strawman the survey's continuous
    batching section (Orca) eliminates.
    """

    def __init__(self, batch_size: int):
        self.batch_size = batch_size
        self.current: List[Request] = []

    def plan(self, waiting: List[Request], running: List[Request]
             ) -> IterationPlan:
        # drop finished AND eos-stopped (DONE before max_new_tokens) requests
        self.current = [r for r in self.current
                        if not r.is_finished() and r.state != State.DONE]
        if not self.current:
            admit = waiting[: self.batch_size]
            for r in admit:
                r.state = State.PREFILL
            self.current = list(admit)
            return IterationPlan([(r, len(r.tokens)) for r in admit], [])
        return IterationPlan([], list(self.current))


class ContinuousBatcher:
    """Orca/vLLM iteration-level scheduling.

    Every iteration: finished requests leave immediately; waiting requests
    are admitted while decode slots AND KV blocks remain. Admission runs
    full-prompt prefill (one iteration), then the request joins the decode
    batch -- diverse-length requests coexist.
    """

    def __init__(self, max_batch: int, kv_capacity_tokens: int,
                 block_size: int = 16):
        self.max_batch = max_batch
        self.kv_capacity = kv_capacity_tokens
        self.block_size = block_size

    def _kv_used(self, running: List[Request]) -> int:
        # ``lookahead`` reserves the speculative draft/verify slack: those
        # slots write up to gamma positions past the committed stream, so
        # capacity accounting must include it or admission overcommits.
        # ``kv_total_len`` counts POST-compression visual tokens -- what
        # the pool actually holds -- so compressed requests free real
        # admission headroom instead of reserving for pruned tokens.
        bs = self.block_size
        return sum(((r.kv_total_len + r.max_new_tokens + r.lookahead
                     + bs - 1) // bs) * bs
                   for r in running)

    def plan(self, waiting: List[Request], running: List[Request]
             ) -> IterationPlan:
        running = [r for r in running if not r.is_finished()]
        prefill = []
        used = self._kv_used(running)
        for r in list(waiting):
            if len(running) + len(prefill) >= self.max_batch:
                break
            need = ((r.kv_prompt_len + r.max_new_tokens + r.lookahead
                     + self.block_size - 1)
                    // self.block_size) * self.block_size
            if used + need > self.kv_capacity:
                break
            prefill.append((r, len(r.tokens)))
            used += need
            r.state = State.PREFILL
        return IterationPlan(prefill, running)


class MLFQScheduler:
    """FastServe skip-join Multi-Level Feedback Queue.

    Requests enter at the level matching their prompt length (skip-join),
    are served shortest-first, and are demoted after exceeding the level's
    token quantum -- preempting long-running decodes to cut mean JCT.
    """

    def __init__(self, max_batch: int, kv_capacity_tokens: int,
                 levels: int = 4, base_quantum: int = 16,
                 block_size: int = 16):
        self.max_batch = max_batch
        self.kv_capacity = kv_capacity_tokens
        self.levels = levels
        self.base_quantum = base_quantum
        self.block_size = block_size

    def entry_level(self, r: Request) -> int:
        q = self.base_quantum
        for lvl in range(self.levels):
            if r.prompt_len <= q:
                return lvl
            q *= 4
        return self.levels - 1

    def quantum(self, level: int) -> int:
        return self.base_quantum * (4 ** level)

    def plan(self, waiting: List[Request], running: List[Request]
             ) -> IterationPlan:
        for r in waiting:
            if r.priority == 0 and r.served_tokens == 0:
                r.priority = self.entry_level(r)
        # demote exhausted requests
        for r in running:
            if r.served_tokens > self.quantum(r.priority) \
                    and r.priority < self.levels - 1:
                r.priority += 1
        # highest priority (lowest level) first; preempt the rest
        pool = [r for r in running if not r.is_finished()]
        pool.sort(key=lambda r: (r.priority, r.arrival))
        active = pool[: self.max_batch]
        for r in pool[self.max_batch:]:
            r.state = State.PREEMPTED
        prefill = []
        if len(active) < self.max_batch and waiting:
            cands = sorted(waiting, key=lambda r: (r.priority, r.arrival))
            for r in cands[: self.max_batch - len(active)]:
                prefill.append((r, len(r.tokens)))
                r.state = State.PREFILL
        return IterationPlan(prefill, active)


class ChunkedPrefillScheduler:
    """Sarathi-Serve: split prefills into chunks, co-schedule with decodes.

    Each iteration has a token budget; decodes (1 token each) get strict
    priority (they are latency-critical), the remaining budget is filled
    with prefill CHUNKS -- saturating compute without stalling decodes.
    """

    def __init__(self, max_batch: int, token_budget: int = 512,
                 chunk_size: int = 128):
        self.max_batch = max_batch
        self.token_budget = token_budget
        self.chunk_size = chunk_size

    def plan(self, waiting: List[Request], running: List[Request]
             ) -> IterationPlan:
        decode = [r for r in running if not r.is_finished()][: self.max_batch]
        budget = self.token_budget - len(decode)
        prefill = []
        # in-flight (partially prefilled) first, then new admissions
        partial = [r for r in waiting if 0 < r.prefill_done < len(r.tokens)]
        fresh = [r for r in waiting if r.prefill_done == 0]
        for r in partial + fresh:
            if budget <= 0 or len(decode) + len(prefill) >= self.max_batch:
                break
            n = min(self.chunk_size, len(r.tokens) - r.prefill_done, budget)
            if n <= 0:
                continue
            prefill.append((r, n))
            budget -= n
            r.state = State.PREFILL
        return IterationPlan(prefill, decode)


SCHEDULERS = {
    "static": StaticBatcher,
    "continuous": ContinuousBatcher,
    "mlfq": MLFQScheduler,
    "chunked": ChunkedPrefillScheduler,
}
