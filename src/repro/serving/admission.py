"""Admission control for the async serving layer: KV watermarks with
hysteresis and FIFO backpressure.

The engine's dense slot pool (and any narrower ``kv_capacity_tokens``
budget) is a hard resource: vLLM-style serving systems gate request entry
on free KV blocks so a burst degrades into queueing delay, never into an
allocator crash. Here the pressure signal is
``Engine.kv_committed_tokens()`` -- the block-rounded reservation
(prompt + max_new + decode lookahead, speculative ``gamma`` included) of
every live request -- measured against ``Engine.kv_capacity_tokens``:

  * a submit that keeps usage at or below ``high_watermark`` is admitted
    immediately (``Engine.submit`` runs synchronously, FIFO with any
    earlier waiters);
  * otherwise the caller AWAITS in a FIFO queue. Waiters drain only once
    usage falls back to ``low_watermark`` (hysteresis, so admission does
    not thrash around the boundary), each re-checked against the high
    watermark as it is admitted;
  * ``max_inflight`` optionally bounds the number of live requests inside
    the engine (waiting + running) regardless of KV headroom.

The deferred queue drains FIFO by default. ``order="slack"`` switches it
to SLO-slack order: waiters are admitted earliest-deadline-first (each
request's TTFT deadline minus the fleet's expected TTFT -- the serving
layer installs the key via ``AdmissionController.order_key``). EDF over
fixed per-request deadlines is starvation-free: a parked request's
deadline never moves while every NEW arrival's deadline recedes, so the
parked one eventually sorts first -- and the drain loop never admits past
a waiter that does not fit, so sorting first guarantees admission next.

The controller is event-loop-confined like the rest of the serving layer:
no locks, admission decisions interleave only at awaits.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
from typing import Callable, Deque, List, Optional, Tuple


@dataclasses.dataclass
class AdmissionConfig:
    """Watermarks are fractions of ``Engine.kv_capacity_tokens``."""
    high_watermark: float = 0.9
    low_watermark: float = 0.7
    max_inflight: Optional[int] = None     # live requests in the engine
    order: str = "fifo"                    # deferred-queue order: fifo|slack

    def __post_init__(self):
        if not 0.0 < self.high_watermark <= 1.0:
            raise ValueError("high_watermark must be in (0, 1]")
        if not 0.0 < self.low_watermark <= self.high_watermark:
            raise ValueError("low_watermark must be in (0, high_watermark]")
        if self.order not in ("fifo", "slack"):
            raise ValueError("order must be 'fifo' or 'slack'")


class AdmissionController:
    """Gates ``Engine.submit`` behind KV watermarks (see module docstring).

    ``admit`` is the only await point; ``maybe_admit`` is the drain hook
    the server pump calls after every step and abort.
    """

    def __init__(self, cfg: AdmissionConfig, engine):
        self.cfg = cfg
        self.engine = engine
        # (future, request, kv need, commit callable) -- the commit is
        # what admission runs once the request fits: Engine.submit for a
        # fresh request, Engine.import_kv for a migrated-in one
        self._waiters: Deque[Tuple[asyncio.Future, object, int, Callable]] \
            = collections.deque()
        self._draining = False          # blocked until usage <= low mark
        self.admitted = 0
        self.deferrals = 0              # submits that had to wait
        # deferred-queue ordering hook: None = strict FIFO; otherwise a
        # key(request) callable -- waiters drain smallest-key-first (the
        # serving layer installs an SLO-slack key for order="slack")
        self.order_key: Optional[Callable[[object], float]] = None

    # ------------------------------------------------------------ state --
    def _live(self) -> int:
        eng = self.engine
        return (len([r for r in eng.waiting if not r.aborted])
                + len([r for r in eng.running if not r.aborted]))

    def _fits(self, need: int) -> bool:
        cfg, eng = self.cfg, self.engine
        if cfg.max_inflight is not None and self._live() >= cfg.max_inflight:
            return False
        return (eng.kv_committed_tokens() + need
                <= cfg.high_watermark * eng.kv_capacity_tokens)

    def _can_admit(self, need: int) -> bool:
        eng = self.engine
        if not (eng.waiting or eng.running):
            return True      # empty engine: a lone request always progresses
        return self._fits(need)

    @property
    def queue_depth(self) -> int:
        return len(self._waiters)

    @property
    def draining(self) -> bool:
        """Hysteresis state: True while admits are held until committed
        usage falls back to the low watermark (exported as the
        ``repro_admission_draining`` gauge; a controller pressure
        signal)."""
        return self._draining

    def refresh(self, req) -> bool:
        """Recompute a DEFERRED waiter's stored KV need after something
        rewrote the request's shape (the adaptive controller swapping
        ``req.compression`` to an aggressive preset). Without this the
        queue would keep gating on the pre-rewrite token count and a
        shrunken request could wait on KV it no longer needs. Returns
        True if ``req`` was found in the queue."""
        for i, entry in enumerate(self._waiters):
            if entry[1] is req:
                fut, r, _stale, submit = entry
                self._waiters[i] = (fut, r,
                                    self.engine.kv_request_tokens(r),
                                    submit)
                return True
        return False

    # ------------------------------------------------------------- gate --
    async def admit(self, req, submit: Optional[Callable] = None) -> bool:
        """Commit ``req`` into the engine, awaiting under backpressure.

        ``submit`` is the commit callable gated by the watermarks --
        ``Engine.submit`` by default; the serving layer passes
        ``Engine.import_kv`` (bound to a migration ticket) for a
        migrated-in request so KV imports respect the same pressure
        limits as fresh admissions.

        Returns True once the commit has run, False if the waiter was
        retracted via ``cancel`` (the request never entered the engine).
        Oversized single requests (which can NEVER fit a slot) still
        raise ``ValueError`` from the engine -- backpressure is for
        aggregate pool pressure, not impossible requests.
        """
        if submit is None:
            submit = self.engine.submit
        need = self.engine.kv_request_tokens(req)
        if not (self.engine.waiting or self.engine.running):
            self._draining = False      # idle engine: hysteresis is stale
        if not self._waiters and not self._draining and self._can_admit(need):
            submit(req)
            self.admitted += 1
            return True
        self.deferrals += 1
        self._draining = True
        req._gate_clock = self.engine.clock   # deadline anchor for slack
        fut = asyncio.get_running_loop().create_future()
        entry = (fut, req, need, submit)
        self._waiters.append(entry)
        try:
            # maybe_admit() submits before resolving True; cancel()
            # retracts the entry and resolves False
            return await fut
        except asyncio.CancelledError:
            if fut.done() and not fut.cancelled() \
                    and fut.exception() is None and fut.result():
                # admitted between cancellation and wakeup: undo
                self.engine.abort(req.rid)
            else:
                try:
                    # analysis: atomic-step (removes only this coroutine's
                    # own entry; no other waiter state is read or assumed
                    # to be unchanged across the await)
                    self._waiters.remove(entry)
                except ValueError:
                    pass
            raise

    def cancel(self, req) -> bool:
        """Retract a queued waiter (its stream was cancelled before
        admission). The awaiting ``admit`` returns False; the request
        never reaches ``Engine.submit``."""
        for entry in list(self._waiters):
            fut, r = entry[0], entry[1]
            if r is req:
                self._waiters.remove(entry)
                if not fut.done():
                    fut.set_result(False)
                self._draining = bool(self._waiters)
                return True
        return False

    def _drain_order(self) -> List[Tuple[asyncio.Future, object, int,
                                         Callable]]:
        """Waiters in admission order: FIFO, or smallest ``order_key``
        first (stable, so equal-slack waiters keep arrival order)."""
        if self.order_key is None:
            return list(self._waiters)
        return sorted(self._waiters, key=lambda e: self.order_key(e[1]))

    def maybe_admit(self) -> int:
        """Drain waiters when usage is back under the low watermark.
        Called by the pump after every engine step / abort. Returns the
        number of requests admitted. Never admits PAST a waiter that does
        not fit (no bypass), so the head of the drain order -- FIFO or
        earliest slack -- is always the next admitted: starvation-free."""
        if not self._waiters:
            self._draining = False
            return 0
        eng = self.engine
        if (eng.kv_committed_tokens()
                > self.cfg.low_watermark * eng.kv_capacity_tokens):
            return 0
        n = 0
        for entry in self._drain_order():
            fut, req, need, submit = entry
            if fut.cancelled():
                self._waiters.remove(entry)
                continue
            if not self._can_admit(need):
                break
            self._waiters.remove(entry)
            try:
                submit(req)        # commit BEFORE resolving: accounting is
            except Exception as exc:   # impossible request (can never fit
                # a slot): surface to ITS caller, exactly like the
                # fast-path submit would -- never into the pump, which
                # calls this drain and must not die for one bad request
                if not fut.done():
                    fut.set_exception(exc)
                continue
            self.admitted += 1     # correct even if the waiter runs late
            fut.set_result(True)
            n += 1
        self._draining = bool(self._waiters)
        return n

    def cancel_waiters(self) -> None:
        """Fail every pending waiter (server shutdown without drain)."""
        while self._waiters:
            fut = self._waiters.popleft()[0]
            if not fut.done():
                fut.set_exception(
                    RuntimeError("server stopped before admission"))
        self._draining = False
