"""Multimodal speculative decoding demo (survey dim 4a).

A language-only draft speculates for a multimodal target (Gagrani et al.):
the draft never sees the image; the target verifies with full context.
A distilled draft shows real acceptance; LANTERN relaxation on top.

    PYTHONPATH=src python examples/spec_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.decoding import acceptance_rate, speculative_generate
from repro.models import build
from repro.training import OptimizerConfig, adamw_init, adamw_update


def distill_draft(target, t_params, draft, d_params, vocab, steps=60):
    """Train the draft to mimic the target's next-token logits (tiny KD)."""
    oc = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=steps,
                         weight_decay=0.0)
    opt = adamw_init(d_params)
    rng = np.random.RandomState(0)

    @jax.jit
    def step(d_params, opt, tokens):
        t_logits, _ = target.forward(t_params, {"tokens": tokens})
        t_probs = jax.nn.softmax(t_logits, -1)

        def loss_fn(p):
            d_logits, _ = draft.forward(p, {"tokens": tokens})
            lsm = jax.nn.log_softmax(d_logits, -1)
            return -(t_probs * lsm).sum(-1).mean()
        loss, grads = jax.value_and_grad(loss_fn)(d_params)
        d_params, opt, _ = adamw_update(oc, grads, opt, d_params)
        return d_params, opt, loss

    for s in range(steps):
        tokens = jnp.asarray(rng.randint(1, vocab, (8, 24)), jnp.int32)
        d_params, opt, loss = step(d_params, opt, tokens)
        if s % 20 == 0:
            print(f"  distill step {s:3d} KD-loss {float(loss):.4f}")
    return d_params


def main():
    cfg = get_config("qwen2-vl-2b", smoke=True).with_(vocab_size=512)
    target = build(cfg)
    # train the target briefly so its outputs have learnable structure
    # (an untrained target's greedy stream is noise no draft can match)
    from repro.training import SyntheticDataConfig, train_loop
    print("== training target on the synthetic stream")
    t_out = train_loop(target,
                       oc=OptimizerConfig(lr=2e-3, warmup_steps=5,
                                          total_steps=80),
                       dc=SyntheticDataConfig(batch=8, seq_len=32),
                       num_steps=80, log_every=40)
    t_params = t_out["params"]
    # language-only draft: NO visual pathway (dense family, tiny)
    dcfg = get_config("phi4-mini-3.8b", smoke=True).with_(
        num_layers=1, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
        head_dim=32, vocab_size=cfg.vocab_size)
    draft = build(dcfg)
    d_params = draft.init(jax.random.PRNGKey(1))

    rng = np.random.RandomState(2)
    prompt = list(rng.randint(1, cfg.vocab_size, size=20))
    ve = jnp.asarray(rng.randn(cfg.num_visual_tokens, cfg.d_model) * 0.02,
                     jnp.float32)
    n_new, gamma = 24, 4

    print("== random draft (no training)")
    toks0, s0 = speculative_generate(target, draft, t_params, d_params,
                                     prompt, max_new_tokens=n_new,
                                     gamma=gamma, visual_embeds=ve)
    print(f"  acceptance={acceptance_rate(s0):.2f} "
          f"target_calls={s0.target_calls} (vs {n_new} sequential)")

    print("== distilled language-only draft")
    d_params = distill_draft(target, t_params, draft, d_params,
                             cfg.vocab_size, steps=150)
    toks1, s1 = speculative_generate(target, draft, t_params, d_params,
                                     prompt, max_new_tokens=n_new,
                                     gamma=gamma, visual_embeds=ve)
    print(f"  acceptance={acceptance_rate(s1):.2f} "
          f"target_calls={s1.target_calls} "
          f"call_reduction={n_new / s1.target_calls:.2f}x")

    print("== + LANTERN relaxed acceptance (temperature 0.8)")
    toks2, s2 = speculative_generate(target, draft, t_params, d_params,
                                     prompt, max_new_tokens=n_new,
                                     gamma=gamma, visual_embeds=ve,
                                     temperature=0.8, lantern_k=16,
                                     lantern_delta=0.3)
    print(f"  acceptance={acceptance_rate(s2):.2f} "
          f"target_calls={s2.target_calls}")

    # fidelity: greedy speculative == greedy target
    assert toks1[:8] == toks0[:8], "greedy outputs must agree"
    print("greedy fidelity check passed")


if __name__ == "__main__":
    main()
