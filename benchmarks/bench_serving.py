"""Benchmark: serving & scheduling (survey dim 2c), via the ``repro.api``
facade.

Real engine, real smoke model, virtual-clock metrics:
  * scheduler comparison on a bursty mixed-length workload,
  * prefix caching on shared-system-prompt traffic,
  * per-request decoder mixing: greedy + sampling + speculative +
    early-exit requests in ONE engine run (batched speculative slots),
  * disaggregated vs colocated pools under KV-transfer cost (analytic sim).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.api import EngineConfig, GenerationConfig, LVLM, Request
from repro.core.serving import (CostModel, PoolConfig, goodput,
                                simulate_colocated, simulate_disaggregated)


def _reqs(cfg, n, seed=0, shared=0, lo=10, hi=60, new=8, gap=0.001):
    rng = np.random.RandomState(seed)
    pre = list(rng.randint(1, cfg.vocab_size, size=shared))
    return [Request(rid=i, tokens=pre + list(
        rng.randint(1, cfg.vocab_size, size=rng.randint(lo, hi))),
        max_new_tokens=new, arrival=i * gap) for i in range(n)]


def schedulers(lvlm: LVLM) -> None:
    for sched in ("static", "continuous", "mlfq", "chunked"):
        out = lvlm.serve(
            _reqs(lvlm.cfg, 12, seed=1),
            EngineConfig(max_batch=4, cache_len=128, scheduler=sched,
                         chunk_size=16, token_budget=48)).stats
        emit(f"serve/sched/{sched}", out["virtual_time_s"] * 1e6,
             f"ttft_mean={out['ttft_mean']:.4f};"
             f"jct_mean={out['jct_mean']:.4f};"
             f"tput={out['throughput_tok_per_s']:.0f}")


def prefix_cache(lvlm: LVLM) -> None:
    for on in (False, True):
        out = lvlm.serve(
            _reqs(lvlm.cfg, 10, seed=2, shared=64, lo=4, hi=16, new=4),
            EngineConfig(max_batch=4, cache_len=192, prefix_cache=on,
                         prefix_block=16)).stats
        extra = (f"hit_rate={out.get('prefix_token_hit_rate', 0):.3f};"
                 if on else "")
        emit(f"serve/prefix_cache/{'on' if on else 'off'}",
             out["virtual_time_s"] * 1e6,
             extra + f"ttft_mean={out['ttft_mean']:.4f}")


def mixed_decoders(lvlm: LVLM) -> None:
    """One engine, four decode strategies concurrently (survey dim 4 at
    serving scale): per-request ``decoder`` mixing with batched speculative
    slots, vs the same workload served all-greedy."""
    strategies = ("speculative", "speculative", "speculative", "greedy",
                  "sampling", "early_exit", "greedy", "speculative")
    for label, decs in (("mixed", strategies),
                        ("all_greedy", ("greedy",) * len(strategies))):
        reqs = _reqs(lvlm.cfg, len(decs), seed=4, lo=8, hi=24, new=8,
                     gap=0.0005)
        for r, d in zip(reqs, decs):
            r.decoder = d
        out = lvlm.serve(
            reqs, EngineConfig(max_batch=4, cache_len=128,
                               temperature=0.0),
            gen=GenerationConfig(decoder="greedy", temperature=0.0,
                                 max_new_tokens=8, gamma=3)).stats
        spec = (f"spec_acc={out.get('speculative/acceptance', 0):.2f};"
                f"spec_slots={out.get('speculative/max_slots_per_round', 0)};"
                if label == "mixed" else "")
        emit(f"serve/mixed_decoders/{label}",
             out["virtual_time_s"] * 1e6,
             spec + f"ttft_mean={out['ttft_mean']:.4f};"
             f"jct_mean={out['jct_mean']:.4f};"
             f"tput={out['throughput_tok_per_s']:.0f}")


def disaggregation() -> None:
    cost = CostModel(prefill_us_per_token=30.0, decode_us_per_token=600.0,
                     decode_us_per_ctx_token=0.01,
                     kv_bytes_per_token=500_000, transfer_gbps=20.0)
    for label, fn in (
            ("colocated", lambda rs: simulate_colocated(
                rs, cost, n_instances=2, decode_batch=16)),
            ("disagg", lambda rs: simulate_disaggregated(
                rs, cost, PoolConfig(1, 1, 16))),
            ("disagg_predlen", lambda rs: simulate_disaggregated(
                rs, cost, PoolConfig(1, 1, 16), predict_len=True))):
        rng = np.random.RandomState(3)
        reqs = [Request(rid=i, tokens=list(rng.randint(1, 64, size=rng.randint(
            100, 500))), max_new_tokens=int(rng.randint(8, 64)),
            arrival=i * 0.003) for i in range(32)]
        for r in reqs:
            r.predicted_len = r.max_new_tokens
        out = fn(reqs)
        g = goodput(reqs, ttft_slo=0.15, tpot_slo=0.002)
        emit(f"serve/disagg/{label}", out["makespan"] * 1e6,
             f"ttft_p99={out['ttft_p99']:.4f};tpot={out['tpot_mean']:.5f};"
             f"goodput={g:.2f}")


def run() -> None:
    lvlm = LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)
    schedulers(lvlm)
    prefix_cache(lvlm)
    mixed_decoders(lvlm)
    disaggregation()


if __name__ == "__main__":
    run()
