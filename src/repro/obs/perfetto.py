"""Chrome-trace / Perfetto JSON export of a ``Tracer`` event log.

Mapping (chrome://tracing "JSON Array Format" / Perfetto-loadable):

  * one **process** per replica (``pid`` = replica index, named
    ``replica{i}`` via process_name metadata),
  * lifecycle spans -> **async events** (``ph`` ``"b"``/``"e"``,
    ``id`` = rid, ``cat`` = ``"request"``) so overlapping stages of one
    request (admission wait inside the request span, migration spanning
    two replicas) render as nested tracks without the strict
    begin/end nesting B/E slices require,
  * engine-step / slot activity (tracer ``slice``) -> **complete
    events** (``ph`` ``"X"``) on ``tid`` lanes: lane 0 is the engine
    pump, lane ``1 + slot`` is that engine slot,
  * instants -> ``ph`` ``"i"``, counters -> ``ph`` ``"C"`` (one counter
    track per name per replica: KV watermark, admission queue depth,
    prefix-tier hits, migration bytes in flight).

Timestamps: Perfetto wants microseconds. The **virtual clock** is the
primary timeline (deterministic; what the cost model charged) --
``vt * 1e6``. Wall time rides along in ``args.wall_s`` on every event
so per-stage wall attribution survives the export.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List

ENGINE_LANE = 0                 # tid of the engine pump lane
SLOT_LANE_BASE = 1              # tid of slot s is SLOT_LANE_BASE + s

_PH = {"B": "b", "E": "e", "i": "i", "X": "X", "C": "C"}


def _us(vt) -> float:
    return float(vt or 0.0) * 1e6


def to_chrome_trace(events: Iterable[Dict]) -> Dict:
    """Convert tracer events to a ``{"traceEvents": [...]}`` dict
    (load via chrome://tracing or ui.perfetto.dev)."""
    out: List[Dict] = []
    reps = set()
    for ev in events:
        pid = int(ev.get("rep", 0))
        reps.add(pid)
        kind = ev["k"]
        ph = _PH.get(kind)
        if ph is None:
            continue
        args: Dict = {"wall_s": ev.get("wt")}
        if ev.get("attrs"):
            args.update(ev["attrs"])
        te: Dict = {"name": ev["name"], "ph": ph, "pid": pid,
                    "ts": _us(ev.get("vt")), "args": args}
        if kind in ("B", "E"):
            # async event pair: id groups begin/end across replicas
            te["cat"] = "request"
            te["id"] = ev.get("rid", 0)
            te["tid"] = ENGINE_LANE
        elif kind == "X":
            slot = ev.get("slot")
            te["tid"] = (ENGINE_LANE if slot is None
                         else SLOT_LANE_BASE + int(slot))
            te["dur"] = _us(ev.get("dur"))
            if ev.get("rid") is not None:
                args["rid"] = ev["rid"]
        elif kind == "i":
            te["tid"] = ENGINE_LANE
            te["s"] = "t"                      # thread-scoped instant
            if ev.get("rid") is not None:
                args["rid"] = ev["rid"]
        elif kind == "C":
            te["tid"] = ENGINE_LANE
            te["args"] = {"value": ev.get("value", 0)}
        out.append(te)
    meta = []
    for pid in sorted(reps):
        meta.append({"name": "process_name", "ph": "M", "pid": pid,
                     "args": {"name": f"replica{pid}"}})
        meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": ENGINE_LANE, "args": {"name": "engine"}})
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def write_chrome_trace(events: Iterable[Dict], path: str) -> int:
    """Write the Chrome-trace JSON; returns the traceEvents count."""
    doc = to_chrome_trace(events)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])
