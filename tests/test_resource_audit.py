"""Resource-lifecycle audit of the abort/release paths, with the
runtime sanitizer on (``EngineConfig(sanitize=True)``).

These are the regression tests for ISSUE 6's resource audit: abort
mid-chunked-prefill, abort of a speculative request, and prefix-pin
accounting under shared prefixes must all return the engine to a
conserved state -- and breaking ``_release_request`` must make the
sanitizer trip (the dynamic twin of the R001 mutation tests in
``test_analysis.py``).
"""
import jax
import numpy as np
import pytest

from repro.analysis import SanitizerError
from repro.api.decoders import SpeculativeDecoder
from repro.configs import get_config
from repro.core.serving import Engine, EngineConfig, Request


@pytest.fixture(scope="module")
def small():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build_model(cfg)
    return cfg, model[0], model[1]


def build_model(cfg):
    from repro.models import build
    model = build(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _prompt(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return list(rng.randint(1, cfg.vocab_size, size=n))


def _assert_baseline(eng):
    assert all(r is None for r in eng.slot_req), eng.slot_req
    assert eng._prefix_pins == {}, eng._prefix_pins
    assert eng.kv_committed_tokens() == 0
    for dec in eng._decoders.values():
        bound = getattr(dec, "bound_slots", None)
        if bound is not None:
            assert bound() == set()


def test_abort_mid_chunked_prefill_returns_to_baseline(small):
    cfg, model, params = small
    eng = Engine(model, params, EngineConfig(
        max_batch=2, cache_len=64, chunk_size=4, token_budget=8,
        sanitize=True))
    r = Request(rid=0, tokens=_prompt(cfg, 24), max_new_tokens=4)
    eng.submit(r)
    assert eng.step()                       # partial prefill: slot bound
    assert any(s is not None for s in eng.slot_req)
    assert eng.abort(0)                     # sanitizer runs inside abort
    _assert_baseline(eng)
    assert not eng.abort(0)                 # double-abort is a no-op


def test_abort_speculative_request_frees_draft_row(small):
    cfg, model, params = small
    eng = Engine(model, params, EngineConfig(
        max_batch=2, cache_len=64, sanitize=True))
    eng._decoders["speculative"] = SpeculativeDecoder(gamma=2)
    r = Request(rid=0, tokens=_prompt(cfg, 8), max_new_tokens=8,
                decoder="speculative")
    eng.submit(r)
    for _ in range(3):
        eng.step()
    dec = eng._decoders["speculative"]
    assert eng.abort(0)
    assert dec.bound_slots() == set()
    _assert_baseline(eng)


def test_prefix_pins_balance_with_shared_prefixes(small):
    cfg, model, params = small
    eng = Engine(model, params, EngineConfig(
        max_batch=3, cache_len=96, prefix_cache=True, prefix_block=4,
        sanitize=True))
    shared = _prompt(cfg, 16, seed=3)
    eng.submit(Request(rid=0, tokens=list(shared), max_new_tokens=3))
    eng.run()                               # seeds the prefix cache
    # two reuse requests + one aborted mid-flight
    for rid in (1, 2):
        eng.submit(Request(rid=rid, tokens=list(shared) + [rid],
                           max_new_tokens=3))
    eng.step()
    eng.abort(1)                            # pin decremented, not leaked
    eng.run()
    _assert_baseline(eng)


def test_mixed_decoder_run_conserves_under_sanitizer(small):
    cfg, model, params = small
    eng = Engine(model, params, EngineConfig(
        max_batch=3, cache_len=64, chunk_size=8, sanitize=True))
    eng._decoders["speculative"] = SpeculativeDecoder(gamma=2)
    for i, dec in enumerate((None, "speculative", "greedy")):
        eng.submit(Request(rid=i, tokens=_prompt(cfg, 6, seed=i),
                           max_new_tokens=4, decoder=dec))
    stats = eng.run()
    assert len(eng.finished) == 3
    _assert_baseline(eng)
    assert stats is not None


def test_broken_release_trips_sanitizer(small):
    """Dynamic acceptance check: neuter _release_request and the very
    first abort fails the conservation asserts."""
    cfg, model, params = small
    eng = Engine(model, params, EngineConfig(
        max_batch=1, cache_len=64, sanitize=True))
    eng.submit(Request(rid=0, tokens=_prompt(cfg, 8), max_new_tokens=8))
    eng.step()
    eng._release_request = lambda r: None   # the leak under test
    with pytest.raises(SanitizerError, match="slot leak"):
        eng.abort(0)


def test_sanitize_env_var_enables(small, monkeypatch):
    cfg, model, params = small
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    eng = Engine(model, params, EngineConfig(max_batch=1, cache_len=32))
    assert eng.sanitize is True
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    eng = Engine(model, params, EngineConfig(max_batch=1, cache_len=32))
    assert eng.sanitize is False
    # explicit config wins over the env var
    eng = Engine(model, params, EngineConfig(max_batch=1, cache_len=32,
                                             sanitize=True))
    assert eng.sanitize is True
