"""Config system for the repro framework.

A single frozen ``ModelConfig`` dataclass covers all six architecture
families assigned to this paper (dense, moe, vlm, ssm, hybrid, audio).
Every architecture in ``src/repro/configs/<id>.py`` exports

    CONFIG       -- the full production config (exact assigned numbers)
    SMOKE_CONFIG -- a reduced variant of the same family (<=2 layers,
                    d_model<=512, <=4 experts) used by CPU smoke tests.

Input shapes live in ``shapes.py``; the registry in ``registry.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity ------------------------------------------------------------
    name: str
    family: str                      # dense | moe | vlm | ssm | hybrid | audio
    source: str = ""                 # citation for the config numbers

    # transformer core ------------------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0
    activation: str = "swiglu"       # swiglu | relu2 | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False

    # positional ------------------------------------------------------------
    rope_theta: float = 1.0e4
    use_mrope: bool = False          # Qwen2-VL multimodal RoPE (3 sections)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2

    # MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (0 -> d_ff)
    first_k_dense_layers: int = 0    # deepseek: first k layers are dense
    dense_residual: bool = False     # arctic: parallel dense MLP residual
    router_aux_loss_coef: float = 1.0e-2

    # MLA (DeepSeek-V3 multi-head latent attention) ----------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM (Mamba2 / RWKV6) ------------------------------------------------
    ssm_state_dim: int = 0
    ssm_conv_dim: int = 4
    ssm_head_dim: int = 64           # per-head channel width for SSD / RWKV6
    ssm_expand: int = 2              # Mamba2 inner expansion

    # hybrid (Zamba2): shared attention block every k SSM layers ----------
    attn_layer_period: int = 0       # 0 -> no interleaved attention

    # encoder-decoder (Whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings length
    decoder_max_seq: int = 0         # architectural decoder limit (doc only)

    # multimodal frontend stub ---------------------------------------------
    num_visual_tokens: int = 0       # patch embeds injected by input_specs()
    projector: str = "mlp"           # mlp | perceiver (Flamingo resampler)
    num_latents: int = 64            # perceiver: fixed visual-token budget

    # long-context -----------------------------------------------------
    sliding_window: int = 0          # 0 = full attention; >0 = ring-buffer window

    # numerics --------------------------------------------------------------
    dtype: str = "bfloat16"
    logits_softcap: float = 0.0
    weight_quant: str = "none"       # none | int8_ffn (serving: FFN weights
    #                                  stored int8 + per-channel f32 scales;
    #                                  halves fsdp gather bytes per step)

    # ----------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # derived -----------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def kv_head_dim(self) -> int:
        """Width of one KV entry per layer per token (for cache sizing)."""
        if self.use_mla:
            return self.kv_lora_rank + self.qk_rope_head_dim  # latent cache
        return 2 * self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Exact parameter count from the spec tree (filled by registry)."""
        from repro.models.registry import build
        specs = build(self).param_specs()
        total = 0

        def _walk(node):
            nonlocal total
            if isinstance(node, dict):
                for v in node.values():
                    _walk(v)
            else:
                n = 1
                for s in node.shape:
                    n *= s
                total += n
        _walk(specs)
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE discounts inactive experts)."""
        total = self.param_count()
        if not self.num_experts:
            return total
        from repro.models.registry import build
        specs = build(self).param_specs()
        expert_params = 0

        def _walk(node, path=()):
            nonlocal expert_params
            if isinstance(node, dict):
                for k, v in node.items():
                    _walk(v, path + (k,))
            else:
                if any("expert" in p for p in path) and "shared" not in "/".join(path):
                    n = 1
                    for s in node.shape:
                        n *= s
                    expert_params += n
        _walk(specs)
        if self.num_experts:
            frac = self.experts_per_token / self.num_experts
            total = total - expert_params + int(expert_params * frac)
        return total

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Selects taxonomy-dimension-1/2 features for a serving run."""
    # visual token compression (dim 1)
    token_pruner: str = "none"       # none|fastv|sparsevlm|l2|divprune|cdpruner|pyramiddrop
    token_merger: str = "none"       # none|tome|framefusion
    keep_ratio: float = 1.0          # fraction of visual tokens kept
    prune_layer: int = 2             # FastV: drop after this decoder layer
    # KV cache (dim 2)
    kv_selector: str = "none"        # none|snapkv|h2o|streaming|l2
    kv_budget: int = 0               # tokens retained (0 = unlimited)
    kv_budget_policy: str = "uniform"   # uniform|pyramid|adaptive
    kv_merger: str = "none"          # none|d2o
    # decoding (dim 4)
    speculative: bool = False
    draft_len: int = 4
    early_exit_threshold: float = 0.0   # 0 = disabled
