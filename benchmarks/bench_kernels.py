"""Benchmark: hardware-aware attention kernels (survey dim 3c).

On this CPU container the Pallas kernels run in interpret mode (orders
of magnitude slower than compiled -- correctness-grade timing only), so
the XLA-compiled blockwise flash-style path carries the meaningful
timing rows; the Pallas rows exist to keep the TRAJECTORY measured (the
same rows on a TPU runtime become the real kernel baseline) plus an
interpret-mode allclose spot check. True kernel timing belongs on a TPU
runtime (EXPERIMENTS.md §Perf).

``--emit-bench BENCH_kernels.json`` writes the schema-v1 per-kernel
rows (min/mean/std us per call, warmup-correct -- see
``benchmarks.common.time_jit``) that ``python -m repro.obs.regress``
gates CI against.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timing, emit, time_jit
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.models.attention import blockwise_sdpa


def _naive(q, k, v, pos):
    s = jnp.einsum("bqkgd,bckd->bkgqc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (q.shape[-1] ** 0.5)
    mask = pos[None, :] <= pos[:, None]
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1)


def _row(rows, kernel: str, backend: str, shape: str, t: Timing,
         iters: int, derived: str = "") -> None:
    rows.append({"kernel": kernel, "backend": backend, "shape": shape,
                 "us_per_call": t.stats(), "iters": iters})
    emit(f"kern/{kernel}/{shape}", t, derived)


def bench_blockwise(rows) -> None:
    """XLA blockwise flash-style path vs naive materialized attention."""
    rng = np.random.RandomState(0)
    for s in (512, 2048):
        b, kvh, g, d = 1, 2, 2, 64
        q = jnp.asarray(rng.randn(b, s, kvh, g, d), jnp.float32)
        k = jnp.asarray(rng.randn(b, s, kvh, d), jnp.float32)
        v = jnp.asarray(rng.randn(b, s, kvh, d), jnp.float32)
        pos = jnp.arange(s)
        t_naive = time_jit(jax.jit(lambda *a: _naive(*a, pos)), q, k, v,
                           iters=3)
        t_block = time_jit(jax.jit(
            lambda qq, kk, vv: blockwise_sdpa(qq, kk, vv, q_pos=pos,
                                              k_pos=pos, causal=True,
                                              block_k=512)), q, k, v,
            iters=3)
        shape = f"b{b}_kvh{kvh}_g{g}_s{s}_d{d}"
        _row(rows, "blockwise_sdpa", "xla", shape, t_block, 3,
             f"naive_us={t_naive:.0f}")
        _row(rows, "naive_sdpa", "xla", shape, t_naive, 3)


def bench_flash(rows) -> None:
    """Pallas flash-attention prefill kernel (interpret mode on CPU)."""
    rng = np.random.RandomState(1)
    b, h, kvh, d = 1, 4, 2, 32
    for s in (64, 128):
        q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
        k = jnp.asarray(rng.randn(b, kvh, s, d), jnp.float32)
        v = jnp.asarray(rng.randn(b, kvh, s, d), jnp.float32)
        t = time_jit(lambda: flash_attention(q, k, v, causal=True,
                                             block_q=32, block_k=32),
                     iters=3)
        _row(rows, "flash_attention", "pallas_interpret",
             f"b{b}_h{h}_s{s}_d{d}", t, 3)


def bench_paged(rows) -> None:
    """Pallas paged decode-attention kernel (interpret mode on CPU)."""
    rng = np.random.RandomState(2)
    b, h, kvh, d, page = 2, 4, 2, 32, 16
    for pps in (4, 8):                 # pages per sequence
        P = b * pps
        q = jnp.asarray(rng.randn(b, h, d), jnp.float32)
        kp = jnp.asarray(rng.randn(P, page, kvh, d), jnp.float32)
        vp = jnp.asarray(rng.randn(P, page, kvh, d), jnp.float32)
        bt = jnp.asarray(rng.choice(P, (b, pps), replace=False),
                         jnp.int32)
        sl = jnp.asarray(rng.randint(page, pps * page, b), jnp.int32)
        t = time_jit(lambda: paged_attention(q, kp, vp, bt, sl), iters=3)
        _row(rows, "paged_attention", "pallas_interpret",
             f"b{b}_h{h}_ctx{pps * page}_d{d}", t, 3)


def check_flash_vs_ref(rows) -> None:
    """Interpret-mode correctness spot check (the TPU kernel's oracle
    gate); ``max_err`` is informational to the regress gate."""
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(1, 4, 64, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 64, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 64, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.abs(out - expect).max())
    rows.append({"kernel": "flash_attention", "check": "allclose_vs_ref",
                 "max_err": err})
    emit("kern/pallas_interpret_allclose", 0.0, f"max_err={err:.2e}")


def run(emit_bench: str = None) -> None:
    rows = []
    bench_blockwise(rows)
    bench_flash(rows)
    bench_paged(rows)
    check_flash_vs_ref(rows)
    if emit_bench:
        doc = {"schema_version": 1, "bench": "kernels",
               "backend_note": "pallas rows are interpret-mode on CPU "
                               "(correctness-grade; recapture baselines "
                               "per runtime)",
               "rows": rows}
        with open(emit_bench, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {emit_bench} ({len(rows)} rows)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--emit-bench", metavar="PATH",
                    help="write schema-v1 per-kernel timing rows "
                         "(BENCH_kernels.json) for repro.obs.regress")
    args = ap.parse_args(argv)
    run(emit_bench=args.emit_bench)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
