from repro.core.serving.request import Request, SLO, State, summarize
from repro.core.serving.scheduler import (
    SCHEDULERS, IterationPlan, StaticBatcher, ContinuousBatcher,
    MLFQScheduler, ChunkedPrefillScheduler)
from repro.core.serving.disaggregation import (
    CostModel, PoolConfig, simulate_disaggregated, simulate_colocated,
    goodput)
from repro.core.serving.engine import Engine, EngineConfig
