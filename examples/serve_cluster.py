"""Serve one request stream over a HETEROGENEOUS engine fleet through the
cluster Router -- replica 0 is speculative-heavy (self-draft, gamma=4),
replica 1 decodes with early exit; least-KV routing balances them while
each request is served by whatever strategy its replica defaults to. Then
a prefix-affinity demo: the same shared-prefix workload routed
round-robin vs prefix-affinity, showing the fleet-wide prefix-cache hit
count climb when one replica owns the prefix family:

    PYTHONPATH=src python examples/serve_cluster.py
"""
import asyncio

import numpy as np

from repro.api import (AdmissionConfig, EngineConfig, GenerationConfig,
                       LVLM, Request)


def requests(cfg, n=8, seed=0, shared=0, new=10):
    rng = np.random.RandomState(seed)
    pre = list(rng.randint(1, cfg.vocab_size, size=shared)) if shared else []
    return [Request(rid=i, tokens=pre + list(
        rng.randint(1, cfg.vocab_size, size=int(rng.randint(8, 20)))),
        max_new_tokens=new) for i in range(n)]


async def client(router, req):
    toks = [tok async for tok in router.submit(req)]
    return req.rid, toks


async def heterogeneous_fleet(lvlm):
    print("=== heterogeneous fleet: speculative replica + early-exit "
          "replica (least_kv routing) ===")
    router = lvlm.serve_cluster(
        [{"gen": GenerationConfig(decoder="speculative", temperature=0.0,
                                  max_new_tokens=10, gamma=4)},
         {"gen": GenerationConfig(decoder="early_exit", temperature=0.0,
                                  max_new_tokens=10)}],
        EngineConfig(max_batch=4, cache_len=128, temperature=0.0),
        routing="least_kv",
        admission=AdmissionConfig(high_watermark=0.9, low_watermark=0.7,
                                  order="slack"))
    async with router:
        done = await asyncio.gather(
            *(client(router, r) for r in requests(lvlm.cfg, n=8, seed=1)))
    for rid, toks in done:
        print(f"  client {rid}: {len(toks)} tokens {toks[:6]}...")
    s = router.summary()
    print(f"  dispatched per replica: {s['dispatched_by_replica']} "
          f"(0=speculative, 1=early_exit)")
    for i, rep in enumerate(router.replicas):
        stats = rep.server.engine.decoder_stats()
        keyed = {k: round(v, 3) for k, v in stats.items()
                 if isinstance(v, (int, float))}
        print(f"  replica {i} [{rep.state}] decoder stats: {keyed}")
    print(f"  fleet TTFT p95 {s['ttft_p95']:.4f}s  goodput "
          f"{s['slo_goodput']:.2f}  fleet tput "
          f"{s['fleet_throughput_tok_per_s']:.0f} tok/s\n")


async def prefix_affinity_demo(lvlm):
    print("=== prefix affinity vs round robin (shared 32-token prefix) ===")
    for routing in ("round_robin", "prefix_affinity"):
        router = lvlm.serve_cluster(
            2, EngineConfig(max_batch=4, cache_len=160, temperature=0.0,
                            prefix_cache=True),
            gen=GenerationConfig(decoder="greedy", temperature=0.0,
                                 max_new_tokens=6),
            routing=routing)
        async with router:
            await asyncio.gather(*(client(router, r) for r in
                                   requests(lvlm.cfg, n=6, seed=2,
                                            shared=32, new=6)))
        s = router.summary()
        print(f"  {routing:16s} dispatched={s['dispatched_by_replica']} "
              f"prefix_hit_tokens={s['prefix_hit_tokens']}")
    print("  (affinity concentrates the family on one replica: every "
          "request after the first reuses the cached prefix)")


async def main_async():
    lvlm = LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)
    await heterogeneous_fleet(lvlm)
    await prefix_affinity_demo(lvlm)


def main():
    asyncio.run(main_async())


if __name__ == "__main__":
    main()
