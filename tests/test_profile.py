"""repro.obs.profile (PR tentpole): continuous hot-path profiling and
the perf-regression gate.

Contracts locked down here:

  * ZERO overhead when off: the default engine/server hold
    NULL_PROFILER and the hot path performs no profiler calls at all
    (every NullProfiler site method is patched to raise; full serve and
    disaggregated-cluster runs must not trip one),
  * profiling changes nothing: a profiled cluster run (sanitizer on) is
    bit-identical to the unprofiled run at temperature 0, while the
    profiler sees every hot-path site class (prefill forward, decode
    launch, KV export/transfer),
  * self/total attribution: nested sites subtract from the parent's
    self time, and the collapsed-stack export carries the nesting path,
  * the Prometheus histogram family shape (cumulative ``le`` buckets,
    ``+Inf``, ``_sum``/``_count``) and its single fleet-level rendering
    in ``Router.metrics_snapshot()``,
  * the committed ``BENCH_kernels.json`` baseline gates: self-compare
    exits 0, a synthetically slowed copy beyond tolerance exits 1
    (``python -m repro.obs.regress``),
  * ``scripts/profile_report.py`` (table + collapsed stacks),
    ``scripts/trace_report.py --json``, and warmup-correct
    ``benchmarks.common.time_jit`` min/mean/std stats.
"""
import asyncio
import importlib.util
import json
import os

import numpy as np
import pytest

import repro.obs.regress as regress
from repro.api import EngineConfig, GenerationConfig, LVLM, Request
from repro.core.serving.disaggregation import CostModel
from repro.obs import (NULL_PROFILER, NullProfiler, Profiler,
                       profile_families)
from repro.obs.profile import bucket_bounds
from repro.obs.prom import PromText

MAX_NEW = 6
GEN = GenerationConfig(decoder="greedy", temperature=0.0,
                       max_new_tokens=MAX_NEW)
COST = CostModel(kv_bytes_per_token=100_000)
REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def lvlm():
    return LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)


def _ec(**kw):
    base = dict(max_batch=4, cache_len=96, temperature=0.0, sanitize=True)
    base.update(kw)
    return EngineConfig(**base)


def _prompts(n, seed=0, lo=8, hi=16):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, 512, size=rng.randint(lo, hi)))
            for _ in range(n)]


def _reqs(prompts, new=MAX_NEW):
    return [Request(rid=i, tokens=list(p), max_new_tokens=new)
            for i, p in enumerate(prompts)]


async def _consume(stream):
    return [tok async for tok in stream]


def _drive_all(front, reqs):
    async def drive():
        async with front:
            return await asyncio.gather(
                *(_consume(front.submit(r)) for r in reqs))

    outs = asyncio.run(drive())
    return {r.rid: list(o) for r, o in zip(reqs, outs)}


def _load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(REPO, "scripts", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------- zero overhead when off --


def test_unprofiled_hot_path_makes_no_profiler_calls(lvlm, monkeypatch):
    """The default (unprofiled) stack must not call ANY profiler method
    -- guarded sites skip on ``enabled`` alone. Patching every
    NullProfiler site method to raise turns one stray call into a test
    failure (the NullTracer overhead test's twin)."""
    def boom(*a, **k):
        raise AssertionError("profiler method called on the unprofiled "
                             "path")

    for name in ("site_begin", "site_end"):
        monkeypatch.setattr(NullProfiler, name, boom)
    res = lvlm.serve(_reqs(_prompts(3, seed=1)), engine_cfg=_ec(), gen=GEN)
    assert res.engine.profiler is NULL_PROFILER
    assert res.stats["finished"] == 3
    # the cluster path too (migration exercises the kv_* sites)
    router = lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                roles=["prefill", "decode"])
    got = _drive_all(router, _reqs(_prompts(2, seed=2)))
    assert all(len(o) == MAX_NEW for o in got.values())


def test_profiled_run_is_bit_identical_at_temp0(lvlm):
    """Profiling only reads clocks: same tokens, sanitizer clean, and
    every expected hot-path site class observed on a disaggregated
    fleet (prefill forward on the prefill replica, kv export/transfer
    across the link, decode launches on the decode replica)."""
    prompts = _prompts(4, seed=3)
    ref = _drive_all(lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                        roles=["prefill", "decode"]),
                     _reqs(prompts))
    prof = Profiler()
    got = _drive_all(lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                        roles=["prefill", "decode"],
                                        profile=prof),
                     _reqs(prompts))
    assert got == ref
    snap = prof.snapshot()
    for site in ("prefill_forward", "decode:greedy", "kv_export",
                 "kv_transfer"):
        assert snap[site]["count"] > 0, site
        assert snap[site]["wall_total_s"] >= snap[site]["wall_self_s"] >= 0
        assert sum(n for _, n in snap[site]["wall_buckets"]) \
            == snap[site]["count"]
    # virtual attribution flows from the cost model, not the wall clock
    assert snap["kv_transfer"]["virtual_s"] > 0.0
    assert snap["decode:greedy"]["virtual_s"] > 0.0


# ------------------------------------------------- attribution mechanics --


def _manual_profiler():
    t = [0.0]
    prof = Profiler(clock=lambda: t[0])
    return prof, t


def test_profiler_self_total_nesting():
    prof, t = _manual_profiler()
    prof.site_begin("outer")
    t[0] = 1.0
    prof.site_begin("inner")
    t[0] = 3.0
    prof.site_end("inner", vt=0.5)
    t[0] = 4.0
    prof.site_end("outer", vt=1.5)
    snap = prof.snapshot()
    assert snap["outer"]["wall_total_s"] == pytest.approx(4.0)
    assert snap["outer"]["wall_self_s"] == pytest.approx(2.0)
    assert snap["inner"]["wall_total_s"] == pytest.approx(2.0)
    assert snap["inner"]["wall_self_s"] == pytest.approx(2.0)
    assert snap["outer"]["virtual_s"] == pytest.approx(1.5)
    assert snap["inner"]["virtual_s"] == pytest.approx(0.5)
    lines = prof.collapsed()
    assert "outer 2000000" in lines
    assert "outer;inner 2000000" in lines
    rec = prof.bench_record()
    assert rec["schema_version"] == 1
    assert rec["sites"]["outer"]["count"] == 1


def test_profiler_log_buckets():
    bounds = bucket_bounds()
    assert all(b2 == 2 * b1 for b1, b2 in zip(bounds, bounds[1:]))
    prof, t = _manual_profiler()
    for dur in (1e-6, 3e-6, 3e-6, 0.5):
        t0 = t[0]
        prof.site_begin("s")
        t[0] = t0 + dur
        prof.site_end("s")
    buckets = {round(le, 9): n
               for le, n in prof.snapshot()["s"]["wall_buckets"] if n}
    assert buckets[round(1e-6, 9)] == 1            # <= base bound
    assert buckets[round(4e-6, 9)] == 2            # two 3us calls
    assert sum(buckets.values()) == 4


def test_profiler_mismatched_end_is_defensive():
    prof, t = _manual_profiler()
    prof.site_end("never_opened")                   # no-op, no raise
    prof.site_begin("outer")
    prof.site_begin("leaked")
    t[0] = 1.0
    prof.site_end("outer")                          # unwinds past "leaked"
    snap = prof.snapshot()
    assert "leaked" not in snap                     # discarded, not counted
    assert snap["outer"]["count"] == 1


# --------------------------------------------------- prometheus histogram --


def test_prom_histogram_rendering():
    prom = PromText()
    prom.histogram("lat_seconds", "Latency.", [(0.001, 2), (0.004, 1)],
                   0.0055, 4, labels={"site": "s"})
    prom.histogram("lat_seconds", "Latency.", [(0.001, 1)], 0.001, 1,
                   labels={"site": "t"})
    text = prom.render()
    assert text.count("# TYPE repro_lat_seconds histogram") == 1
    assert 'repro_lat_seconds_bucket{le="0.001",site="s"} 2' in text
    # cumulative: the 0.004 bucket includes the 0.001 bucket's count
    assert 'repro_lat_seconds_bucket{le="0.004",site="s"} 3' in text
    # +Inf always closes the family at the total count
    assert 'repro_lat_seconds_bucket{le="+Inf",site="s"} 4' in text
    assert 'repro_lat_seconds_sum{site="s"} 0.0055' in text
    assert 'repro_lat_seconds_count{site="s"} 4' in text
    assert 'repro_lat_seconds_bucket{le="+Inf",site="t"} 1' in text


def test_metrics_snapshot_renders_profile_once_per_fleet(lvlm):
    prof = Profiler()
    router = lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                roles=["prefill", "decode"], profile=prof)
    got = _drive_all(router, _reqs(_prompts(3, seed=5)))
    assert all(len(o) == MAX_NEW for o in got.values())
    text = router.metrics_snapshot()
    # ONE fleet-level histogram family (the profiler is fleet-shared;
    # per-replica rendering would duplicate identical data)
    assert text.count("# TYPE repro_profile_wall_seconds histogram") == 1
    assert 'site="prefill_forward"' in text
    assert 'site="kv_transfer"' in text
    assert "repro_profile_wall_self_seconds_total" in text
    # a standalone (replica-less) server renders its own families
    server = lvlm.serve_async(_ec(), GEN, profile=Profiler())
    _drive_all(server, _reqs(_prompts(2, seed=6)))
    solo = server.metrics_snapshot()
    assert "# TYPE repro_profile_wall_seconds histogram" in solo
    # ...but not when labeled for a fleet scrape (the router owns it)
    assert "profile_wall_seconds" not in server.metrics_snapshot(replica=0)


def test_profile_families_helper():
    prof, t = _manual_profiler()
    prof.site_begin("a")
    t[0] = 0.002
    prof.site_end("a", vt=0.25)
    prom = PromText()
    profile_families(prom, prof, labels={"cluster": "x"})
    text = prom.render()
    assert 'cluster="x"' in text
    assert "# TYPE repro_profile_virtual_seconds histogram" in text
    assert 'repro_profile_virtual_seconds_sum{cluster="x",site="a"} 0.25' \
        in text


# -------------------------------------------------------- regression gate --


def test_regress_committed_kernel_baseline_self_compare():
    """Acceptance: the committed BENCH_kernels.json gates against
    itself cleanly, and a 3x-slowed copy beyond tolerance exits 1."""
    path = os.path.join(REPO, "BENCH_kernels.json")
    doc = json.load(open(path))
    assert doc["schema_version"] == 1
    kernels = {r["kernel"] for r in doc["rows"]}
    assert {"flash_attention", "paged_attention",
            "blockwise_sdpa"} <= kernels
    assert regress.main([path, path]) == 0


def test_regress_slowed_copy_fails(tmp_path):
    path = os.path.join(REPO, "BENCH_kernels.json")
    doc = json.load(open(path))
    for r in doc["rows"]:
        if "us_per_call" in r:
            r["us_per_call"] = {k: v * 3.0
                                for k, v in r["us_per_call"].items()}
    slow = str(tmp_path / "slow.json")
    json.dump(doc, open(slow, "w"))
    assert regress.main([slow, path, "--tolerance", "0.5"]) == 1
    # a FASTER copy is an improvement, never a regression
    for r in doc["rows"]:
        if "us_per_call" in r:
            r["us_per_call"] = {k: v / 9.0
                                for k, v in r["us_per_call"].items()}
    fast = str(tmp_path / "fast.json")
    json.dump(doc, open(fast, "w"))
    assert regress.main([fast, path, "--tolerance", "0.5"]) == 0


def test_regress_direction_heuristics():
    assert regress._direction("rows.k/s.us_per_call.min") == 1
    assert regress._direction("rows.k/s.us_per_call.std") == 0   # noise
    assert regress._direction("virtual.ttft_s.p50") == 1
    assert regress._direction("wall.throughput_tok_per_s") == -1
    assert regress._direction("stages.decode.share") == 0
    assert regress._direction("schema_version") == 0
    assert regress._direction("profile.sites.compress.wall_self_s") == 1
    assert regress._direction("requests") == 0
    # lower throughput regresses, higher does not
    regs, _ = regress.compare({"throughput_tok_per_s": 1.0},
                              {"throughput_tok_per_s": 3.0}, 0.5)
    assert len(regs) == 1
    regs, _ = regress.compare({"throughput_tok_per_s": 9.0},
                              {"throughput_tok_per_s": 3.0}, 0.5)
    assert regs == []
    # rows are matched by identity key, not list position
    a = {"rows": [{"kernel": "k1", "shape": "s", "us_per_call": {"min": 1}},
                  {"kernel": "k2", "shape": "s", "us_per_call": {"min": 5}}]}
    b = {"rows": [{"kernel": "k2", "shape": "s", "us_per_call": {"min": 5}},
                  {"kernel": "k1", "shape": "s", "us_per_call": {"min": 1}}]}
    regs, compared = regress.compare(a, b, 0.1)
    assert regs == [] and len(compared) == 2


def test_serving_baseline_has_profile_block():
    doc = json.load(open(os.path.join(REPO, "BENCH_serving.json")))
    assert doc["schema_version"] == 1
    sites = doc["profile"]["sites"]
    assert sites["prefill_forward"]["count"] > 0
    assert sites["kv_transfer"]["virtual_s"] > 0.0


# ---------------------------------------------------------- report tools --


def test_profile_report_table_and_collapsed(tmp_path, capsys):
    prof, t = _manual_profiler()
    prof.site_begin("prefill_forward")
    t[0] = 1.0
    prof.site_begin("compress")
    t[0] = 3.0
    prof.site_end("compress")
    t[0] = 4.0
    prof.site_end("prefill_forward", vt=0.125)
    p = str(tmp_path / "profile.json")
    prof.write_json(p)
    pr = _load_script("profile_report")
    folded = str(tmp_path / "profile.folded")
    assert pr.main([p, "--collapsed", folded]) == 0
    out = capsys.readouterr().out
    assert "prefill_forward" in out and "compress" in out
    lines = open(folded).read().splitlines()
    assert "prefill_forward;compress 2000000" in lines
    assert "prefill_forward 2000000" in lines


def test_trace_report_json_diffable(lvlm, tmp_path, capsys):
    from repro.obs import Tracer
    tracer = Tracer()
    router = lvlm.serve_cluster(2, _ec(cost=COST), gen=GEN,
                                roles=["prefill", "decode"], obs=tracer)
    got = _drive_all(router, _reqs(_prompts(3, seed=7)))
    assert all(len(o) == MAX_NEW for o in got.values())
    p = str(tmp_path / "events.jsonl")
    tracer.write_jsonl(p)
    tr = _load_script("trace_report")
    assert tr.main([p, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["schema_version"] == 1
    assert doc["requests"] == 3 and doc["aborted"] == 0
    shares = [s["share"] for s in doc["stages"].values()]
    assert sum(shares) == pytest.approx(1.0)
    assert doc["stages"]["kv_migration"]["mean_s"] > 0.0
    # two identical attribution documents diff clean through the gate
    a = str(tmp_path / "a.json")
    json.dump(doc, open(a, "w"))
    assert regress.main([a, a]) == 0
    # and a slower decode stage beyond tolerance fails it
    worse = json.loads(json.dumps(doc))
    for k in ("mean_s", "p50_s", "p95_s"):
        worse["stages"]["decode"][k] = doc["stages"]["decode"][k] * 4.0
    b = str(tmp_path / "b.json")
    json.dump(worse, open(b, "w"))
    assert regress.main([b, a, "--tolerance", "0.5"]) == 1


def test_time_jit_reports_min_mean_std():
    common_spec = importlib.util.spec_from_file_location(
        "bench_common", os.path.join(REPO, "benchmarks", "common.py"))
    common = importlib.util.module_from_spec(common_spec)
    common_spec.loader.exec_module(common)
    import jax.numpy as jnp
    x = jnp.arange(128.0)
    t = common.time_jit(lambda a: (a * 2).sum(), x, warmup=1, iters=4)
    assert isinstance(t, float)
    assert float(t) == t.min_us
    assert t.min_us <= t.mean_us
    assert t.std_us >= 0.0
    stats = t.stats()
    assert set(stats) == {"min", "mean", "std"}
    # the float value formats like the old scalar return (emit() rows)
    assert f"{t:.1f}" == f"{t.min_us:.1f}"
