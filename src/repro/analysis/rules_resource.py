"""R-rules: resource acquire/release pairing over the serving stack.

R001  Release-completeness: the canonical release functions
      (``Engine._release_request``, ``AsyncLVLMServer.abort``,
      ``RouterStream._retire``) must contain EVERY release action in
      the API table -- deleting a single release call (e.g. the
      prefix-pin decrement) is a finding at the function def.
R002  Acquire-reaches-release (per-function CFG walk): for every
      acquire site in the table (slot bind, pin increment, retirement
      append), no path function-entry -> acquire -> exit may avoid all
      matching release/handoff sites. Built on ``cfg.build_cfg``; loops,
      branches, try/except/finally, and early returns are walked.
R003  Module pairing: resources acquired and released in different
      functions by design (server ``_streams``, router ``inflight``,
      admission ``_waiters``) must have at least one matching release
      site somewhere in the module.

The acquire/release API table lives in ``tables.py`` (``RESOURCES``,
``RELEASE_COMPLETENESS``); the runtime sanitizer
(``repro.analysis.sanitizer``) confirms or refutes R-findings with
conservation asserts at engine step boundaries.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.cfg import ENTRY, EXIT, build_cfg, function_defs
from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register
from repro.analysis.tables import RELEASE_COMPLETENESS, RESOURCES


def _suffix_match(path: str, suffixes) -> bool:
    return any(path.endswith(s) for s in suffixes)


@register
class ReleaseCompletenessRule(Rule):
    rule_id = "R001"
    family = "R"
    severity = "error"
    description = ("canonical release function is missing a release "
                   "action from the acquire/release API table")

    def applies(self, path: str) -> bool:
        return _suffix_match(path, {p for p, _ in RELEASE_COMPLETENESS})

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        out: List[Finding] = []
        for (suffix, fn_name), actions in RELEASE_COMPLETENESS.items():
            if not path.endswith(suffix):
                continue
            fns = [f for f in function_defs(tree) if f.name == fn_name]
            if not fns:
                out.append(self.finding(
                    path, 1, f"release function `{fn_name}` not found "
                    "(API table expects it)"))
                continue
            for fn in fns:
                stmts = [n for n in ast.walk(fn) if isinstance(n, ast.stmt)]
                for action in actions:
                    if not any(action.matcher(s) for s in stmts):
                        out.append(self.finding(
                            path, fn.lineno,
                            f"`{fn_name}` is missing release action: "
                            f"{action.name}"))
        return out


@register
class AcquireReleaseCFGRule(Rule):
    rule_id = "R002"
    family = "R"
    severity = "error"
    description = ("an acquire site has a control-flow path to a function "
                   "exit that avoids every matching release/handoff")

    def applies(self, path: str) -> bool:
        suffixes = set()
        for res in RESOURCES:
            if not res.module_pairing:
                suffixes.update(res.path_suffixes)
        return _suffix_match(path, suffixes)

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        out: List[Finding] = []
        for res in RESOURCES:
            if res.module_pairing or not _suffix_match(
                    path, res.path_suffixes):
                continue
            for fn in function_defs(tree):
                if fn.name in res.exempt_functions:
                    continue
                body_stmts = [n for n in ast.walk(fn)
                              if isinstance(n, ast.stmt) and n is not fn]
                acquires = [s for s in body_stmts if res.acquire(s)]
                if not acquires:
                    continue
                ok = set(s for s in body_stmts if res.release(s))
                if res.handoff is not None:
                    ok |= set(s for s in body_stmts if res.handoff(s))
                graph = build_cfg(fn)
                for acq in acquires:
                    if acq not in graph.succ:
                        continue        # nested def: out of this walk
                    reaches_acq = graph.path_avoiding(ENTRY, acq, ok)
                    leaks = graph.path_avoiding(acq, EXIT, ok - {acq})
                    if reaches_acq and leaks:
                        out.append(self.finding(
                            path, acq.lineno,
                            f"resource `{res.rid}` acquired here can reach "
                            f"a function exit of `{fn.name}` without a "
                            f"matching release ({res.description})"))
        return out


@register
class ModulePairingRule(Rule):
    rule_id = "R003"
    family = "R"
    severity = "error"
    description = ("a module acquires a handed-off resource but contains "
                   "no matching release site")

    def applies(self, path: str) -> bool:
        suffixes = set()
        for res in RESOURCES:
            if res.module_pairing:
                suffixes.update(res.path_suffixes)
        return _suffix_match(path, suffixes)

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        out: List[Finding] = []
        stmts = [n for n in ast.walk(tree) if isinstance(n, ast.stmt)]
        for res in RESOURCES:
            if not res.module_pairing or not _suffix_match(
                    path, res.path_suffixes):
                continue
            acquires = [s for s in stmts if res.acquire(s)]
            if acquires and not any(res.release(s) for s in stmts):
                out.append(self.finding(
                    path, acquires[0].lineno,
                    f"resource `{res.rid}` is acquired in this module but "
                    f"never released here ({res.description})"))
        return out
