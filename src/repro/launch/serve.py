"""Serving driver: the taxonomy engine end-to-end on synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-vl-2b --smoke \
        --requests 16 --scheduler chunked --pruner divprune --keep 0.5
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.base import CompressionConfig
from repro.core.serving import Engine, EngineConfig, Request
from repro.models.registry import build


def synth_requests(cfg, n, *, seed=0, prompt_lo=16, prompt_hi=48,
                   new_tokens=16, shared_prefix=0):
    rng = np.random.RandomState(seed)
    shared = list(rng.randint(1, cfg.vocab_size,
                              size=shared_prefix)) if shared_prefix else []
    reqs = []
    for i in range(n):
        toks = shared + list(rng.randint(
            1, cfg.vocab_size, size=rng.randint(prompt_lo, prompt_hi)))
        ve = None
        if cfg.family == "vlm":
            ve = rng.randn(cfg.num_visual_tokens, cfg.d_model).astype(
                np.float32) * 0.02
        reqs.append(Request(rid=i, tokens=toks, max_new_tokens=new_tokens,
                            visual_embeds=ve, arrival=i * 0.01))
    return reqs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-vl-2b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--scheduler", default="continuous",
                    choices=("static", "continuous", "mlfq", "chunked"))
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--shared-prefix", type=int, default=0)
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--pruner", default="none")
    ap.add_argument("--keep", type=float, default=1.0)
    ap.add_argument("--kv-selector", default="none")
    ap.add_argument("--kv-budget", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower/compile decode_32k under the production mesh")
    args = ap.parse_args()

    if args.dry_run:
        import os
        import subprocess
        import sys
        return subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", args.arch, "--shape", "decode_32k"],
            env=dict(os.environ, PYTHONPATH="src"))

    cfg = get_config(args.arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ec = EngineConfig(
        max_batch=args.max_batch, cache_len=args.cache_len,
        scheduler=args.scheduler, temperature=args.temperature,
        prefix_cache=args.prefix_cache,
        compression=CompressionConfig(
            token_pruner=args.pruner, keep_ratio=args.keep,
            kv_selector=args.kv_selector, kv_budget=args.kv_budget))
    eng = Engine(model, params, ec)
    for r in synth_requests(cfg, args.requests,
                            new_tokens=args.new_tokens,
                            shared_prefix=args.shared_prefix):
        eng.submit(r)
    out = eng.run()
    print(json.dumps({k: v for k, v in out.items()
                      if not isinstance(v, (list, dict))}, indent=1,
                     default=float))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
