"""``repro.obs`` -- zero-overhead-when-off observability.

Per-request lifecycle tracing (``Tracer`` -- dual virtual/wall clocks,
one contiguous trace per request across the prefill->decode migration
boundary), Chrome-trace/Perfetto export (``perfetto``), Prometheus text
metric snapshots (``prom``), shared summary statistics (``stats``), and
trace validation (``python -m repro.obs.validate``).

Enable via the facade: ``lvlm.serve_async(..., obs=True)`` or pass a
``Tracer``; disabled (the default) the stack holds ``NULL_TRACER`` and
every instrumentation site short-circuits on ``tracer.enabled``.
"""
from repro.obs.perfetto import to_chrome_trace, write_chrome_trace
from repro.obs.profile import (NULL_PROFILER, NullProfiler, Profiler,
                               profile_families)
from repro.obs.stats import (mean_or_none, percentile_summary,
                             summarize_records)
from repro.obs.trace import NULL_TRACER, JsonlSink, NullTracer, Tracer
from repro.obs.validate import load_trace, validate_trace

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "JsonlSink",
    "Profiler", "NullProfiler", "NULL_PROFILER", "profile_families",
    "to_chrome_trace", "write_chrome_trace",
    "summarize_records", "percentile_summary", "mean_or_none",
    "load_trace", "validate_trace",
]
