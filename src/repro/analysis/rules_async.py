"""A-rules: async hazards in the serving/cluster pumps.

A001  Blocking call (``time.sleep``, sync subprocess, sync HTTP) inside
      ``async def`` -- stalls the event loop; the pump must use
      ``asyncio.sleep`` / executors.
A002  Shared mutable serving state (``_streams``, ``_waiters``,
      ``inflight``, engine queues -- see ``tables.SHARED_STATE_ATTRS``)
      read before an ``await`` and written after it in one async
      function: the await is a suspension point, another task may have
      mutated the structure in between. Deliberate, safe cases carry a
      ``# analysis: atomic-step`` fence on the write (documented
      evidence the re-read/idempotence was considered).
A003  Fire-and-forget ``create_task`` / ``ensure_future``: the returned
      task is dropped, so its exceptions vanish and it is collectable
      mid-flight; keep a reference or add a done-callback.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.findings import Finding, fence_lines
from repro.analysis.registry import Rule, register
from repro.analysis.tables import (BLOCKING_CALLS, MUTATING_METHODS,
                                   SHARED_STATE_ATTRS)


def _dotted(node: ast.expr) -> str:
    """Best-effort dotted name of a call target ('time.sleep', 'sleep')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _async_defs(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, ast.AsyncFunctionDef):
            yield node


@register
class BlockingCallRule(Rule):
    rule_id = "A001"
    family = "A"
    severity = "error"
    description = "blocking call inside async def stalls the event loop"

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        # names imported `from time import sleep`-style
        bare: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                for mod, name in BLOCKING_CALLS:
                    if node.module == mod:
                        for alias in node.names:
                            if alias.name == name:
                                bare[alias.asname or name] = f"{mod}.{name}"
        dotted = {f"{m}.{n}" for m, n in BLOCKING_CALLS}
        out: List[Finding] = []
        for fn in _async_defs(tree):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                target = _dotted(node.func)
                hit = target if target in dotted else bare.get(target)
                if hit:
                    out.append(self.finding(
                        path, node.lineno,
                        f"blocking `{hit}` inside async `{fn.name}`; use "
                        "asyncio.sleep / run_in_executor"))
        return out


@register
class AwaitSpanningMutationRule(Rule):
    rule_id = "A002"
    family = "A"
    severity = "warning"
    description = ("shared mutable state read before and written after an "
                   "await without an `# analysis: atomic-step` fence")

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        fences = fence_lines(src)
        out: List[Finding] = []
        for fn in _async_defs(tree):
            if fn.lineno in fences:
                continue                      # whole function fenced
            awaits = [n.lineno for n in ast.walk(fn)
                      if isinstance(n, ast.Await)]
            if not awaits:
                continue
            reads: Dict[str, List[int]] = {}
            writes: Dict[str, List[Tuple[int, int]]] = {}
            self._collect(fn, reads, writes)
            for attr, wlist in writes.items():
                for wline, _ in wlist:
                    if wline in fences:
                        continue
                    hazard = any(
                        r < a <= wline
                        for a in awaits for r in reads.get(attr, ()))
                    if hazard:
                        out.append(self.finding(
                            path, wline,
                            f"`{attr}` read before an await and mutated "
                            f"after it in async `{fn.name}`; re-check state "
                            "after suspension or fence with "
                            "`# analysis: atomic-step (why it is safe)`"))
                        break                 # one finding per attr per fn
        return out

    @staticmethod
    def _collect(fn: ast.AsyncFunctionDef, reads, writes) -> None:
        for node in ast.walk(fn):
            # attribute loads
            if isinstance(node, ast.Attribute) \
                    and node.attr in SHARED_STATE_ATTRS:
                if isinstance(node.ctx, ast.Load):
                    reads.setdefault(node.attr, []).append(node.lineno)
                else:
                    writes.setdefault(node.attr, []).append(
                        (node.lineno, node.col_offset))
            # subscript stores on a shared attr: self._streams[k] = v
            if isinstance(node, ast.Subscript) \
                    and isinstance(node.value, ast.Attribute) \
                    and node.value.attr in SHARED_STATE_ATTRS \
                    and not isinstance(node.ctx, ast.Load):
                writes.setdefault(node.value.attr, []).append(
                    (node.lineno, node.col_offset))
            # mutating method calls: self._waiters.remove(...)
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in MUTATING_METHODS \
                    and isinstance(node.func.value, ast.Attribute) \
                    and node.func.value.attr in SHARED_STATE_ATTRS:
                writes.setdefault(node.func.value.attr, []).append(
                    (node.lineno, node.col_offset))


@register
class FireAndForgetTaskRule(Rule):
    rule_id = "A003"
    family = "A"
    severity = "warning"
    description = ("create_task/ensure_future result dropped "
                   "(exceptions vanish; task is collectable mid-flight)")

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Expr):
                continue
            call = node.value
            if isinstance(call, ast.Call):
                name = _dotted(call.func)
                if name.endswith("create_task") \
                        or name.endswith("ensure_future"):
                    out.append(self.finding(
                        path, node.lineno,
                        "fire-and-forget task: keep the handle (or "
                        "add_done_callback) so failures surface"))
        return out
