"""``repro.analysis`` -- architecture lint, resource-pairing and
async-hazard checker, plus the runtime sanitizer the checks feed into.

The repo's layering and resource-lifecycle rules (ROADMAP "Standing
layering rules"; the acquire/release discipline PRs 2-5 grew around slot
pools, draft rows, gamma reservations, and prefix pins) were enforced
only by convention and after-the-fact tests. This subsystem makes them
machine-checked:

  * **L-rules** (layering): ``repro.core.*`` stays internal -- no core
    imports outside ``src/repro``; ``EngineConfig.compression`` is never
    mutated outside the facade; ``Engine`` is constructed only behind
    the ``LVLM`` facade.
  * **R-rules** (resource pairing): every slot / draft-row / gamma /
    prefix-pin acquire site in the engine, server, and router must be
    paired with a matching release -- checked with a per-function CFG
    walk over the known acquire/release API table (``tables.py``), plus
    a release-completeness check on ``Engine._release_request`` and the
    other canonical release functions.
  * **A-rules** (async hazards): blocking calls inside ``async def``
    pumps; shared mutable server/router state read before and written
    after an ``await`` without a documented ``# analysis: atomic-step``
    fence; fire-and-forget ``create_task``.
  * **K-rules** (Pallas kernels): index_map arity vs grid (+ scalar
    prefetch), kernel-signature ref counts vs specs, literal grid x
    block divisibility, and output-ref stores without an explicit
    ``astype`` (dtype hazards).

CLI::

    PYTHONPATH=src python -m repro.analysis [--rules L001,R002] [paths]
    PYTHONPATH=src python -m repro.analysis --fail-on-regression \
        --baseline analysis_baseline.json
    PYTHONPATH=src python -m repro.analysis --write-baseline

Findings carry rule id, severity, and file:line. A committed baseline
(``analysis_baseline.json``) waives pre-existing findings so CI fails
only on regressions; per-line waivers use ``# analysis: allow L001
(reason)``.

The runtime half (``repro.analysis.sanitizer``) is wired into
``Engine.step`` and the ``AsyncLVLMServer`` pump via
``EngineConfig.sanitize`` / ``REPRO_SANITIZE=1``: conservation asserts
(kv committed == sum of live reservations, draft-pool bound rows subset
of live slots, prefix pins == live pinning requests) confirm or refute
R-rule findings with runtime evidence.
"""
from repro.analysis.findings import (Baseline, Finding, parse_waivers)
from repro.analysis.registry import ALL_RULES, RULE_FAMILIES, select_rules
from repro.analysis.runner import (DEFAULT_PATHS, analyze_file,
                                   analyze_source, run_analysis)
from repro.analysis.sanitizer import (SanitizerError, check_engine_conservation,
                                      check_server_conservation,
                                      sanitize_enabled)

__all__ = [
    "Finding", "Baseline", "parse_waivers",
    "ALL_RULES", "RULE_FAMILIES", "select_rules",
    "analyze_source", "analyze_file", "run_analysis", "DEFAULT_PATHS",
    "SanitizerError", "check_engine_conservation",
    "check_server_conservation", "sanitize_enabled",
]
