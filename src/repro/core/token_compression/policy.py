"""Compression policy: maps CompressionConfig -> a callable applied to the
visual token stream before (encoder-side) or inside (decoder-side) the
backbone.

Two layers live here:

  * ``compress_visual_tokens`` -- the stateless library entry point over
    the pruners/mergers (what the examples and the standalone drivers use).
  * ``CompressionStrategy``    -- the FIRST-CLASS strategy object the
    serving engine dispatches per request (the dim-1/2a mirror of the
    decoder hook): every request may carry its own strategy
    (``Request.compression``), resolved against the engine's compressor
    registry exactly like ``Request.decoder``.

Strategy protocol (duck-typed; ``CompressionStrategy`` is the config-backed
reference implementation):

    name                        -- registry key (``Request.compression``)
    encoder_active              -- bool: run ``compress_prefill`` at all?
    compress_prefill(embeds, *, query=None, scores=None)
                                -- encoder-side hook, [B,N,d] ->
                                   (compressed, kept_idx | None, info)
    compressed_token_count(n)   -- EXACT post-compression count for n
                                   visual tokens (KV accounting: admission
                                   watermarks / ``kv_request_tokens`` must
                                   never run the pruner to size a request)
    decode_budget()             -- optional KV-side hook: tokens to compact
                                   each slot to after prefill (None = no
                                   live KV compaction)
    kv_selector                 -- selector name for ``decode_budget``
    validate(engine)            -- optional, run on first use
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CompressionConfig
from repro.core.token_compression import merging, pruning

#: selectors the engine can run live post-prefill (attention-free;
#: attention-score selectors stay library-level -- survey §V)
LIVE_KV_SELECTORS = ("l2", "streaming")


def compress_visual_tokens(cc: CompressionConfig, embeds, *,
                           query=None, scores=None
                           ) -> Tuple[jax.Array, Optional[jax.Array], Dict]:
    """Apply the configured encoder-side compressor.

    embeds [B,N,d]; query [B,Q,d] (text embeddings) for cross-modal
    pruners; scores [B,N] externally computed salience (e.g. encoder
    attention for PruMerge/VisionZip-style reduction).

    Returns (compressed, kept_idx or None, info).
    """
    n = embeds.shape[1]
    keep = max(1, int(round(n * cc.keep_ratio)))
    if cc.keep_ratio >= 1.0 and cc.token_merger == "none":
        return embeds, None, {"keep": n, "method": "none"}

    if cc.token_merger == "tome":
        out, sizes = merging.tome_to_count(embeds, keep)
        return out, None, {"keep": out.shape[1], "method": "tome"}
    if cc.token_merger == "framefusion":
        out, idx, info = merging.prune_then_merge(embeds, keep, scores=scores)
        return out, idx, {"method": "prune+merge", **info}

    if cc.token_pruner == "none":
        return embeds, None, {"keep": n, "method": "none"}
    if cc.token_pruner == "fastv" and scores is None:
        # the scanned production path never materializes attention matrices
        # (survey §V), so score-free callers (the engine) use the L2-norm
        # salience proxy: low-norm keys receive high attention [L2Compress]
        scores = -jnp.linalg.norm(embeds, axis=-1)
    fn = pruning.PRUNERS[cc.token_pruner]
    out, idx, info = fn(embeds, keep, scores=scores, query=query)
    return out, idx, {"keep": keep, "method": cc.token_pruner, **info}


def fastv_scores_from_attention(attn_probs, visual_slice) -> jax.Array:
    """FastV salience from a decoder layer's attention probabilities.

    attn_probs [B, H, Sq, Sk]; visual_slice = (start, stop) of the visual
    tokens inside the key axis. Score = mean over heads and queries of the
    attention each visual key receives.
    """
    start, stop = visual_slice
    return attn_probs[..., start:stop].mean(axis=(1, 2))


def compressed_token_count(cc: CompressionConfig, n: int) -> int:
    """EXACT number of tokens ``compress_visual_tokens(cc, [*, n, d])``
    returns, computed shape-only.

    KV accounting (admission watermarks, ``Engine.kv_request_tokens``,
    ``least_kv`` routing) sizes requests with this instead of the FULL
    visual count, so compressed requests stop over-reserving pool tokens
    -- and it must never have to run the pruner to know the answer.
    """
    keep = max(1, int(round(n * cc.keep_ratio)))
    if cc.keep_ratio >= 1.0 and cc.token_merger == "none":
        return n
    if cc.token_merger == "tome":
        # mirror merging.tome_to_count's capped-r loop (max_r_ratio=0.4)
        m = n
        while m > keep:
            m -= min(m - keep, max(1, int((m // 2) * 0.4)))
        return m
    if cc.token_merger == "framefusion":
        return keep
    if cc.token_pruner == "none":
        return n
    return keep


def _derive_name(cc: CompressionConfig) -> str:
    """Canonical strategy name for a config -- matches the parametric
    preset grammar (``repro.api.generation.resolve_compression``), so a
    default built from ``EngineConfig.compression`` and a per-request
    name like ``"fastv-0.5"`` resolve to the SAME registry entry."""
    if cc.token_pruner != "none":
        return f"{cc.token_pruner}-{cc.keep_ratio:g}"
    if cc.token_merger != "none":
        return f"{cc.token_merger}-{cc.keep_ratio:g}"
    if cc.kv_selector in LIVE_KV_SELECTORS and cc.kv_budget > 0:
        return f"{cc.kv_selector}-kv-{cc.kv_budget}"
    return "none"


class CompressionStrategy:
    """Config-backed compression strategy (see the module docstring for
    the protocol). Wraps the existing pruners/mergers behind the engine's
    per-request dispatch; richer strategies (learned budgets, per-layer
    schedules) duck-type the same surface."""

    def __init__(self, cc: Optional[CompressionConfig] = None,
                 name: Optional[str] = None):
        self.cc = cc if cc is not None else CompressionConfig()
        self.name = name if name is not None else _derive_name(self.cc)

    def __repr__(self) -> str:
        return f"CompressionStrategy({self.name!r})"

    # -------------------------------------------------- encoder side --
    @property
    def encoder_active(self) -> bool:
        """Whether ``compress_prefill`` does anything (the engine skips
        the hook entirely for KV-only / no-op strategies)."""
        return (self.cc.token_pruner != "none"
                or self.cc.token_merger != "none")

    @property
    def needs_query(self) -> bool:
        """Whether ``compress_prefill`` consumes the text ``query``
        embeddings -- only the cross-modal pruners do; the engine skips
        building the query for everything else (prefill hot path)."""
        return self.cc.token_pruner in ("sparsevlm", "cdpruner")

    def compress_prefill(self, embeds, *, query=None, scores=None
                         ) -> Tuple[jax.Array, Optional[jax.Array], Dict]:
        """Encoder-side hook: compress [B, N, d] visual embeddings before
        they enter the backbone. ``query`` [B, Q, d] carries the TEXT
        prompt embeddings so cross-modal pruners (sparsevlm / cdpruner)
        rank by instruction relevance."""
        return compress_visual_tokens(self.cc, embeds, query=query,
                                      scores=scores)

    def compressed_token_count(self, n: int) -> int:
        return compressed_token_count(self.cc, n)

    # ------------------------------------------------------- KV side --
    @property
    def kv_selector(self) -> str:
        return self.cc.kv_selector

    def decode_budget(self) -> Optional[int]:
        """KV-side hook: live post-prefill compaction budget (tokens per
        slot), or None when this strategy does not compact."""
        if self.cc.kv_selector in LIVE_KV_SELECTORS and self.cc.kv_budget:
            return self.cc.kv_budget
        return None

    def validate(self, eng) -> None:
        """First-use check against the engine (mirrors decoder
        validation): live KV compaction needs the windowed, position-exact
        cache the engine only builds when its DEFAULT strategy compacts --
        per-request overrides cannot retrofit it."""
        if self.decode_budget() is not None \
                and not getattr(eng, "compacting", False):
            raise ValueError(
                f"compression strategy {self.name!r} needs live KV "
                "compaction, but the engine was not built compacting; "
                "set the engine DEFAULT (EngineConfig.compression or the "
                "facade's GenerationConfig.compression) to a kv preset")
