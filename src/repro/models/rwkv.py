"""RWKV-6 "Finch" block (attention-free; data-dependent decay). [arXiv:2404.05892]

Time-mix with dynamic data-dependent decay w_t (the Finch signature) and
per-head bonus u; channel-mix with squared-ReLU. The decode state is O(1):
one token-shift vector per mix + the [H, hd, hd] wkv state per layer --
there is NO KV cache, which is why the survey's attention-score KV
techniques are marked inapplicable for this arch (DESIGN.md §3).

Full-sequence path: lax.scan over time (the recurrence is inherently
sequential; chunk-parallel forms exist but the scan keeps the HLO compact
and the state math identical to decode).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, spec

_LORA_R = 32   # decay-LoRA rank (scaled-down faithful default 64)


def _dims(cfg):
    nheads = cfg.d_model // cfg.ssm_head_dim
    return nheads, cfg.ssm_head_dim


def rwkv_specs(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    nheads, hd = _dims(cfg)
    tm = {
        # static token-shift lerp coefficients per stream
        "mu_r": spec((d,), ("embed",), init="zeros"),
        "mu_k": spec((d,), ("embed",), init="zeros"),
        "mu_v": spec((d,), ("embed",), init="zeros"),
        "mu_w": spec((d,), ("embed",), init="zeros"),
        "mu_g": spec((d,), ("embed",), init="zeros"),
        "w_r": spec((d, d), ("embed", "heads_flat")),
        "w_k": spec((d, d), ("embed", "heads_flat")),
        "w_v": spec((d, d), ("embed", "heads_flat")),
        "w_g": spec((d, d), ("embed", "heads_flat")),
        "w_o": spec((d, d), ("heads_flat", "embed")),
        # data-dependent decay: w_t = exp(-exp(w0 + lora(x_w)))
        "w0": spec((d,), ("embed",), init="zeros"),
        "w_lora_a": spec((d, _LORA_R), ("embed", None)),
        "w_lora_b": spec((_LORA_R, d), (None, "embed"), scale=0.01),
        "u": spec((nheads, hd), ("heads", None), init="zeros"),
        "ln_scale": spec((d,), ("embed",), init="ones"),
        "ln_bias": spec((d,), ("embed",), init="zeros"),
    }
    cm = {
        "mu_k": spec((d,), ("embed",), init="zeros"),
        "mu_r": spec((d,), ("embed",), init="zeros"),
        "w_k": spec((d, cfg.d_ff), ("embed", "ffn")),
        "w_v": spec((cfg.d_ff, d), ("ffn", "embed")),
        "w_r": spec((d, d), ("embed", "embed_out")),
    }
    return {"time_mix": tm, "channel_mix": cm}


def rwkv_cache_specs(cfg, batch: int):
    nheads, hd = _dims(cfg)
    return {
        "tm_shift": spec((batch, cfg.d_model), ("batch", "embed"), init="zeros"),
        "cm_shift": spec((batch, cfg.d_model), ("batch", "embed"), init="zeros"),
        "wkv": spec((batch, nheads, hd, hd), ("batch", "heads", None, None),
                    init="zeros", dtype="float32"),
    }


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _time_mix_streams(p, x, x_prev, cfg):
    """Project the five streams; returns r,k,v,g [.. ,H,hd], w decay [..,H,hd]."""
    nheads, hd = _dims(cfg)
    r = _lerp(x, x_prev, p["mu_r"]) @ p["w_r"]
    k = _lerp(x, x_prev, p["mu_k"]) @ p["w_k"]
    v = _lerp(x, x_prev, p["mu_v"]) @ p["w_v"]
    g = _lerp(x, x_prev, p["mu_g"]) @ p["w_g"]
    xw = _lerp(x, x_prev, p["mu_w"])
    w_dyn = (xw @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp((p["w0"] + w_dyn).astype(jnp.float32)))  # (0,1)
    shp = x.shape[:-1] + (nheads, hd)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            g.reshape(shp), w.reshape(shp))


def _wkv_step(state, r, k, v, w, u):
    """state [B,H,hd,hd] (k-major); one token. Returns (y, new_state)."""
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]            # [B,H,hd,hd]
    y = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32),
                   state + u[None, :, :, None] * kv)
    new_state = w[..., :, None] * state + kv
    return y, new_state


def _group_norm(y, scale, bias, eps=1e-5):
    """Per-head LayerNorm over the last dim; y [..., H, hd]."""
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + eps)
    return y * scale + bias


def time_mix_forward(p, x, cfg, state=None) -> Tuple[jax.Array, Dict]:
    """x [B,T,d] full-sequence scan. state: {"tm_shift","wkv"} or None."""
    b, t, d = x.shape
    nheads, hd = _dims(cfg)
    x_prev_seq = jnp.concatenate(
        [(state["tm_shift"][:, None] if state is not None
          else jnp.zeros((b, 1, d), x.dtype)), x[:, :-1]], axis=1)
    r, k, v, g, w = _time_mix_streams(p, x, x_prev_seq, cfg)
    u = p["u"].astype(jnp.float32)

    init = (state["wkv"].astype(jnp.float32) if state is not None
            else jnp.zeros((b, nheads, hd, hd), jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp
        y, s = _wkv_step(s, rt, kt, vt, wt, u)
        return s, y

    final, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
         jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0)))
    ys = jnp.moveaxis(ys, 0, 1)                          # [B,T,H,hd]
    ys = _group_norm(ys, p["ln_scale"].reshape(nheads, hd),
                     p["ln_bias"].reshape(nheads, hd))
    ys = ys.reshape(b, t, d) * jax.nn.silu(g.reshape(b, t, d).astype(jnp.float32))
    out = (ys.astype(x.dtype) @ p["w_o"])
    new_state = {"tm_shift": x[:, -1], "wkv": final}
    return out, new_state


def channel_mix_forward(p, x, cfg, state=None) -> Tuple[jax.Array, Dict]:
    b, t, d = x.shape
    x_prev = jnp.concatenate(
        [(state["cm_shift"][:, None] if state is not None
          else jnp.zeros((b, 1, d), x.dtype)), x[:, :-1]], axis=1)
    k = _lerp(x, x_prev, p["mu_k"]) @ p["w_k"]
    k = jnp.square(jax.nn.relu(k))
    r = jax.nn.sigmoid(_lerp(x, x_prev, p["mu_r"]) @ p["w_r"])
    out = r * (k @ p["w_v"])
    return out, {"cm_shift": x[:, -1]}


# Layer assembly (pre-norms + residuals) lives in models/transformer.py;
# the mixes are exposed separately so the norm'd streams drive the shift
# states identically in prefill and decode.
