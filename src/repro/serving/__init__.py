"""``repro.serving`` -- async streaming serving on top of the grouped engine.

This is the layer where the survey's accelerations meet open-loop traffic:
instead of the closed ``Engine.run()`` batch, an ``AsyncLVLMServer`` pumps
the engine's iteration loop in the background and exposes each request as
an independent async token channel, so millions-of-users-style workloads
(requests arriving over time, clients consuming tokens as they stream,
some hanging up mid-generation) are served with per-request SLO telemetry.

Architecture (three small planes over one Engine):

  server.py    ``AsyncLVLMServer`` -- the asyncio pump. One background
               task repeatedly calls ``Engine.step()`` (each step is a
               fixed-shape jitted iteration over the whole slot pool,
               grouped by decode strategy) and fans newly emitted tokens
               out to per-request ``TokenStream`` queues:

                   server = lvlm.serve_async(EngineConfig(...))
                   async with server:
                       async for tok in server.submit(req):
                           ...                       # streams as decoded
                       stream.cancel()               # mid-stream abort ->
                                                     # Engine.abort(rid)

               Cancellation is a full lifecycle event: the engine frees
               the request's main KV slot, its speculative draft-pool
               slot, the reserved ``gamma`` lookahead, and any
               prefix-cache pin -- pool accounting returns to baseline.
               Everything runs on ONE event loop (the jitted step holds
               the GIL regardless); the win is request multiplexing and
               backpressure, not compute parallelism.
               ``pacing="wall"`` sleeps each step's virtual duration in
               real time (x ``pacing_scale``); ``disconnect_timeout_s``
               aborts streams whose consumer stopped reading (same
               resource release as an explicit cancel).

  admission.py ``AdmissionController`` -- high/low KV watermarks with
               hysteresis over ``Engine.kv_committed_tokens()`` (block-
               rounded prompt + max_new + decode lookahead per live
               request). A submit that would push the pool past the high
               watermark AWAITS in a FIFO queue instead of crashing the
               engine (the paged pool's ``OutOfBlocksError`` failure mode);
               waiters drain once usage falls below the low watermark --
               strictly in order, FIFO or SLO-slack
               (``AdmissionConfig(order="slack")``: earliest TTFT
               deadline minus live expected TTFT first, starvation-free).

  metrics.py   ``MetricsRegistry`` -- per-request TTFT / TPOT / JCT /
               queue-wait records against the engine's deterministic
               virtual clock, percentile summaries (p50/p95/p99), SLO
               attainment fractions (per-request ``Request.slo`` targets),
               abort counts, and the engine's per-decoder-group
               virtual-clock decode cost.

The sync path (``LVLM.serve``) and this async path share the same Engine,
schedulers, decoder strategies, and clock -- at temperature 0 the async
server's streams are bit-identical to the sync facade's outputs
(locked down by ``tests/test_async_serving.py``).
"""
from repro.serving.admission import AdmissionConfig, AdmissionController
from repro.serving.metrics import MetricsRegistry, RequestRecord
from repro.serving.server import AsyncLVLMServer, TokenStream

__all__ = [
    "AsyncLVLMServer", "TokenStream",
    "AdmissionConfig", "AdmissionController",
    "MetricsRegistry", "RequestRecord",
]
