"""Token sampling (decode substrate).

Pure functions over logits [B, V]; all jit-friendly. ``sample_token`` is the
single dispatch the engine and the speculative-decoding verifier share, so
draft and target distributions are computed by the same code path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _mask_top_k(logits: jax.Array, k: int) -> jax.Array:
    kth = jax.lax.top_k(logits, k)[0][..., -1:]
    return jnp.where(logits < kth, -jnp.inf, logits)


def _mask_top_p(logits: jax.Array, p: float) -> jax.Array:
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # smallest set whose mass >= p (always keep the argmax)
    cutoff_idx = jnp.sum(cum < p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    return jnp.where(logits < cutoff, -jnp.inf, logits)


def temperature_sample(key, logits, temperature: float = 1.0) -> jax.Array:
    return jax.random.categorical(key, logits / max(temperature, 1e-6)
                                  ).astype(jnp.int32)


def top_k_sample(key, logits, k: int, temperature: float = 1.0) -> jax.Array:
    return temperature_sample(key, _mask_top_k(logits, k), temperature)


def top_p_sample(key, logits, p: float, temperature: float = 1.0) -> jax.Array:
    return temperature_sample(key, _mask_top_p(logits, p), temperature)


def sample_probs(logits, *, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 0.0) -> jax.Array:
    """The (post-warp) categorical the sampler draws from; used by the
    speculative verifier, which needs explicit draft/target probabilities."""
    if temperature <= 0.0:
        onehot = jax.nn.one_hot(jnp.argmax(logits, -1), logits.shape[-1])
        return onehot
    l = logits / temperature
    if top_k:
        l = _mask_top_k(l, top_k)
    if top_p:
        l = _mask_top_p(l, top_p)
    return jax.nn.softmax(l, axis=-1)


def sample_token(key: Optional[jax.Array], logits, *, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 0.0) -> jax.Array:
    """Dispatch: temperature<=0 -> greedy; else warped categorical."""
    if temperature <= 0.0:
        return greedy(logits)
    l = logits
    if top_k:
        l = _mask_top_k(l, top_k)
    if top_p:
        l = _mask_top_p(l, top_p)
    return temperature_sample(key, l, temperature)
