"""Model registry: ``build(cfg)`` -> Model (assembly in transformer.py)."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.transformer import Model

_FAMILIES = ("dense", "moe", "vlm", "ssm", "hybrid", "audio")


def build(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILIES:
        raise ValueError(f"unknown family {cfg.family!r}")
    return Model(cfg)
