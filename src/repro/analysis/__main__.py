"""CLI: ``PYTHONPATH=src python -m repro.analysis [--rules ...] [paths]``.

Exit code 0 when no non-baselined findings; 1 otherwise (so CI's
``--fail-on-regression`` is the default behavior, the flag documents
intent). ``--write-baseline`` accepts the current findings as debt.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.findings import Baseline
from repro.analysis.registry import rule_table
from repro.analysis.runner import (DEFAULT_BASELINE, run_analysis)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="architecture lint / resource-pairing / async-hazard "
                    "/ kernel checks (L/R/A/K rule families)")
    ap.add_argument("paths", nargs="*",
                    help="files or directories (default: src benchmarks "
                         "examples scripts)")
    ap.add_argument("--rules", default="all",
                    help="comma list of rule ids and/or families "
                         "(e.g. L001,R or 'all')")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON of accepted findings "
                         "(default: analysis_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current full finding set to the "
                         "baseline file and exit 0")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 on non-baselined findings (this is "
                         "already the default; flag documents CI intent)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in rule_table():
            print(f"{r['id']}  [{r['family']}/{r['severity']:7s}] "
                  f"{r['description']}")
        return 0

    rules = None if args.rules == "all" \
        else [r for r in args.rules.split(",") if r]
    baseline = None if args.no_baseline else args.baseline
    report = run_analysis(paths=args.paths or None, rules=rules,
                          baseline=None if args.write_baseline
                          else baseline)

    if args.write_baseline:
        Baseline(report.findings).save(args.baseline)
        print(f"wrote {len(report.findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    if args.format == "json":
        print(json.dumps({
            "findings": [f.to_json() for f in report.findings],
            "baselined": len(report.baselined),
            "files_checked": report.files_checked,
        }, indent=2))
    else:
        print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
