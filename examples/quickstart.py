"""Quickstart: build an LVLM, train a few steps, then serve requests
through the taxonomy engine -- the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import CompressionConfig
from repro.core.serving import Engine, EngineConfig, Request
from repro.models import build
from repro.training import (OptimizerConfig, SyntheticDataConfig,
                            train_loop)


def main():
    # 1. pick an assigned architecture (reduced smoke variant for CPU)
    cfg = get_config("qwen2-vl-2b", smoke=True).with_(vocab_size=512)
    model = build(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={model.cfg.param_count() / 1e6:.1f}M")

    # 2. train a few steps on the synthetic multimodal pipeline
    out = train_loop(
        model,
        oc=OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=30),
        dc=SyntheticDataConfig(batch=4, seq_len=32),
        num_steps=30, log_every=10)
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}")

    # 3. serve it: continuous batching + FastV-style visual pruning
    eng = Engine(model, out["params"], EngineConfig(
        max_batch=4, cache_len=128, scheduler="continuous",
        compression=CompressionConfig(token_pruner="divprune",
                                      keep_ratio=0.5)))
    rng = np.random.RandomState(0)
    for i in range(6):
        eng.submit(Request(
            rid=i,
            tokens=list(rng.randint(1, cfg.vocab_size, size=12)),
            visual_embeds=rng.randn(cfg.num_visual_tokens,
                                    cfg.d_model).astype(np.float32) * 0.02,
            max_new_tokens=8))
    stats = eng.run()
    print(f"served {stats['finished']} requests, "
          f"{stats['tokens']} tokens, "
          f"throughput {stats['throughput_tok_per_s']:.0f} tok/s (virtual)")
    print("generated:", eng.finished[0].generated)


if __name__ == "__main__":
    main()
