"""Visual token pruning (survey dim 1a).

All pruners share one signature:

    prune(embeds, keep, *, scores=None, query=None, key=None)
        embeds : [B, N, d]  visual token embeddings
        keep   : int        number of tokens to retain
        -> (kept_embeds [B, keep, d], kept_idx [B, keep] int32, info dict)

``kept_idx`` is always sorted ascending so downstream positional encodings
stay monotone (the survey's §V RoPE-decay caveat).

Implemented (each cites its surveyed source):
  * fastv        -- attention-score pruning after layer k [FastV]
  * sparsevlm    -- query-conditioned cross-modal relevance [SparseVLM/TRIM]
  * l2           -- low L2-norm keys ~ high attention proxy [L2Compress];
                    attention-free, applicable to SSM backbones (DESIGN §3)
  * divprune     -- Max-Min Diversity Problem greedy 2-approximation [DivPrune]
  * cdpruner     -- conditional-diversity DPP greedy MAP [CDPruner]
  * pyramiddrop  -- progressive multi-stage schedule helper [PyramidDrop]
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Out = Tuple[jax.Array, jax.Array, Dict]


def _take(embeds, idx):
    return jnp.take_along_axis(embeds, idx[..., None], axis=1)


def _topk_sorted(scores, keep) -> jax.Array:
    """Top-``keep`` indices, returned in ascending positional order."""
    _, idx = jax.lax.top_k(scores, keep)
    return jnp.sort(idx, axis=-1).astype(jnp.int32)


# --------------------------------------------------------------------------

def prune_fastv(embeds, keep, *, scores, **_) -> Out:
    """FastV: keep visual tokens with highest received attention.

    ``scores`` [B, N]: mean attention each visual token receives from all
    queries at the pruning layer (layer 2 in the paper). Task-agnostic --
    its failure mode on fine-grained prompts is what SparseVLM fixes.
    """
    idx = _topk_sorted(scores, keep)
    return _take(embeds, idx), idx, {"criterion": "attn"}


def prune_sparsevlm(embeds, keep, *, query, **_) -> Out:
    """SparseVLM/TRIM: rank by relevance to the user query.

    ``query`` [B, Q, d] text-token embeddings; relevance = max cosine
    similarity of each visual token to any query token.
    """
    v = embeds / (jnp.linalg.norm(embeds, axis=-1, keepdims=True) + 1e-6)
    q = query / (jnp.linalg.norm(query, axis=-1, keepdims=True) + 1e-6)
    rel = jnp.einsum("bnd,bqd->bnq", v, q).max(-1)          # [B,N]
    idx = _topk_sorted(rel, keep)
    return _take(embeds, idx), idx, {"criterion": "query-relevance"}


def prune_l2(embeds, keep, *, key=None, **_) -> Out:
    """L2Compress: low key-norm correlates with high attention.

    Works on key embeddings when provided, else on the token embeddings --
    an attention-FREE salience proxy (survey §V open problem), hence the
    pruner of record for SSM backbones.
    """
    target = key if key is not None else embeds
    norms = jnp.linalg.norm(target.astype(jnp.float32), axis=-1)
    idx = _topk_sorted(-norms, keep)                        # low norm = keep
    return _take(embeds, idx), idx, {"criterion": "l2"}


def prune_divprune(embeds, keep, **_) -> Out:
    """DivPrune: greedy Max-Min-Diversity (2-approx of MMDP).

    Iteratively adds the token whose minimum distance to the selected set
    is largest; drops duplicate textures (sky/wall) regardless of salience.
    """
    b, n, d = embeds.shape
    x = embeds.astype(jnp.float32)
    x = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-6)
    sim = jnp.einsum("bnd,bmd->bnm", x, x)                  # cosine sim
    dist = 1.0 - sim                                        # [B,N,N]

    def body(carry, _):
        min_dist, selected_mask = carry
        cand = jnp.where(selected_mask, -jnp.inf, min_dist)
        nxt = jnp.argmax(cand, axis=-1)                     # [B]
        selected_mask = selected_mask.at[jnp.arange(b), nxt].set(True)
        min_dist = jnp.minimum(min_dist,
                               dist[jnp.arange(b), nxt])    # [B,N]
        return (min_dist, selected_mask), nxt

    # seed with token 0 (deterministic)
    sel0 = jnp.zeros((b, n), bool).at[:, 0].set(True)
    (_, mask), picks = jax.lax.scan(
        body, (dist[:, 0], sel0), None, length=keep - 1)
    idx_unsorted = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.int32), jnp.moveaxis(picks, 0, 1)], axis=1)
    idx = jnp.sort(idx_unsorted, axis=-1).astype(jnp.int32)
    return _take(embeds, idx), idx, {"criterion": "max-min-diversity"}


def prune_cdpruner(embeds, keep, *, query=None, **_) -> Out:
    """CDPruner: greedy MAP of a (conditional) DPP.

    Kernel L = diag(q) * S * diag(q): S = cosine similarity, q = relevance
    to the instruction (uniform when no query). Greedy MAP via Cholesky-
    style update selects a set that is jointly diverse AND relevant.
    """
    b, n, d = embeds.shape
    x = embeds.astype(jnp.float32)
    xn = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-6)
    s = jnp.einsum("bnd,bmd->bnm", xn, xn)
    if query is not None:
        qn = query / (jnp.linalg.norm(query, axis=-1, keepdims=True) + 1e-6)
        rel = (jnp.einsum("bnd,bqd->bnq", xn, qn).max(-1) + 1.0) / 2.0
    else:
        rel = jnp.ones((b, n), jnp.float32)
    l_kern = rel[:, :, None] * s * rel[:, None, :]

    # greedy DPP MAP (incremental marginal-gain, O(keep * N) per batch)
    def body(carry, _):
        di2, cis, selected_mask, step = carry
        gain = jnp.where(selected_mask, -jnp.inf, jnp.log(di2 + 1e-12))
        j = jnp.argmax(gain, axis=-1)                        # [B]
        bidx = jnp.arange(b)
        dj = jnp.sqrt(di2[bidx, j] + 1e-12)                  # [B]
        # e_i = (L[j,i] - <c_j, c_i>) / d_j
        lji = l_kern[bidx, j]                                # [B,N]
        cj = cis[:, :, :]                                    # [B,K,N]
        cjj = jnp.take_along_axis(cj, j[:, None, None], axis=2)[..., 0]
        e = (lji - jnp.einsum("bkn,bk->bn", cj, cjj)) / dj[:, None]
        cis = cis.at[:, step, :].set(e)
        di2 = jnp.maximum(di2 - jnp.square(e), 0.0)
        selected_mask = selected_mask.at[bidx, j].set(True)
        return (di2, cis, selected_mask, step + 1), j

    di2_0 = jnp.einsum("bnn->bn", l_kern)
    cis0 = jnp.zeros((b, keep, n), jnp.float32)
    sel0 = jnp.zeros((b, n), bool)
    (_, _, _, _), picks = jax.lax.scan(
        body, (di2_0, cis0, sel0, 0), None, length=keep)
    idx = jnp.sort(jnp.moveaxis(picks, 0, 1), axis=-1).astype(jnp.int32)
    return _take(embeds, idx), idx, {"criterion": "conditional-dpp"}


# --------------------------------------------------------------------------

def pyramiddrop_schedule(n_tokens: int, num_layers: int, stages: int = 4,
                         final_keep_ratio: float = 0.125):
    """PyramidDrop: per-stage (layer, keep) schedule.

    Returns [(layer_idx, n_keep), ...] dropping progressively: rather than
    FastV's single aggressive drop, tokens shrink geometrically across
    ``stages`` evenly spaced depths.
    """
    import math
    out = []
    ratio = final_keep_ratio ** (1.0 / stages)
    keep = n_tokens
    for s in range(stages):
        layer = max(1, (s + 1) * num_layers // (stages + 1))
        keep = max(1, int(math.ceil(keep * ratio)))
        out.append((layer, keep))
    return out


PRUNERS = {
    "fastv": prune_fastv,
    "sparsevlm": prune_sparsevlm,
    "l2": prune_l2,
    "divprune": prune_divprune,
    "cdpruner": prune_cdpruner,
}
