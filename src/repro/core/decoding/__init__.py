"""Internal decoding layer (sampling, speculative, early exit).

DEPRECATION NOTE: these drivers stay importable as the internal layer, but
the public entry point is now ``repro.api`` -- all four decode strategies
(greedy / sampling / speculative / early_exit) run behind
``LVLM.generate(prompts, GenerationConfig(decoder=...))``.
"""
from repro.core.decoding.sampling import (
    sample_token, sample_probs, greedy, temperature_sample, top_k_sample,
    top_p_sample)
from repro.core.decoding.speculative import (
    SpecStats, speculative_generate, acceptance_rate, draft_block,
    accept_block, lantern_neighbourhood_from_params)
from repro.core.decoding.early_exit import (
    early_exit_decode_step, layer_confidences)
