"""L-rules: architecture layering (ROADMAP "Standing layering rules").

L001  ``repro.core.*`` (and ``repro.serving``/``repro.cluster`` internals
      reached via core) imports outside ``src/repro`` -- examples,
      benchmarks, and scripts must go through the ``repro.api`` facade.
      Micro-benchmarks may keep core imports with an explicit waiver:
      ``# analysis: allow L001 (micro-bench)``.
L002  ``EngineConfig.compression`` mutated outside the facade -- the
      facade registers named strategies instead (PR 5's rule).
L003  ``Engine(...)`` constructed outside ``src/repro`` -- external
      layers use ``LVLM.serve*``.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register
from repro.analysis.tables import (COMPRESSION_MUTATION_OK_PREFIXES,
                                   ENGINE_CONSTRUCTION_OK_PREFIXES,
                                   INTERNAL_IMPORT_OK_PREFIXES)


def _under(path: str, prefixes) -> bool:
    return any(path.startswith(p) for p in prefixes)


@register
class CoreImportRule(Rule):
    rule_id = "L001"
    family = "L"
    severity = "error"
    description = ("repro.core.* import outside src/repro "
                   "(use the repro.api facade)")

    def applies(self, path: str) -> bool:
        return not _under(path, INTERNAL_IMPORT_OK_PREFIXES)

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(tree):
            mod = None
            if isinstance(node, ast.ImportFrom) and node.module:
                mod = node.module
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.startswith("repro.core"):
                        mod = alias.name
                        break
            if mod and mod.startswith("repro.core"):
                out.append(self.finding(
                    path, node.lineno,
                    f"imports internal layer `{mod}`; route through "
                    "`repro.api` (or waive: # analysis: allow L001 (...))"))
        return out


@register
class CompressionMutationRule(Rule):
    rule_id = "L002"
    family = "L"
    severity = "error"
    description = ("EngineConfig.compression mutated outside the facade "
                   "(register a CompressionStrategy instead)")

    def applies(self, path: str) -> bool:
        return not _under(path, COMPRESSION_MUTATION_OK_PREFIXES)

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        out: List[Finding] = []
        for fn_or_mod in ast.walk(tree):
            body = getattr(fn_or_mod, "body", None)
            if not isinstance(fn_or_mod, (ast.Module, ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                continue
            # locals bound from EngineConfig(...) in this scope
            ec_names = {"ec", "engine_cfg"}
            for stmt in ast.walk(fn_or_mod):
                if isinstance(stmt, ast.Assign) \
                        and isinstance(stmt.value, ast.Call) \
                        and isinstance(stmt.value.func, ast.Name) \
                        and stmt.value.func.id == "EngineConfig":
                    for t in stmt.targets:
                        if isinstance(t, ast.Name):
                            ec_names.add(t.id)
            for stmt in body or ():
                for node in ast.walk(stmt):
                    if not isinstance(node, (ast.Assign, ast.AugAssign)):
                        continue
                    targets = (node.targets
                               if isinstance(node, ast.Assign)
                               else [node.target])
                    for t in targets:
                        if not (isinstance(t, ast.Attribute)
                                and t.attr == "compression"):
                            continue
                        base = t.value
                        is_ec = (isinstance(base, ast.Name)
                                 and base.id in ec_names)
                        is_ec = is_ec or (isinstance(base, ast.Attribute)
                                          and base.attr in ec_names)
                        is_ec = is_ec or (isinstance(base, ast.Call)
                                          and isinstance(base.func, ast.Name)
                                          and base.func.id == "EngineConfig")
                        if is_ec:
                            out.append(self.finding(
                                path, node.lineno,
                                "mutates EngineConfig.compression outside "
                                "the facade; pass a CompressionStrategy / "
                                "GenerationConfig.compression instead"))
        return out


@register
class EngineConstructionRule(Rule):
    rule_id = "L003"
    family = "L"
    severity = "error"
    description = ("Engine constructed outside src/repro "
                   "(use LVLM.serve / serve_async / serve_cluster)")

    def applies(self, path: str) -> bool:
        return not _under(path, ENGINE_CONSTRUCTION_OK_PREFIXES)

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == "Engine":
                out.append(self.finding(
                    path, node.lineno,
                    "constructs Engine directly; the public decode/serving "
                    "surface is LVLM (decoder.engine_decode and "
                    "CompressionStrategy run behind it)"))
        return out
