"""Target-hardware constants (TPU v5e; the container itself is CPU-only)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops_bf16: float      # per chip
    hbm_bw: float               # bytes/s per chip
    ici_bw: float               # bytes/s per link
    hbm_bytes: float            # capacity per chip


TPU_V5E = HW(
    name="tpu-v5e",
    peak_flops_bf16=197e12,     # 197 TFLOP/s bf16
    hbm_bw=819e9,               # 819 GB/s
    ici_bw=50e9,                # ~50 GB/s per ICI link
    hbm_bytes=16e9,             # 16 GB HBM
)

# Inter-pool KV link bandwidth in GB/s: the one number every KV-movement
# model shares (tiered-cache host offload, disaggregated prefill->decode
# transfer, cluster prefix-tier installs). DCN-class, deliberately below
# ici_bw -- KV migration crosses pool boundaries, not the ICI mesh.
KV_LINK_GBPS = 32.0
