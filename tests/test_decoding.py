"""Decoding strategies (dim 4): sampling, speculative, early exit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.decoding import (acceptance_rate, early_exit_decode_step,
                                 layer_confidences, speculative_generate)
from repro.core.decoding.sampling import (greedy, sample_probs, sample_token,
                                          top_k_sample, top_p_sample)
from repro.models import build


# -------------------------------------------------------------- sampling --

def test_greedy_is_argmax():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [5.0, 0.0, 0.1]])
    assert greedy(logits).tolist() == [1, 0]
    assert sample_token(None, logits, temperature=0.0).tolist() == [1, 0]


def test_top_k_restricts_support():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0, 4.0]])
    draws = {int(top_k_sample(jax.random.fold_in(key, i), logits, k=2,
                              temperature=1.0)[0]) for i in range(50)}
    assert draws <= {3, 4}


def test_top_p_keeps_argmax_and_mass():
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
    p = sample_probs(logits, temperature=1.0, top_p=0.6)
    assert float(p[0, 0]) > 0
    assert float(p[0, 3]) == 0.0
    np.testing.assert_allclose(float(p.sum()), 1.0, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), temp=st.floats(0.2, 2.0))
def test_sample_probs_is_distribution(seed, temp):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (2, 16))
    p = sample_probs(logits, temperature=temp, top_k=8)
    assert float(jnp.abs(p.sum(-1) - 1.0).max()) < 1e-5
    assert float(p.min()) >= 0.0


# ------------------------------------------------------------ speculative --

@pytest.fixture(scope="module")
def target_and_draft():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    target = build(cfg)
    t_params = target.init(jax.random.PRNGKey(0))
    dcfg = cfg.with_(num_layers=1, d_model=128, num_heads=4, num_kv_heads=2,
                     d_ff=256, head_dim=32)
    draft = build(dcfg)
    d_params = draft.init(jax.random.PRNGKey(1))
    return cfg, target, t_params, draft, d_params


def test_speculative_greedy_exactness(target_and_draft):
    """temperature=0 speculative decoding must emit EXACTLY the target's
    greedy continuation (the draft only accelerates, never changes it)."""
    cfg, target, tp, draft, dp = target_and_draft
    rng = np.random.RandomState(0)
    prompt = list(rng.randint(1, cfg.vocab_size, size=20))
    toks, stats = speculative_generate(target, draft, tp, dp, prompt,
                                       max_new_tokens=10, gamma=3,
                                       temperature=0.0)
    # reference greedy loop
    ref = []
    t_logits, cache = jax.jit(
        lambda p, b: target.prefill(p, b, cache_len=64))(
            tp, {"tokens": jnp.asarray(prompt)[None]})
    tok = int(jnp.argmax(t_logits[0, -1]))
    ref.append(tok)
    pos = len(prompt)
    step = jax.jit(target.decode_step)
    for i in range(9):
        lg, cache = step(tp, cache, jnp.asarray([[tok]], jnp.int32), pos)
        tok = int(jnp.argmax(lg[0]))
        ref.append(tok)
        pos += 1
    assert toks == ref
    assert stats.proposed > 0


def test_speculative_self_draft_accepts_everything(target_and_draft):
    """Draft == target -> every proposal is accepted (sanity upper bound)."""
    cfg, target, tp, _, _ = target_and_draft
    rng = np.random.RandomState(1)
    prompt = list(rng.randint(1, cfg.vocab_size, size=16))
    toks, stats = speculative_generate(target, target, tp, tp, prompt,
                                       max_new_tokens=9, gamma=3,
                                       temperature=0.0)
    assert acceptance_rate(stats) == 1.0
    # gamma+1 tokens per target call: 3 calls for 9 tokens instead of 9
    assert stats.target_calls <= 4


def test_lantern_relaxation_increases_acceptance(target_and_draft):
    cfg, target, tp, draft, dp = target_and_draft
    rng = np.random.RandomState(2)
    prompt = list(rng.randint(1, cfg.vocab_size, size=16))
    _, strict = speculative_generate(target, draft, tp, dp, prompt,
                                     max_new_tokens=10, gamma=3,
                                     temperature=0.8)
    _, relaxed = speculative_generate(target, draft, tp, dp, prompt,
                                      max_new_tokens=10, gamma=3,
                                      temperature=0.8, lantern_k=16,
                                      lantern_delta=0.5)
    assert acceptance_rate(relaxed) >= acceptance_rate(strict)


# -------------------------------------------------------------- early exit --

@pytest.fixture(scope="module")
def ee_setup():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompt = jnp.asarray(rng.randint(1, cfg.vocab_size, size=(1, 16)),
                         jnp.int32)
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_len=32))(
        params, {"tokens": prompt})
    return model, params, cache


def test_layer_confidences_shape(ee_setup):
    model, params, cache = ee_setup
    confs = layer_confidences(model, params, cache,
                              jnp.asarray([[5]], jnp.int32), 16)
    assert confs.shape == (model.cfg.num_layers, 1)
    assert float(confs.min()) >= 0 and float(confs.max()) <= 1


def test_early_exit_disabled_matches_full(ee_setup):
    """threshold > 1 can never fire -> logits equal the plain decode."""
    model, params, cache = ee_setup
    tok = jnp.asarray([[7]], jnp.int32)
    full, _ = jax.jit(model.decode_step)(params, cache, tok, 16)
    ee, _, info = early_exit_decode_step(model, params, cache, tok, 16,
                                         threshold=1.1)
    assert not info["exited"] and info["layers_used"] == model.cfg.num_layers
    np.testing.assert_allclose(np.asarray(ee), np.asarray(full[:, ]),
                               rtol=1e-4, atol=1e-5)


def test_early_exit_fires_and_saves_flops(ee_setup):
    model, params, cache = ee_setup
    tok = jnp.asarray([[7]], jnp.int32)
    _, _, info = early_exit_decode_step(model, params, cache, tok, 16,
                                        threshold=0.0, patience=0,
                                        min_layers=1)
    assert info["exited"]
    assert info["layers_used"] < model.cfg.num_layers
    assert info["flops_frac"] < 1.0
