"""Benchmark: advanced decoding (survey dim 4), via the ``repro.api``
facade -- the same ``generate()`` signature drives every strategy.

  * speculative decoding: target-model calls saved vs gamma (the memory-
    bound decode loop is the cost unit) for self-draft (upper bound),
    untrained draft, and LANTERN relaxation,
  * early exit: layers used vs confidence threshold.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.api import GenerationConfig, LVLM


def speculative() -> None:
    target = LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)
    draft = LVLM.from_pretrained(
        "phi4-mini-3.8b", smoke=True, seed=1, num_layers=1, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, head_dim=32)
    rng = np.random.RandomState(0)
    prompt = list(rng.randint(1, target.cfg.vocab_size, size=24))
    n_new = 24
    for gamma in (2, 4):
        gen = GenerationConfig(decoder="speculative", temperature=0.0,
                               max_new_tokens=n_new, gamma=gamma)
        cases = (
            # self-draft = acceptance upper bound
            ("self", target.generate(prompt, gen)),
            ("draft", target.generate(prompt, gen, draft=draft)),
            ("lantern", target.generate(
                prompt, gen.with_(temperature=0.8, lantern_k=16,
                                  lantern_delta=0.3), draft=draft)),
        )
        for tag, res in cases:
            st = res.stats
            speedup = n_new / max(st["target_calls"], 1)
            emit(f"decode/spec/g{gamma}/{tag}", 0.0,
                 f"accept={st['acceptance']:.3f};"
                 f"target_calls={st['target_calls']};"
                 f"call_reduction={speedup:.2f}x")


def early_exit() -> None:
    lvlm = LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)
    rng = np.random.RandomState(1)
    prompt = list(rng.randint(1, lvlm.cfg.vocab_size, size=24))
    for thr in (1.1, 0.5, 0.0):
        res = lvlm.generate(prompt, GenerationConfig(
            decoder="early_exit", temperature=0.0, max_new_tokens=8,
            exit_threshold=thr, exit_patience=0, exit_min_layers=1))
        st = res.stats
        emit(f"decode/early_exit/thr{thr}", 0.0,
             f"layers={st['layers_used_mean']:.1f}/{lvlm.cfg.num_layers};"
             f"flops_frac={st['layers_used_mean'] / lvlm.cfg.num_layers:.2f}")


def run() -> None:
    speculative()
    early_exit()


if __name__ == "__main__":
    run()
