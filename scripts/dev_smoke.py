"""Dev-loop smoke: forward/loss/prefill/decode for every smoke config."""
import sys
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import build

only = sys.argv[1:] or ARCHS
ok = True
for arch in only:
    cfg = get_config(arch, smoke=True)
    try:
        model = build(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        b, s = 2, 16
        batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
        if cfg.family == "vlm":
            batch["visual_embeds"] = jax.random.normal(
                key, (b, cfg.num_visual_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                key, (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        logits, aux = jax.jit(model.forward)(params, batch)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), "fwd NaN"
        loss, metrics = model.loss(params, batch)
        assert np.isfinite(float(loss)), "loss NaN"
        # prefill + 3 decode steps
        pl_logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=s + 8))(params, batch)
        tok = jnp.argmax(pl_logits[:, -1], -1)[:, None].astype(jnp.int32)
        step = jax.jit(model.decode_step)
        for i in range(3):
            lg, cache = step(params, cache, tok, s + i)
            assert np.isfinite(np.asarray(lg, np.float32)).all(), "dec NaN"
            tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        print(f"OK   {arch:22s} loss={float(loss):.4f} "
              f"logits={tuple(logits.shape)}")
    except Exception as e:
        ok = False
        print(f"FAIL {arch}: {e}")
        traceback.print_exc()
print("ALL OK" if ok else "FAILURES")
sys.exit(0 if ok else 1)
