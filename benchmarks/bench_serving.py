"""Benchmark: serving & scheduling (survey dim 2c), via the ``repro.api``
facade.

Real engine, real smoke model, virtual-clock metrics:
  * scheduler comparison on a bursty mixed-length workload,
  * prefix caching on shared-system-prompt traffic,
  * per-request decoder mixing: greedy + sampling + speculative +
    early-exit requests in ONE engine run (batched speculative slots),
  * per-request COMPRESSION mixing (``--compression a,b``): VLM traffic
    cycling strategies in one engine through the async server, emitting
    per-strategy prefill-token-reduction in a ``# open_loop`` record,
  * open-loop Poisson traffic through the ASYNC serving stack at EVERY
    replica count (cluster Router, least-KV routing, SLO-slack deferred
    queues): one ``# open_loop`` JSON record per (rate, replica count)
    with fleet-wide percentiles + SLO attainment -- the multi-replica
    throughput/latency trajectory (``--replicas 1,2,4`` to extend),
  * disaggregated vs colocated pools under KV-transfer cost (analytic sim).

Latency rows report percentiles (p50/p95/p99), not just means.
"""
from __future__ import annotations

import asyncio
import json

import numpy as np

from benchmarks.common import emit
from repro.api import (AdmissionConfig, CostModel, EngineConfig,
                       GenerationConfig, LVLM, PoolConfig, Request, goodput,
                       simulate_colocated, simulate_disaggregated)


def _pcts(out, metric: str) -> str:
    return ";".join(f"{metric}_p{p}={out.get(f'{metric}_p{p}') or 0:.4f}"
                    for p in (50, 95, 99))


def _reqs(cfg, n, seed=0, shared=0, lo=10, hi=60, new=8, gap=0.001):
    rng = np.random.RandomState(seed)
    pre = list(rng.randint(1, cfg.vocab_size, size=shared))
    return [Request(rid=i, tokens=pre + list(
        rng.randint(1, cfg.vocab_size, size=rng.randint(lo, hi))),
        max_new_tokens=new, arrival=i * gap) for i in range(n)]


def schedulers(lvlm: LVLM) -> None:
    for sched in ("static", "continuous", "mlfq", "chunked"):
        out = lvlm.serve(
            _reqs(lvlm.cfg, 12, seed=1),
            EngineConfig(max_batch=4, cache_len=128, scheduler=sched,
                         chunk_size=16, token_budget=48)).stats
        emit(f"serve/sched/{sched}", out["virtual_time_s"] * 1e6,
             f"{_pcts(out, 'ttft')};{_pcts(out, 'tpot')};"
             f"jct_mean={out['jct_mean']:.4f};"
             f"tput={out['throughput_tok_per_s']:.0f}")


def prefix_cache(lvlm: LVLM) -> None:
    for on in (False, True):
        out = lvlm.serve(
            _reqs(lvlm.cfg, 10, seed=2, shared=64, lo=4, hi=16, new=4),
            EngineConfig(max_batch=4, cache_len=192, prefix_cache=on,
                         prefix_block=16)).stats
        extra = (f"hit_rate={out.get('prefix_token_hit_rate', 0):.3f};"
                 if on else "")
        emit(f"serve/prefix_cache/{'on' if on else 'off'}",
             out["virtual_time_s"] * 1e6,
             extra + _pcts(out, 'ttft'))


def mixed_decoders(lvlm: LVLM) -> None:
    """One engine, four decode strategies concurrently (survey dim 4 at
    serving scale): per-request ``decoder`` mixing with batched speculative
    slots, vs the same workload served all-greedy."""
    strategies = ("speculative", "speculative", "speculative", "greedy",
                  "sampling", "early_exit", "greedy", "speculative")
    for label, decs in (("mixed", strategies),
                        ("all_greedy", ("greedy",) * len(strategies))):
        reqs = _reqs(lvlm.cfg, len(decs), seed=4, lo=8, hi=24, new=8,
                     gap=0.0005)
        for r, d in zip(reqs, decs):
            r.decoder = d
        out = lvlm.serve(
            reqs, EngineConfig(max_batch=4, cache_len=128,
                               temperature=0.0),
            gen=GenerationConfig(decoder="greedy", temperature=0.0,
                                 max_new_tokens=8, gamma=3)).stats
        spec = (f"spec_acc={out.get('speculative/acceptance', 0):.2f};"
                f"spec_slots={out.get('speculative/max_slots_per_round', 0)};"
                if label == "mixed" else "")
        emit(f"serve/mixed_decoders/{label}",
             out["virtual_time_s"] * 1e6,
             spec + f"{_pcts(out, 'ttft')};{_pcts(out, 'tpot')};"
             f"jct_mean={out['jct_mean']:.4f};"
             f"tput={out['throughput_tok_per_s']:.0f}")


def open_loop(lvlm: LVLM, replica_counts=(1, 2)) -> None:
    """Open-loop Poisson traffic through the ASYNC serving stack at every
    replica count: requests arrive over (virtual) time at a fixed rate,
    mixed decoder strategies, KV-watermark admission with SLO-slack
    deferred queues, routed over N engine replicas by least-committed-KV.
    One ``# open_loop`` JSON record per (rate, replica count) -- the
    fleet-wide throughput/latency trajectory BENCH_*.json tracks: tail
    TTFT/TPOT and SLO attainment under load, not closed-batch makespan."""
    strategies = ("speculative", "greedy", "sampling", "greedy")
    for label, rate in (("r500", 500.0), ("r2000", 2000.0)):
        for n_rep in replica_counts:
            rng = np.random.RandomState(9)
            reqs = _reqs(lvlm.cfg, 16, seed=10, lo=8, hi=24, new=8)
            arrivals = np.cumsum(rng.exponential(1.0 / rate,
                                                 size=len(reqs)))
            for i, r in enumerate(reqs):
                r.arrival = float(arrivals[i])
                r.decoder = strategies[i % len(strategies)]
            router = lvlm.serve_cluster(
                n_rep,
                EngineConfig(max_batch=4, cache_len=128, temperature=0.0),
                gen=GenerationConfig(decoder="greedy", temperature=0.0,
                                     max_new_tokens=8, gamma=3),
                routing="least_kv",
                admission=AdmissionConfig(high_watermark=0.9,
                                          low_watermark=0.7,
                                          order="slack"))

            async def drive(router=router, reqs=reqs):
                async def consume(r):
                    return [t async for t in router.submit(r)]
                async with router:
                    await asyncio.gather(*(consume(r) for r in reqs))
                return router.summary()

            out = asyncio.run(drive())
            emit(f"serve/open_loop/{label}/replicas{n_rep}",
                 out["virtual_time_s"] * 1e6,
                 f"{_pcts(out, 'ttft')};{_pcts(out, 'tpot')};"
                 f"slo_goodput={out['slo_goodput']:.2f};"
                 f"tput={out.get('fleet_throughput_tok_per_s', 0):.0f};"
                 f"queue_wait_p95={out.get('queue_wait_p95') or 0:.4f};"
                 f"deferred={out['deferred']}")
            record = {"scenario": f"open_loop/{label}/replicas{n_rep}",
                      "rate_rps": rate, "replicas": n_rep,
                      "routing": out["routing_policy"],
                      "finished": out["finished"],
                      "aborted": out["aborted"],
                      "slo_ttft_attainment": out["slo_ttft_attainment"],
                      "slo_tpot_attainment": out["slo_tpot_attainment"],
                      "slo_goodput": out["slo_goodput"],
                      "deferred": out["deferred"],
                      "failovers": out["failovers"],
                      "dispatched_by_replica": out["dispatched_by_replica"],
                      "fleet_throughput_tok_per_s":
                          out.get("fleet_throughput_tok_per_s"),
                      "virtual_time_s": out["virtual_time_s"]}
            record.update({k: out[k] for k in out if k.startswith(
                ("ttft_p", "tpot_p", "queue_wait_"))})
            print("# open_loop " + json.dumps(record, default=float),
                  flush=True)


def compression_mix(presets=("none", "fastv-0.5")) -> None:
    """Mixed-compression VLM workload: per-request ``Request.compression``
    cycles over ``presets`` in ONE engine (dim 1 at serving scale),
    open-loop Poisson arrivals through the async server. Emits one
    ``# open_loop`` JSON record whose ``prefill_token_reduction_by_
    strategy`` charts how much prefill each strategy saved -- the
    EffiVLM-BENCH-style sweep signal, measured on heterogeneous traffic
    instead of per-preset engine rebuilds."""
    vlm = LVLM.from_pretrained("qwen2-vl-2b", smoke=True)
    rng = np.random.RandomState(21)
    reqs = _reqs(vlm.cfg, 12, seed=22, lo=8, hi=20, new=6)
    arrivals = np.cumsum(rng.exponential(1.0 / 1000.0, size=len(reqs)))
    for i, r in enumerate(reqs):
        r.arrival = float(arrivals[i])
        r.visual_embeds = rng.randn(
            vlm.cfg.num_visual_tokens, vlm.cfg.d_model
        ).astype(np.float32) * 0.02
        r.compression = presets[i % len(presets)]
    server = vlm.serve_async(
        EngineConfig(max_batch=4, cache_len=128, temperature=0.0),
        gen=GenerationConfig(decoder="greedy", temperature=0.0,
                             max_new_tokens=6),
        admission=AdmissionConfig(high_watermark=0.9, low_watermark=0.7))

    async def drive():
        async def consume(r):
            return [t async for t in server.submit(r)]
        async with server:
            await asyncio.gather(*(consume(r) for r in reqs))
        return server.summary()

    out = asyncio.run(drive())
    reduction = {
        name.split("/")[1]: out[name]
        for name in out if name.startswith("compression/")
        and name.endswith("/prefill_token_reduction")}
    emit("serve/compression_mix/" + "+".join(presets),
         out["virtual_time_s"] * 1e6,
         ";".join(f"{n}={r:.2f}" for n, r in sorted(reduction.items()))
         + f";{_pcts(out, 'ttft')};finished={out['finished']}")
    record = {"scenario": "open_loop/compression_mix",
              "presets": list(presets),
              "finished": out["finished"],
              "prefill_token_reduction_by_strategy": reduction,
              "slo_goodput": out["slo_goodput"],
              "virtual_time_s": out["virtual_time_s"]}
    record.update({k: out[k] for k in out
                   if k.startswith(("ttft_p", "tpot_p"))})
    print("# open_loop " + json.dumps(record, default=float), flush=True)


def _wall_stats(events):
    """Per-request wall-clock latencies derived from tracer events: TTFT
    is the ``first_token`` instant minus the ``request`` span begin,
    TPOT the decode stretch (request end - first token) over the
    emitted tokens. These are the REAL elapsed times of the smoke-model
    run -- the profiling baseline BENCH_serving.json pins next to the
    cost-model's virtual-clock numbers."""
    begin, first, end, tokens = {}, {}, {}, {}
    for ev in events:
        if ev["name"] == "request" and ev["k"] == "B":
            begin[ev["rid"]] = ev["wt"]
        elif ev["name"] == "first_token":
            first[ev["rid"]] = ev["wt"]
        elif ev["name"] == "request" and ev["k"] == "E":
            end[ev["rid"]] = ev["wt"]
            tokens[ev["rid"]] = (ev.get("attrs") or {}).get("tokens", 0)
    ttft = [first[r] - begin[r] for r in first if r in begin]
    tpot = [(end[r] - first[r]) / (tokens[r] - 1)
            for r in end if r in first and tokens.get(r, 0) > 1]
    wts = [ev["wt"] for ev in events]
    return {"ttft": ttft, "tpot": tpot,
            "wall_time_s": (max(wts) - min(wts)) if wts else 0.0}


def wall_baseline(lvlm: LVLM, out_path: str, trace_out=None) -> None:
    """``--emit-bench``: one traced open-loop run on a disaggregated
    prefill/decode fleet, written as the schema-stable wall-clock
    profiling baseline ``BENCH_serving.json``.

    Schema (keys are stable; values vary with the host):
      schema_version            int, bumped on any key change
      scenario / roles / routing  what ran
      requests / finished / aborted / migrations  workload accounting
      virtual                   cost-model clock: time_s,
                                throughput_tok_per_s, ttft_s/tpot_s
                                {p50,p95}
      wall                      measured perf_counter: same keys --
                                the smoke-model profiling baseline
      profile                   Profiler.bench_record(): per hot-path
                                site call counts + wall self/total and
                                virtual seconds
    """
    from repro.obs import Profiler, Tracer, write_chrome_trace
    tracer = Tracer()
    profiler = Profiler()
    rng = np.random.RandomState(7)
    reqs = _reqs(lvlm.cfg, 16, seed=8, lo=8, hi=24, new=8)
    arrivals = np.cumsum(rng.exponential(1 / 2000.0, size=len(reqs)))
    for r, t in zip(reqs, arrivals):
        r.arrival = float(t)
    router = lvlm.serve_cluster(
        [{"role": "prefill"}, {"role": "decode"}],
        EngineConfig(max_batch=4, cache_len=128, temperature=0.0,
                     cost=CostModel(kv_bytes_per_token=100_000)),
        gen=GenerationConfig(decoder="greedy", temperature=0.0,
                             max_new_tokens=8),
        routing="least_kv", obs=tracer, profile=profiler)

    async def drive():
        async def consume(r):
            return [t async for t in router.submit(r)]
        async with router:
            await asyncio.gather(*(consume(r) for r in reqs))
        return router.summary()

    out = asyncio.run(drive())
    wall = _wall_stats(tracer.events)

    def _p(vals, p):
        return float(np.percentile(vals, p)) if vals else None

    tokens = out["tokens"]
    doc = {
        "schema_version": 1,
        "scenario": "open_loop/disagg_baseline",
        "roles": ["prefill", "decode"],
        "routing": out["routing_policy"],
        "requests": len(reqs),
        "finished": out["finished"],
        "aborted": out["aborted"],
        "migrations": out.get("disaggregation", {}).get("migrations", 0),
        "tokens": tokens,
        "virtual": {
            "time_s": out["virtual_time_s"],
            "throughput_tok_per_s": out.get("fleet_throughput_tok_per_s"),
            "ttft_s": {"p50": out.get("ttft_p50"),
                       "p95": out.get("ttft_p95")},
            "tpot_s": {"p50": out.get("tpot_p50"),
                       "p95": out.get("tpot_p95")},
        },
        "wall": {
            "time_s": wall["wall_time_s"],
            "throughput_tok_per_s": (tokens / wall["wall_time_s"]
                                     if wall["wall_time_s"] else None),
            "ttft_s": {"p50": _p(wall["ttft"], 50),
                       "p95": _p(wall["ttft"], 95)},
            "tpot_s": {"p50": _p(wall["tpot"], 50),
                       "p95": _p(wall["tpot"], 95)},
        },
        "profile": profiler.bench_record(),
    }
    with open(out_path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1, default=float)
        f.write("\n")
    if trace_out:
        write_chrome_trace(tracer.events, trace_out)
    print(f"# bench_baseline written to {out_path} "
          f"(wall {wall['wall_time_s']:.3f}s, "
          f"virtual {out['virtual_time_s'] * 1e3:.3f}ms)", flush=True)


def disagg_burst(lvlm: LVLM, trace_out=None) -> None:
    """Tentpole acceptance: a video-heavy prefill burst lands mid-run on
    a steady chat stream. Colocated replicas interleave the burst's
    chunked prefill with chat decode iterations, inflating chat TPOT; a
    ``--roles prefill:1,decode:1`` split keeps the decode replica's
    iterations prefill-free -- post-compression KV crosses the modeled
    link instead -- so the chat cohort's TPOT p95 stays within 10% of
    its no-burst baseline. Real engines, real migration, one
    ``# open_loop`` record per fleet with the degradation ratio."""
    cost = CostModel(kv_bytes_per_token=100_000)
    gen = GenerationConfig(decoder="greedy", temperature=0.0,
                           max_new_tokens=16)

    def _ec(batch):
        return EngineConfig(max_batch=batch, cache_len=512,
                            scheduler="chunked", chunk_size=32,
                            temperature=0.0, cost=cost)

    def _fleet(label, tracer=None):
        # equal aggregate slots (24) either way; the disagg fleet spends
        # them asymmetrically -- narrow prefill, wide decode batch
        if label == "disagg":
            return lvlm.serve_cluster(
                [{"role": "prefill", "engine_cfg": _ec(8)},
                 {"role": "decode", "engine_cfg": _ec(16)}],
                _ec(8), gen=gen, obs=tracer)
        return lvlm.serve_cluster(2, _ec(12), gen=gen, obs=tracer)

    def _workload(burst):
        rng = np.random.RandomState(33)
        chat = _reqs(lvlm.cfg, 16, seed=34, lo=8, hi=24, new=16)
        arr = np.cumsum(rng.exponential(1 / 2000.0, size=len(chat)))
        for r, t in zip(chat, arr):
            r.arrival = float(t)
        video = [Request(rid=100 + j, tokens=list(rng.randint(
            1, lvlm.cfg.vocab_size, size=420)), max_new_tokens=4,
            arrival=float(arr[4]) + j * 0.0005)
            for j in range(3)] if burst else []
        return chat, video

    def _chat_tpot_p95(chat):
        return float(np.percentile(
            [(r.finish_time - r.first_token_time)
             / max(1, len(r.generated) - 1) for r in chat], 95))

    for label in ("colocated", "disagg"):
        tpot, moved = {}, 0
        for phase in ("baseline", "burst"):
            tracer = None
            if trace_out and label == "disagg" and phase == "burst":
                # trace the interesting fleet: the burst crossing the
                # prefill->decode KV link (CI validates this trace)
                from repro.obs import Tracer
                tracer = Tracer()
            router = _fleet(label, tracer=tracer)
            chat, video = _workload(burst=(phase == "burst"))

            async def drive(router=router, reqs=chat + video):
                async def consume(r):
                    return [t async for t in router.submit(r)]
                async with router:
                    await asyncio.gather(*(consume(r) for r in reqs))
                return router.summary()

            out = asyncio.run(drive())
            tpot[phase] = _chat_tpot_p95(chat)
            if phase == "burst":
                moved = out.get("disaggregation", {}).get("migrations", 0)
            if tracer is not None:
                from repro.obs import write_chrome_trace
                write_chrome_trace(tracer.events, trace_out)
                print(f"# trace written to {trace_out} "
                      f"({len(tracer.events)} events)", flush=True)
        ratio = tpot["burst"] / tpot["baseline"]
        emit(f"serve/disagg_burst/{label}", tpot["burst"] * 1e6,
             f"chat_tpot_p95={tpot['burst']:.6f};"
             f"baseline={tpot['baseline']:.6f};ratio={ratio:.3f};"
             f"migrations={moved}")
        record = {"scenario": f"open_loop/disagg_burst/{label}",
                  "roles": (["prefill", "decode"] if label == "disagg"
                            else ["unified", "unified"]),
                  "chat_tpot_p95": tpot["burst"],
                  "chat_tpot_p95_no_burst": tpot["baseline"],
                  "degradation_ratio": ratio,
                  "within_10pct": bool(ratio <= 1.10),
                  "migrations": moved}
        print("# open_loop " + json.dumps(record, default=float),
              flush=True)


def control_burst(trace_out=None) -> None:
    """Adaptive-control acceptance: a video-heavy Poisson burst overloads
    a KV-tight server (every request carries 160 visual tokens at the
    ``none`` preset -- only ~2 fit the pool). Defer-only admission parks
    the overflow at the gate, so END-TO-END first-token latency (queue
    wait + TTFT, ``slo_e2e_attainment``) collapses; the SLO-adaptive
    controller (``control=``) degrades the deferred cohort to aggressive
    pruning presets instead -- smaller KV per request admits ~4x the
    concurrency and the queue drains. Identical workload, identical
    arrival rate, both runs; one ``# open_loop`` record per mode with the
    attainment + makespan comparison CI asserts on (controller-on must
    beat defer-only)."""
    from repro.api import ControlConfig, SLO
    vlm = LVLM.from_pretrained("qwen2-vl-2b", smoke=True)

    def _workload():
        rng = np.random.RandomState(77)
        reqs = _reqs(vlm.cfg, 16, seed=78, lo=8, hi=14, new=8)
        arrivals = np.cumsum(rng.exponential(1 / 4000.0, size=len(reqs)))
        for i, r in enumerate(reqs):
            r.arrival = float(arrivals[i])
            r.slo = SLO(ttft_ms=30.0, tpot_ms=6.0)
            r.visual_embeds = rng.randn(
                160, vlm.cfg.d_model).astype(np.float32) * 0.02
        return reqs

    results = {}
    for label, ctl in (("defer_only", None),
                       ("adaptive", ControlConfig(cooldown_s=0.001))):
        tracer = None
        if trace_out and label == "adaptive":
            from repro.obs import Tracer
            tracer = Tracer()
        reqs = _workload()
        server = vlm.serve_async(
            EngineConfig(max_batch=8, cache_len=256,
                         kv_capacity_tokens=512, temperature=0.0),
            gen=GenerationConfig(decoder="greedy", temperature=0.0,
                                 max_new_tokens=8),
            admission=AdmissionConfig(high_watermark=0.9,
                                      low_watermark=0.7),
            obs=tracer, control=ctl)

        async def drive(server=server, reqs=reqs):
            async def consume(r):
                return [t async for t in server.submit(r)]
            async with server:
                await asyncio.gather(*(consume(r) for r in reqs))
            return server.summary()

        out = asyncio.run(drive())
        results[label] = out
        if tracer is not None:
            from repro.obs import write_chrome_trace
            write_chrome_trace(tracer.events, trace_out)
            print(f"# trace written to {trace_out} "
                  f"({len(tracer.events)} events)", flush=True)
        emit(f"serve/control_burst/{label}",
             out["virtual_time_s"] * 1e6,
             f"e2e_attainment={out['slo_e2e_attainment']:.3f};"
             f"e2e_goodput={out['slo_e2e_goodput']:.3f};"
             f"queue_wait_p95={out.get('queue_wait_p95') or 0:.4f};"
             f"deferred={out['deferred']};"
             f"commits={out.get('control_commits', 0)}")
        record = {"scenario": f"open_loop/control_burst/{label}",
                  "rate_rps": 4000.0,
                  "finished": out["finished"],
                  "deferred": out["deferred"],
                  "slo_e2e_attainment": out["slo_e2e_attainment"],
                  "slo_e2e_goodput": out["slo_e2e_goodput"],
                  "slo_goodput": out["slo_goodput"],
                  "queue_wait_p95": out.get("queue_wait_p95"),
                  "e2e_ttft_p95": out.get("e2e_ttft_p95"),
                  "virtual_time_s": out["virtual_time_s"],
                  "control_commits": out.get("control_commits", 0),
                  "control_reverts": out.get("control_reverts", 0),
                  "control_overrides_open":
                      out.get("control_overrides_open", 0)}
        print("# open_loop " + json.dumps(record, default=float),
              flush=True)
    gain = (results["adaptive"]["slo_e2e_attainment"]
            - results["defer_only"]["slo_e2e_attainment"])
    print(f"# control_burst e2e attainment gain: {gain:+.3f} "
          f"(adaptive {results['adaptive']['slo_e2e_attainment']:.3f} "
          f"vs defer-only "
          f"{results['defer_only']['slo_e2e_attainment']:.3f})",
          flush=True)


def disaggregation() -> None:
    cost = CostModel(prefill_us_per_token=30.0, decode_us_per_token=600.0,
                     decode_us_per_ctx_token=0.01,
                     kv_bytes_per_token=500_000, transfer_gbps=20.0)
    for label, fn in (
            ("colocated", lambda rs: simulate_colocated(
                rs, cost, n_instances=2, decode_batch=16)),
            ("disagg", lambda rs: simulate_disaggregated(
                rs, cost, PoolConfig(1, 1, 16))),
            ("disagg_predlen", lambda rs: simulate_disaggregated(
                rs, cost, PoolConfig(1, 1, 16), predict_len=True))):
        rng = np.random.RandomState(3)
        reqs = [Request(rid=i, tokens=list(rng.randint(1, 64, size=rng.randint(
            100, 500))), max_new_tokens=int(rng.randint(8, 64)),
            arrival=i * 0.003) for i in range(32)]
        for r in reqs:
            r.predicted_len = r.max_new_tokens
        out = fn(reqs)
        g = goodput(reqs, ttft_slo=0.15, tpot_slo=0.002)
        emit(f"serve/disagg/{label}", out["makespan"] * 1e6,
             f"ttft_p99={out['ttft_p99']:.4f};tpot={out['tpot_mean']:.5f};"
             f"goodput={g:.2f}")


def run(replica_counts=(1, 2),
        compression=("none", "fastv-0.5")) -> None:
    lvlm = LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)
    schedulers(lvlm)
    prefix_cache(lvlm)
    mixed_decoders(lvlm)
    compression_mix(presets=compression)
    open_loop(lvlm, replica_counts=replica_counts)
    disagg_burst(lvlm)
    control_burst()
    disaggregation()


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--replicas", default="1,2",
                    help="comma-separated replica counts for the "
                         "open-loop trajectory (e.g. '2' or '1,2,4')")
    ap.add_argument("--compression", default="none,fastv-0.5",
                    help="comma-separated compression strategies for the "
                         "mixed-workload scenario (assigned per-request "
                         "round-robin, e.g. 'none,framefusion-0.25')")
    ap.add_argument("--only-open-loop", action="store_true",
                    help="skip the closed-loop scenarios")
    ap.add_argument("--only-disagg-burst", action="store_true",
                    help="run just the prefill/decode burst-isolation "
                         "scenario (the disaggregation smoke check)")
    ap.add_argument("--only-control-burst", action="store_true",
                    help="run just the SLO-adaptive controller vs "
                         "defer-only burst comparison (the repro.control "
                         "smoke check)")
    ap.add_argument("--emit-bench", default=None, metavar="PATH",
                    help="run the traced disaggregated baseline and write "
                         "the schema-stable wall+virtual profiling "
                         "baseline JSON (see wall_baseline docstring for "
                         "the schema) -- e.g. BENCH_serving.json")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the traced "
                         "scenario (--emit-bench run, or the disagg burst "
                         "with --only-disagg-burst); validate with "
                         "python -m repro.obs.validate")
    args = ap.parse_args()
    counts = tuple(int(x) for x in str(args.replicas).split(",") if x)
    presets = tuple(p for p in str(args.compression).split(",") if p)
    if args.emit_bench:
        wall_baseline(LVLM.from_pretrained("phi4-mini-3.8b", smoke=True),
                      args.emit_bench, trace_out=args.trace_out)
    elif args.only_disagg_burst:
        disagg_burst(LVLM.from_pretrained("phi4-mini-3.8b", smoke=True),
                     trace_out=args.trace_out)
    elif args.only_control_burst:
        control_burst(trace_out=args.trace_out)
    elif args.only_open_loop:
        open_loop(LVLM.from_pretrained("phi4-mini-3.8b", smoke=True),
                  replica_counts=counts)
    else:
        run(replica_counts=counts, compression=presets)


if __name__ == "__main__":
    main()
