"""Mamba-2 (SSD: state-space duality) block, chunked-parallel + recurrent.

Full-sequence path uses the chunked SSD algorithm (quadratic attention-like
form inside fixed chunks, linear recurrence across chunks via lax.scan) --
this is the TPU adaptation of the CUDA selective-scan: chunk-local work is
MXU-friendly batched matmul, and the only sequential dependency is the
O(T/chunk) scan over chunk states.

Decode path is the O(1) recurrence: h' = exp(dt*A) h + dt * B (x)  ;
y = C . h' + D x. State cache = {"conv": rolling conv window, "ssd": h}.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, spec


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads, cfg.ssm_state_dim


def mamba_specs(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_inner, nheads, n = _dims(cfg)
    conv_ch = d_inner + 2 * n
    return {
        # order: [z (d_inner) | xBC (d_inner + 2N) | dt (nheads)]
        "in_proj": spec((d, 2 * d_inner + 2 * n + nheads), ("embed", "ssm_inner")),
        "conv_w": spec((cfg.ssm_conv_dim, conv_ch), (None, "ssm_inner"), scale=0.5),
        "conv_b": spec((conv_ch,), ("ssm_inner",), init="zeros"),
        "dt_bias": spec((nheads,), ("ssm_heads",), init="zeros"),
        "a_log": spec((nheads,), ("ssm_heads",), init="ones"),
        "d_skip": spec((nheads,), ("ssm_heads",), init="ones"),
        "norm_scale": spec((d_inner,), ("ssm_inner",), init="ones"),
        "out_proj": spec((d_inner, d), ("ssm_inner", "embed")),
    }


def mamba_cache_specs(cfg, batch: int):
    d_inner, nheads, n = _dims(cfg)
    conv_ch = d_inner + 2 * n
    return {
        "conv": spec((batch, cfg.ssm_conv_dim - 1, conv_ch),
                     ("batch", None, "ssm_inner"), init="zeros"),
        "ssd": spec((batch, nheads, cfg.ssm_head_dim, n),
                    ("batch", "ssm_heads", None, None), init="zeros",
                    dtype="float32"),
    }


def _split_proj(cfg, proj):
    d_inner, nheads, n = _dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:2 * d_inner + 2 * n]
    dt = proj[..., 2 * d_inner + 2 * n:]
    return z, xbc, dt


def _gated_rmsnorm(y, z, scale, eps=1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), -1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)


def _segsum(a):
    """a [..., c] -> lower-triangular pairwise cumulative sums [..., c, c]."""
    c = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, seg, -jnp.inf)


def mamba_forward(p, x, cfg, *, chunk: int = 128,
                  cache=None) -> Tuple[jax.Array, Dict]:
    """Full-sequence SSD. x [B,T,d] -> y [B,T,d] (+ final state if cache)."""
    b, t, d = x.shape
    d_inner, nheads, n = _dims(cfg)
    hd = cfg.ssm_head_dim

    proj = jnp.einsum("btd,de->bte", x, p["in_proj"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, proj)

    # depthwise causal conv over xBC (kernel ssm_conv_dim)
    kw = cfg.ssm_conv_dim
    xbc_pad = jnp.pad(xbc, ((0, 0), (kw - 1, 0), (0, 0)))
    conv = sum(xbc_pad[:, i:i + t] * p["conv_w"][i] for i in range(kw))
    conv = jax.nn.silu(conv + p["conv_b"])
    xs = conv[..., :d_inner].reshape(b, t, nheads, hd)
    bm = conv[..., d_inner:d_inner + n]                      # [B,T,N]
    cm = conv[..., d_inner + n:]                             # [B,T,N]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,T,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                   # [H]
    da = dt * a                                                    # [B,T,H]

    # ---- chunked SSD ------------------------------------------------------
    c = min(chunk, t)
    assert t % c == 0, (t, c)
    nc = t // c
    xs_c = xs.reshape(b, nc, c, nheads, hd).astype(jnp.float32)
    bm_c = bm.reshape(b, nc, c, n).astype(jnp.float32)
    cm_c = cm.reshape(b, nc, c, n).astype(jnp.float32)
    dt_c = dt.reshape(b, nc, c, nheads)
    da_c = da.reshape(b, nc, c, nheads)
    da_cs = jnp.cumsum(da_c, axis=2)                               # [B,nc,c,H]

    # intra-chunk (quadratic within chunk)
    l = jnp.exp(_segsum(jnp.moveaxis(da_c, -1, -2)))     # [B,nc,H,c,c]
    scores = jnp.einsum("bzin,bzjn->bzij", cm_c, bm_c)   # [B,nc,c,c]
    dtx = xs_c * dt_c[..., None]                         # [B,nc,c,H,P]
    y_intra = jnp.einsum("bzij,bzhij,bzjhp->bzihp", scores, l, dtx)

    # chunk final states: S_z = sum_j exp(da_cs[-1]-da_cs[j]) * B_j x_j^T
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)  # [B,nc,c,H]
    s_chunk = jnp.einsum("bzjn,bzjh,bzjhp->bzhpn", bm_c, decay_states, dtx)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])            # [B,nc,H]
    init = (cache["ssd"].astype(jnp.float32) if cache is not None
            else jnp.zeros((b, nheads, hd, n), jnp.float32))

    def scan_fn(h, inp):
        s_c, dec = inp                                   # [B,H,P,N],[B,H]
        h_prev = h
        h = h * dec[..., None, None] + s_c
        return h, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # [B,nc,H,P,N]

    # inter-chunk contribution: C_i . (decay_in * h_prev)
    decay_in = jnp.exp(da_cs)                            # [B,nc,c,H]
    y_inter = jnp.einsum("bzin,bzih,bzhpn->bzihp", cm_c, decay_in, h_prevs)

    y = (y_intra + y_inter).reshape(b, t, nheads, hd)
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, t, d_inner)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", y.astype(x.dtype), p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if cache is not None:
        new_cache = dict(cache,
                         conv=xbc[:, t - (kw - 1):, :] if t >= kw - 1
                         else jnp.concatenate([cache["conv"], xbc], 1)[:, -(kw - 1):],
                         ssd=h_final)
        return out, new_cache
    return out, {}


def mamba_decode_step(p, x, cfg, cache) -> Tuple[jax.Array, Dict]:
    """x [B,1,d]; O(1) recurrent update."""
    b = x.shape[0]
    d_inner, nheads, n = _dims(cfg)
    hd = cfg.ssm_head_dim
    kw = cfg.ssm_conv_dim

    proj = jnp.einsum("btd,de->bte", x, p["in_proj"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, proj)                  # [B,1,*]
    window = jnp.concatenate([cache["conv"], xbc], 1)    # [B,kw,ch]
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv)
    xs = conv[:, :d_inner].reshape(b, nheads, hd).astype(jnp.float32)
    bm = conv[:, d_inner:d_inner + n].astype(jnp.float32)
    cm = conv[:, d_inner + n:].astype(jnp.float32)

    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dtv * a)                               # [B,H]
    h = cache["ssd"].astype(jnp.float32)
    h = h * dec[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xs, bm)
    y = jnp.einsum("bn,bhpn->bhp", cm, h)
    y = y + xs * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_inner)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", y.astype(x.dtype), p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, dict(cache, conv=window[:, 1:], ssd=h)
