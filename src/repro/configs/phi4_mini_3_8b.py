"""Phi-4-mini 3.8B (dense, RoPE SwiGLU GQA). [arXiv:2412.08905]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    source="arXiv:2412.08905",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    activation="swiglu",
    rope_theta=1.0e4,
    tie_embeddings=True,
    sliding_window=16384,   # long_500k variant
)

SMOKE_CONFIG = CONFIG.with_(
    name="phi4-mini-smoke",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512, sliding_window=64, dtype="float32",
)
