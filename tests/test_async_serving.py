"""Async streaming server (PR tentpole): the `repro.serving` subsystem.

Contracts locked down here:

  * the async server's streams are BIT-IDENTICAL to the sync facade at
    temperature 0, per decoder strategy and for mixed
    speculative/greedy/early-exit/sampling batches (the pump + channel
    plumbing must never change a token),
  * mid-stream ``cancel()`` frees the main KV slot, the speculative
    draft-pool slot, and the reserved gamma lookahead -- pool accounting
    returns to baseline while the other request keeps decoding,
  * admission control DEFERS (awaits) rather than raising when the KV
    pool is saturated: everything completes, the live-request count
    respects the watermark, and deferrals are counted,
  * prefix pins: an entry a live request hit cannot be LRU-evicted until
    that request retires/aborts,
  * SLO telemetry: percentiles, queue wait, per-group decode cost,
    attainment fractions.
"""
import asyncio

import numpy as np
import pytest

from repro.api import (AdmissionConfig, EngineConfig, GenerationConfig,
                       LVLM, Request)
from repro.core.serving import Engine
from repro.serving import MetricsRegistry

MAX_NEW = 6
GEN = GenerationConfig(decoder="greedy", temperature=0.0,
                       max_new_tokens=MAX_NEW, gamma=3)


@pytest.fixture(scope="module")
def lvlm():
    return LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)


def _prompts(n, seed=0, lo=8, hi=16):
    rng = np.random.RandomState(seed)
    return [list(rng.randint(1, 512, size=rng.randint(lo, hi)))
            for _ in range(n)]


def _reqs(prompts, new=MAX_NEW, decoders=None):
    reqs = [Request(rid=i, tokens=list(p), max_new_tokens=new)
            for i, p in enumerate(prompts)]
    if decoders:
        for r, d in zip(reqs, decoders):
            r.decoder = d
    return reqs


def _ec(**kw):
    base = dict(max_batch=4, cache_len=96, temperature=0.0)
    base.update(kw)
    return EngineConfig(**base)


async def _consume(stream, cancel_after=None):
    out = []
    async for tok in stream:
        out.append(tok)
        if cancel_after is not None and len(out) >= cancel_after:
            stream.cancel()
            break
    return out


def _serve_all(lvlm, reqs, ec, gen=GEN, admission=None):
    server = lvlm.serve_async(ec, gen=gen, admission=admission)

    async def drive():
        async with server:
            outs = await asyncio.gather(
                *(_consume(server.submit(r)) for r in reqs))
        return outs

    outs = asyncio.run(drive())
    return server, {r.rid: list(o) for r, o in zip(reqs, outs)}


# ------------------------------------------------- golden equivalence --


@pytest.mark.slow
@pytest.mark.parametrize("decoders", [
    None,                                              # default greedy
    ["speculative", "speculative", "speculative"],     # batched spec slots
    ["speculative", "greedy", "early_exit", "sampling"],   # mixed 4-way
], ids=["greedy", "speculative", "mixed"])
def test_async_stream_matches_sync_facade(lvlm, decoders):
    """Every strategy (and a mixed batch) streams the exact tokens the
    sync facade produces at temperature 0."""
    n = len(decoders) if decoders else 3
    prompts = _prompts(n, seed=3)
    sync = lvlm.serve(_reqs(prompts, decoders=decoders), _ec(), gen=GEN)
    ref = {r.rid: list(r.generated) for r in sync.requests}
    _server, got = _serve_all(lvlm, _reqs(prompts, decoders=decoders), _ec())
    assert got == ref


def test_stream_is_incremental_and_summary_complete(lvlm):
    """Tokens arrive over multiple pump iterations (not one burst at the
    end) and the summary carries the full SLO telemetry."""
    req = Request(rid=0, tokens=_prompts(1, seed=4)[0], max_new_tokens=8)
    server = lvlm.serve_async(_ec(), gen=GEN)

    async def drive():
        steps_at_token = []
        async with server:
            async for _ in server.submit(req):
                steps_at_token.append(server.engine.iters)
        return steps_at_token

    steps = asyncio.run(drive())
    assert len(steps) == 8
    assert steps[0] < steps[-1]            # streamed across iterations
    s = server.summary()
    for key in ("ttft_p50", "ttft_p95", "ttft_p99", "tpot_p50",
                "queue_wait_mean", "slo_ttft_attainment", "slo_goodput",
                "decode_cost_by_group", "virtual_time_s"):
        assert key in s, key
    assert s["finished"] == 1 and s["aborted"] == 0
    assert s["decode_cost_by_group"].get("greedy", 0) > 0


# ------------------------------------------------------- cancellation --


def test_abort_frees_slot_draft_pool_and_lookahead(lvlm):
    """Mid-stream cancel: the main slot, the speculative draft-pool slot,
    and the gamma lookahead reservation are all freed while the OTHER
    request keeps decoding; accounting returns to baseline."""
    p0, p1 = _prompts(2, seed=5, lo=10, hi=12)
    r0 = Request(rid=0, tokens=p0, max_new_tokens=24, decoder="speculative")
    r1 = Request(rid=1, tokens=p1, max_new_tokens=24, decoder="speculative")
    server = lvlm.serve_async(_ec(), gen=GEN)
    eng = server.engine

    async def drive():
        async with server:
            s1 = server.submit(r1)
            t1 = asyncio.create_task(_consume(s1))
            s0 = server.submit(r0)
            out0 = await _consume(s0, cancel_after=2)
            # r1 is still mid-decode here: pool accounting must already be
            # back to exactly r1's reservation (incl. its gamma lookahead)
            slot0 = r0._slot
            mid = dict(
                slot_freed=eng.slot_req[slot0] is None,
                draft_freed=slot0 not in
                eng._decoders["speculative"].bound_slots(),
                committed=eng.kv_committed_tokens(),
                r1_need=eng.kv_request_tokens(r1),
                r1_live=len(r1.generated) < 24,
                stream_aborted=s0.aborted)
            out1 = await t1
            return out0, out1, mid

    out0, out1, mid = asyncio.run(drive())
    assert 2 <= len(out0) < 24 and r0.aborted
    assert len(out1) == 24 and not r1.aborted
    assert mid["slot_freed"] and mid["draft_freed"] and mid["r1_live"]
    assert mid["stream_aborted"]
    assert mid["committed"] == mid["r1_need"]        # baseline + r1 only
    # gamma lookahead really is part of the reservation
    assert mid["r1_need"] >= len(p1) + 24 + GEN.gamma
    # after the run everything is back to zero
    assert eng.kv_committed_tokens() == 0
    assert all(r is None for r in eng.slot_req)
    assert eng._decoders["speculative"].bound_slots() == set()
    s = server.summary()
    assert s["aborted"] == 1 and s["finished"] == 1


def test_abort_waiting_request_and_unknown_rid(lvlm):
    """Abort of a not-yet-prefilled (waiting) request and of an unknown
    rid are both clean; Engine.aborted records the cancelled one."""
    eng = Engine(lvlm.model, lvlm.params,
                 EngineConfig(max_batch=1, cache_len=96))
    r0 = Request(rid=0, tokens=list(range(1, 10)), max_new_tokens=4)
    eng.submit(r0)
    assert eng.abort(0) is True
    assert eng.abort(0) is False                 # already gone
    assert eng.abort(99) is False
    assert r0.aborted and eng.waiting == [] and eng.aborted == [r0]
    assert eng.kv_committed_tokens() == 0


def test_cancel_before_first_anext_and_duplicate_rid(lvlm):
    """Regressions: cancel() BEFORE the stream is ever iterated must
    still abort (the request never enters the engine), and a duplicate
    rid submit fails fast instead of orphaning the first stream."""
    server = lvlm.serve_async(_ec(), gen=GEN)

    async def drive():
        async with server:
            live = server.submit(Request(rid=1, tokens=[5, 6, 7],
                                         max_new_tokens=4))
            dead = server.submit(Request(rid=0, tokens=[1, 2, 3],
                                         max_new_tokens=4))
            with pytest.raises(ValueError):
                server.submit(Request(rid=0, tokens=[9], max_new_tokens=1))
            assert dead.cancel() is True
            out_dead = await _consume(dead)      # ends without admitting
            out_live = await _consume(live)
            return out_dead, out_live

    out_dead, out_live = asyncio.run(drive())
    assert out_dead == [] and out_live and len(out_live) == 4
    assert server.engine.kv_committed_tokens() == 0
    assert server.summary()["aborted"] == 1
    assert not any(r.rid == 0 for r in server.engine.finished)


def test_pump_failure_propagates_instead_of_hanging(lvlm):
    """Regression: an exception inside the pump (engine.step) must fail
    every live stream and re-raise at stop() -- never leave consumers
    awaiting a sentinel forever."""
    server = lvlm.serve_async(_ec(), gen=GEN)
    boom = RuntimeError("injected step failure")

    def bad_step():
        raise boom

    async def drive():
        async with server:
            server.engine.step = bad_step
            stream = server.submit(Request(rid=0, tokens=[1, 2, 3],
                                           max_new_tokens=4))
            with pytest.raises(RuntimeError, match="injected"):
                await asyncio.wait_for(_consume(stream), timeout=10)

    with pytest.raises(RuntimeError, match="injected"):
        asyncio.run(drive())             # stop() re-raises the pump error
    assert server._pump_error is boom


# ---------------------------------------------------------- admission --


def test_admission_defers_instead_of_raising_when_saturated(lvlm):
    """KV saturation => submits WAIT at the admission gate (no
    OutOfBlocksError-style crash, no engine overcommit): live requests
    never exceed what the high watermark allows, yet every request
    completes."""
    prompts = _prompts(5, seed=7, lo=12, hi=15)
    reqs = _reqs(prompts)
    # capacity 4*64=256; each request needs 32 (block-rounded prompt+new);
    # high=0.25 -> 64 tokens -> at most TWO live requests at a time
    adm = AdmissionConfig(high_watermark=0.25, low_watermark=0.15)
    server = lvlm.serve_async(_ec(cache_len=64), gen=GEN, admission=adm)
    eng = server.engine
    peak = 0

    async def consume(r):
        nonlocal peak
        out = []
        async for tok in server.submit(r):
            peak = max(peak, len(eng.waiting) + len(eng.running))
            out.append(tok)
        return out

    async def drive():
        async with server:
            return await asyncio.gather(*(consume(r) for r in reqs))

    outs = asyncio.run(drive())
    assert all(len(o) == MAX_NEW for o in outs)
    assert peak <= 2
    assert server.admission.deferrals >= 3
    assert server.admission.queue_depth == 0
    assert server.summary()["queue_wait_p99"] > 0


def test_oversized_deferred_request_raises_without_killing_pump(lvlm):
    """Regression: an impossible request (can NEVER fit a slot) that got
    PARKED at the admission gate (busy engine) must surface its
    ValueError to ITS caller when the drain reaches it -- never detonate
    inside the pump and fail every other stream."""
    ok = Request(rid=0, tokens=_prompts(1, seed=15, lo=12, hi=13)[0],
                 max_new_tokens=MAX_NEW)
    big = Request(rid=1, tokens=list(range(1, 40)), max_new_tokens=64)
    adm = AdmissionConfig(high_watermark=0.2, low_watermark=0.2)
    server = lvlm.serve_async(_ec(cache_len=64), gen=GEN, admission=adm)

    async def drive():
        async with server:
            t0 = asyncio.create_task(_consume(server.submit(ok)))
            await asyncio.sleep(0)          # let `ok` reach the engine first
            with pytest.raises(ValueError, match="needs"):
                await _consume(server.submit(big))     # parked, then drained
            return await t0

    out0 = asyncio.run(drive())
    assert len(out0) == MAX_NEW                        # pump survived
    assert server._pump_error is None
    assert server.admission.deferrals == 1
    assert server.engine.kv_committed_tokens() == 0


def test_admission_single_oversized_request_still_progresses(lvlm):
    """An idle engine always admits (a lone request must progress even if
    it alone exceeds the high watermark fraction)."""
    reqs = _reqs(_prompts(1, seed=8, lo=30, hi=31), new=8)
    adm = AdmissionConfig(high_watermark=0.05, low_watermark=0.05)
    _server, got = _serve_all(lvlm, reqs, _ec(), admission=adm)
    assert len(got[0]) == 8


# -------------------------------------------------------- prefix pins --


def test_prefix_pin_blocks_eviction_until_release(lvlm):
    """An entry a live request hit is pinned: LRU eviction must skip it
    until the request retires (then eviction works again)."""
    eng = Engine(lvlm.model, lvlm.params,
                 EngineConfig(max_batch=1, cache_len=64, prefix_cache=True,
                              prefix_block=4, prefix_cap=1))
    a = list(range(1, 9))
    eng._prefix_insert(a, 0, 8)
    req = Request(rid=0, tokens=a + [99], max_new_tokens=2)
    eng.submit(req)
    eng.step()                                    # prefill: hits + pins A
    key = ("none", tuple(a))      # prefix keys carry the compression variant
    assert eng._prefix_pins.get(key, 0) == 1
    eng._prefix_insert(list(range(101, 109)), 0, 8)   # over cap: A pinned
    assert key in eng._prefix
    while eng.step():
        pass                                      # req retires -> unpin
    assert eng._prefix_pins == {}
    eng._prefix_insert(list(range(201, 209)), 0, 8)   # now A can go
    assert key not in eng._prefix


# ----------------------------------------------- pacing & disconnects --


def test_wall_pacing_sleeps_per_step_virtual_durations(lvlm, monkeypatch):
    """pacing="wall": after every engine step the pump sleeps that step's
    virtual duration x pacing_scale (real per-step latency estimate);
    pacing="virtual" never sleeps a positive duration. Tokens are
    identical either way. Sleeps are recorded, not timed, so the test is
    deterministic."""
    recorded = []
    real_sleep = asyncio.sleep

    async def spy_sleep(dt, *a, **kw):
        recorded.append(dt)
        await real_sleep(0)

    monkeypatch.setattr(asyncio, "sleep", spy_sleep)
    outs = {}
    for pacing in ("virtual", "wall"):
        recorded.clear()
        server = lvlm.serve_async(_ec(), gen=GEN, pacing=pacing,
                                  pacing_scale=3.0)
        reqs = _reqs(_prompts(2, seed=11))
        outs[pacing] = _serve_all_on(server, reqs)
        if pacing == "virtual":
            assert all(dt == 0 for dt in recorded)
        else:
            slept = sum(dt for dt in recorded if dt > 0)
            assert slept == pytest.approx(server.engine.clock * 3.0,
                                          rel=1e-6)
    assert outs["wall"] == outs["virtual"]


def _serve_all_on(server, reqs):
    async def drive():
        async with server:
            outs = await asyncio.gather(
                *(_consume(server.submit(r)) for r in reqs))
        return outs

    outs = asyncio.run(drive())
    return {r.rid: list(o) for r, o in zip(reqs, outs)}


def test_bad_pacing_rejected(lvlm):
    with pytest.raises(ValueError, match="pacing"):
        lvlm.serve_async(_ec(), gen=GEN, pacing="warp")


def test_disconnect_timeout_aborts_stalled_consumer(lvlm):
    """A consumer that stops reading for disconnect_timeout_s wall
    seconds is treated as hung up: its request is Engine.abort-ed and the
    slot / speculative draft row / gamma lookahead / pool accounting
    return to baseline while a live consumer keeps streaming."""
    p0, p1 = _prompts(2, seed=12, lo=10, hi=12)
    r_stall = Request(rid=0, tokens=p0, max_new_tokens=24,
                      decoder="speculative")
    r_live = Request(rid=1, tokens=p1, max_new_tokens=24)
    server = lvlm.serve_async(_ec(), gen=GEN, disconnect_timeout_s=0.05)
    eng = server.engine
    # pace the (wall-time-free virtual) engine so the stalled request
    # CANNOT finish before the timeout trips: >= 20ms per step means 24
    # tokens need >= 120ms of work, while the 50ms timeout fires after
    # ~3 steps of consumer silence -- deterministic, not a wall-clock race
    real_step = eng.step

    def paced_step():
        import time
        time.sleep(0.02)
        return real_step()

    eng.step = paced_step

    async def drive():
        async with server:
            s0 = server.submit(r_stall)
            t1 = asyncio.create_task(_consume(server.submit(r_live)))
            got = []
            async for tok in s0:
                got.append(tok)
                if len(got) == 2:
                    await asyncio.sleep(0.5)     # consumer goes silent
            out1 = await t1
            return got, out1, s0

    got, out1, s0 = asyncio.run(drive())
    assert r_stall.aborted and s0.aborted and s0.disconnected
    assert 2 <= len(got) < 24                    # stream ended early
    assert len(out1) == 24 and not r_live.aborted
    assert server.disconnects == 1
    # pool accounting back to baseline: no slot, no draft row, no KV
    assert eng.kv_committed_tokens() == 0
    assert all(r is None for r in eng.slot_req)
    assert eng._decoders["speculative"].bound_slots() == set()
    s = server.summary()
    assert s["aborted"] == 1 and s["finished"] == 1 and s["disconnects"] == 1


def test_waiting_consumer_is_not_a_disconnect(lvlm):
    """A consumer blocked INSIDE __anext__ (waiting on the engine) or
    promptly draining each token is never treated as hung up, even with
    an absurdly tight timeout -- only queued-unread tokens count."""
    server = lvlm.serve_async(_ec(), gen=GEN, disconnect_timeout_s=1e-9)
    out = _serve_all_on(server, [Request(rid=0,
                                         tokens=_prompts(1, seed=13)[0],
                                         max_new_tokens=MAX_NEW)])
    assert len(out[0]) == MAX_NEW
    assert server.disconnects == 0


# -------------------------------------------------- slack admission --


@pytest.mark.parametrize("order", ["fifo", "slack"])
def test_deferred_queue_order(lvlm, order):
    """Saturated gate: with order="slack" the tighter-deadline waiter is
    admitted first even though it queued SECOND; strict FIFO preserves
    submission order. (The cluster layer's SLO-aware dispatch is exactly
    this, per replica.)"""
    prompts = _prompts(3, seed=14, lo=12, hi=15)
    r0 = Request(rid=0, tokens=prompts[0], max_new_tokens=MAX_NEW)
    relaxed = Request(rid=1, tokens=prompts[1], max_new_tokens=MAX_NEW)
    urgent = Request(rid=2, tokens=prompts[2], max_new_tokens=MAX_NEW)
    relaxed.slo.ttft_ms = 60_000.0
    urgent.slo.ttft_ms = 1.0
    adm = AdmissionConfig(high_watermark=0.9, low_watermark=0.9,
                          max_inflight=1, order=order)
    server = lvlm.serve_async(_ec(), gen=GEN, admission=adm)

    async def drive():
        async with server:
            s0 = server.submit(r0)             # occupies the single slot
            s_relaxed = server.submit(relaxed)  # queues first
            s_urgent = server.submit(urgent)    # queues second, tight SLO
            outs = await asyncio.gather(_consume(s0), _consume(s_relaxed),
                                        _consume(s_urgent))
            return outs, s_relaxed, s_urgent

    outs, s_relaxed, s_urgent = asyncio.run(drive())
    assert all(len(o) == MAX_NEW for o in outs)
    assert server.admission.deferrals == 2
    if order == "slack":
        assert s_urgent.admit_clock < s_relaxed.admit_clock
    else:
        assert s_relaxed.admit_clock < s_urgent.admit_clock


# ------------------------------------------------------------ metrics --


def test_metrics_registry_shared_and_slo_flags(lvlm):
    """A shared registry aggregates across servers; SLO flags follow the
    per-request targets."""
    reg = MetricsRegistry()
    prompts = _prompts(2, seed=9)
    for _k in range(2):
        reqs = _reqs(prompts)
        reqs[0].slo.ttft_ms = 1e-9                # impossible target
        server = lvlm.serve_async(_ec(), gen=GEN, metrics=reg)

        async def drive(server=server, reqs=reqs):
            async with server:
                await asyncio.gather(
                    *(_consume(server.submit(r)) for r in reqs))

        asyncio.run(drive())
    assert len(reg.records) == 4
    s = reg.summary()
    assert s["finished"] == 4
    assert s["slo_ttft_attainment"] == 0.5        # rid 0 misses both runs
    by_rid = [r for r in reg.records if r.rid == 0]
    assert all(not r.ttft_ok for r in by_rid)
    assert all(r.decoder == "greedy" for r in reg.records)
