"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        kv_len: int | None = None,
                        window: int = 0) -> jax.Array:
    """Grouped-query attention oracle.

    q: [B, H, Sq, D];  k, v: [B, KVH, Sk, D];  H = KVH * G.
    ``kv_len``: only the first kv_len keys are valid (padding mask).
    ``window`` > 0: sliding-window causal attention.
    Returns [B, H, Sq, D] in q.dtype (accumulation in f32).
    """
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    qf = q.reshape(b, kvh, g, sq, d).astype(jnp.float32) / (d ** 0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qf, kf)
    q_pos = jnp.arange(sq)
    k_pos = jnp.arange(sk)
    valid = jnp.ones((sq, sk), bool)
    if kv_len is not None:
        valid = valid & (k_pos[None, :] < kv_len)
    if causal:
        # decode convention: q block sits at the END of the kv sequence
        offset = (kv_len if kv_len is not None else sk) - sq
        valid = valid & (k_pos[None, :] <= q_pos[:, None] + offset)
        if window:
            valid = valid & (k_pos[None, :] > q_pos[:, None] + offset - window)
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p, vf)
    return o.reshape(b, h, sq, d).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_table, seq_lens
                        ) -> jax.Array:
    """Decode attention over a paged KV pool, oracle.

    q          : [B, H, D]           one query token per request
    k_pages    : [P, page, KVH, D]   physical page pool
    v_pages    : [P, page, KVH, D]
    block_table: [B, pages_per_seq]  int32 physical page ids
    seq_lens   : [B]                 int32 valid tokens per request
    Returns [B, H, D].
    """
    b, h, d = q.shape
    p_total, page, kvh, _ = k_pages.shape
    pages_per_seq = block_table.shape[1]
    g = h // kvh
    # gather the logical KV for each request: [B, pages*page, KVH, D]
    k_log = k_pages[block_table].reshape(b, pages_per_seq * page, kvh, d)
    v_log = v_pages[block_table].reshape(b, pages_per_seq * page, kvh, d)
    qf = q.reshape(b, kvh, g, d).astype(jnp.float32) / (d ** 0.5)
    s = jnp.einsum("bkgd,bckd->bkgc", qf, k_log.astype(jnp.float32))
    pos = jnp.arange(pages_per_seq * page)
    valid = pos[None] < seq_lens[:, None]                   # [B, C]
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", p, v_log.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)
