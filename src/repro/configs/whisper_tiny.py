"""Whisper-tiny (encoder-decoder audio; conv frontend stubbed). [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
input_specs() supplies precomputed frame embeddings (encoder_seq x d_model).
long_500k is SKIPPED for this arch (source context <= 30s audio / 1500
frames; decoder max 448) -- see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=4,               # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    encoder_seq=1500,           # 30 s at 50 frames/s after conv stride
    decoder_max_seq=448,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.with_(
    name="whisper-smoke",
    num_layers=2, encoder_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=512, encoder_seq=32, decoder_max_seq=64,
    dtype="float32",
)
