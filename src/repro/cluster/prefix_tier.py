"""``SharedPrefixTier``: one cluster-wide prefix-KV cache over N engines.

Per-replica prefix caches only pay off when the SAME replica sees the
same prefix again -- under round-robin dispatch, or in a role-split
fleet where the prefill replicas cache what the rest never sees, every
other replica pays its own cold prefill. This tier promotes cached
prefixes to a fleet-shared, radix-keyed structure:

  * every ``Engine._prefix_insert`` also publishes (variant, tokens,
    snapshot) here;
  * every ``Engine._prefix_lookup`` probes here after its local cache --
    a LONGER remote hit wins, the engine installs the snapshot into its
    local cache (so later lookups are local) and charges one modeled
    KV-link transfer (``CostModel.transfer_time``) to the step that used
    it. A prefix prefilled on ANY replica short-circuits prefill on
    every replica.

Keys are radix: per compression variant, a trie over fixed-size token
BLOCKS (the engines' ``prefix_block``), so a lookup walks the prompt
block-by-block in O(prompt/block) dict probes and the deepest node with
a snapshot is the longest shared prefix -- no per-entry scans, and
sibling prefixes share their common path. Snapshots are immutable jax
arrays, shared by reference across engines (install slices them into a
slot; nothing mutates them in place).

Eviction is LRU over entries (touched on hit); evicting here is always
safe -- engines pin only their LOCAL copies, and a request decoding from
a tier hit holds a local pin, never a tier reference. The tier is plain
event-loop-confined Python like everything above the engine: no locks.
"""
from __future__ import annotations

import collections
from typing import Dict, Optional, Tuple


class _Node:
    """One radix-trie node: children keyed by the next token block."""
    __slots__ = ("children", "snap")

    def __init__(self):
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.snap = None            # KV snapshot covering the path here


class SharedPrefixTier:
    """Fleet-shared radix prefix cache (see module docstring).

    Duck-typed against ``Engine.prefix_share``: ``lookup(variant,
    tokens, *, block, touch)`` -> ``(k, snap)`` with ``k == 0`` on miss,
    and ``insert(variant, tokens, snap, k)``.
    """

    def __init__(self, block: int, cap: int = 256):
        if block <= 0:
            raise ValueError("block must be positive")
        self.block = block
        self.cap = cap
        self._roots: Dict[str, _Node] = {}          # variant -> trie root
        # recency over entries: (variant, key tokens) in LRU order; the
        # value is the node holding the snapshot
        self._lru: "collections.OrderedDict[Tuple, _Node]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._lru)

    # ------------------------------------------------------------ probe --
    def lookup(self, variant: str, tokens, *, block: int,
               touch: bool = True) -> Tuple[int, Optional[object]]:
        """Longest cached prefix of ``tokens`` under ``variant``. Returns
        ``(k, snap)``; ``(0, None)`` on miss or when the caller's block
        size disagrees with the tier's (mixed-granularity fleets never
        share keys)."""
        if block != self.block:
            return 0, None
        t = tuple(int(x) for x in tokens)
        node = self._roots.get(variant)
        best_k, best = 0, None
        i = 0
        while node is not None and i + self.block <= len(t):
            node = node.children.get(t[i:i + self.block])
            if node is None:
                break
            i += self.block
            if node.snap is not None:
                best_k, best = i, node.snap
        if best is None:
            self.misses += 1
            return 0, None
        self.hits += 1
        if touch:
            self._lru.move_to_end((variant, t[:best_k]))
        return best_k, best

    # ----------------------------------------------------------- insert --
    def insert(self, variant: str, tokens, snap, k: int) -> None:
        """Publish a ``k``-token prefix snapshot (``k`` must be a positive
        multiple of the tier's block; shorter/ragged keys are ignored --
        the publishing engine aligned them already)."""
        if k <= 0 or k % self.block != 0:
            return
        t = tuple(int(x) for x in tokens)[:k]
        if len(t) < k:
            return
        key = (variant, t)
        if key in self._lru:
            self._lru.move_to_end(key)              # re-insert = LRU touch
            return
        node = self._roots.setdefault(variant, _Node())
        for i in range(0, k, self.block):
            node = node.children.setdefault(t[i:i + self.block], _Node())
        node.snap = snap
        self._lru[key] = node
        self.inserts += 1
        while len(self._lru) > self.cap:
            self._evict_one()

    def _evict_one(self) -> None:
        (variant, t), node = self._lru.popitem(last=False)
        node.snap = None
        self.evictions += 1
        # prune now-useless trie nodes (no snapshot, no children) so a
        # long-dead prefix family does not pin its whole path forever
        self._prune(variant, t)

    def _prune(self, variant: str, t: Tuple[int, ...]) -> None:
        root = self._roots.get(variant)
        if root is None:
            return
        path = [root]
        node = root
        for i in range(0, len(t), self.block):
            node = node.children.get(t[i:i + self.block])
            if node is None:
                return          # path already gone
            path.append(node)
        for depth in range(len(path) - 1, 0, -1):
            node = path[depth]
            if node.children or node.snap is not None:
                break
            edge = t[(depth - 1) * self.block:depth * self.block]
            del path[depth - 1].children[edge]
        if not root.children and root.snap is None:
            self._roots.pop(variant, None)

    # ---------------------------------------------------------- reports --
    def stats(self) -> Dict:
        probes = self.hits + self.misses
        return {
            "entries": len(self._lru),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / probes if probes else 0.0,
            "inserts": self.inserts,
            "evictions": self.evictions,
        }
