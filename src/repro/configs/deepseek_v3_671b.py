"""DeepSeek-V3 (671B MoE, MLA, 1 shared + 256 routed top-8). [arXiv:2412.19437]

The assignment's "GQA kv=128" reflects MLA's 128 effective heads; the cache
is the compressed latent (kv_lora_rank + rope dim), which is itself a
KV-cache-compression technique in the survey's dimension 2.
MTP (multi-token prediction) is implemented as an extra prediction head
(see models/transformer.py mtp option) used by speculative decoding (dim 4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,            # MLA: all heads share the latent cache
    head_dim=128,
    d_ff=18432,                  # dense-layer FFN width
    vocab_size=129280,
    activation="swiglu",
    rope_theta=1.0e4,
    # MoE
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,               # assigned d_ff=2048 = per-expert width
    first_k_dense_layers=3,
    # MLA
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    sliding_window=16384,        # long_500k variant
)

SMOKE_CONFIG = CONFIG.with_(
    name="deepseek-v3-smoke",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=512, vocab_size=512,
    num_experts=4, experts_per_token=2, num_shared_experts=1, moe_d_ff=128,
    first_k_dense_layers=1,
    q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32, qk_rope_head_dim=16,
    v_head_dim=32, sliding_window=64, dtype="float32",
)
