from repro.models.registry import build
from repro.models.transformer import Model

__all__ = ["build", "Model"]
