"""Early exit / layer skipping (survey dim 4b): AdaInfer-style adaptive depth.

The surveyed observation: "easy" tokens saturate early -- their logit-lens
prediction stops changing after a fraction of the layers -- so a confidence
classifier can terminate the decode pass early and save the remaining
layers' FLOPs.

AdaInfer trains an SVM on per-layer statistical features; here we implement
the training-free confidence variant (logit-lens max-probability threshold)
which is the common baseline in that line of work:

    after layer l:  p_l = softmax(unembed(norm(h_l)));  exit if max p_l > tau
    plus a stability criterion: argmax unchanged for ``patience`` layers.

The decode step runs as a host-side Python loop over UNSTACKED layer params
(the introspection path -- transformer.py's scanned path is for the
production mesh), so the exit is a real break: layers after the exit are
never executed. Returns per-token depth used, giving the FLOPs-saved metric
the benchmarks report.

Applicability (DESIGN §3): dense / vlm / moe decode paths. For SSM the notion
of "skipping remaining layers" still applies but invalidates the recurrent
state of skipped layers for FUTURE tokens -- the survey flags this as an open
problem; we restrict to attention families where the KV cache of skipped
layers can simply be back-filled with the layer input (identity skip).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.transformer import _dense_layer_decode


def _slice_layer(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _set_layer(tree, i, sub):
    return jax.tree.map(lambda a, s: a.at[i].set(s), tree, sub)


def layer_confidences(model, params, cache, tokens, pos) -> jax.Array:
    """Diagnostic: run ALL layers, return [num_layers] logit-lens max-prob."""
    cfg = model.cfg
    x = L.embed_tokens(params["embed"], tokens)
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (b,))
    cos, sin = model._cos_sin(b, pos[:, None])
    confs = []
    n = cfg.num_layers - cfg.first_k_dense_layers \
        if cfg.family == "moe" else cfg.num_layers
    for i in range(n):
        lp = _slice_layer(params["layers"], i)
        lcache = _slice_layer(cache["layers"], i)
        x, _ = _dense_layer_decode(cfg, lp, x, cos, sin, lcache, pos,
                                   window=0)
        h = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = L.unembed(params["embed"], h, cfg.logits_softcap)
        confs.append(jnp.max(jax.nn.softmax(logits[:, 0], -1), -1))
    return jnp.stack(confs)            # [L, B]


def early_exit_decode_step(model, params, cache, tokens, pos, *,
                           threshold: float = 0.9, patience: int = 2,
                           min_layers: int = 2
                           ) -> Tuple[jax.Array, Dict, Dict]:
    """One decode step with confidence-based early exit.

    Returns (logits [B,V], new_cache, info) where info['layers_used'] is the
    actual depth executed (int) and info['exited'] whether the threshold
    fired. Batch exits jointly (min confidence across batch), matching
    AdaInfer's batched serving variant.
    """
    cfg = model.cfg
    if cfg.family not in ("dense", "vlm", "moe"):
        raise NotImplementedError("early exit targets attention families")
    if cfg.family == "moe" and cfg.first_k_dense_layers:
        raise NotImplementedError("early exit w/ dense-prefix MoE unsupported")
    x = L.embed_tokens(params["embed"], tokens)
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (b,))
    cos, sin = model._cos_sin(b, pos[:, None])

    new_layer_cache = cache["layers"]
    n = cfg.num_layers
    last_argmax = None
    stable = 0
    exited = False
    logits = None
    used = n
    for i in range(n):
        lp = _slice_layer(params["layers"], i)
        lcache = _slice_layer(cache["layers"], i)
        x, lcache = _dense_layer_decode(cfg, lp, x, cos, sin, lcache, pos,
                                        window=0)
        new_layer_cache = _set_layer(new_layer_cache, i, lcache)
        if i + 1 < min_layers:
            continue
        h = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = L.unembed(params["embed"], h, cfg.logits_softcap)[:, 0]
        probs = jax.nn.softmax(logits, -1)
        conf = float(jnp.min(jnp.max(probs, -1)))
        am = jnp.argmax(logits, -1)
        if last_argmax is not None and bool(jnp.all(am == last_argmax)):
            stable += 1
        else:
            stable = 0
        last_argmax = am
        if conf > threshold and stable >= patience:
            used = i + 1
            exited = True
            break
    if logits is None:                  # min_layers == n edge case
        h = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = L.unembed(params["embed"], h, cfg.logits_softcap)[:, 0]

    if exited:
        # identity skip: back-fill skipped layers' KV with the exit hidden
        # state so FUTURE tokens see a consistent cache (standard early-exit
        # cache-propagation fix).
        from repro.models import attention as attn
        for i in range(used, n):
            lp = _slice_layer(params["layers"], i)
            lcache = _slice_layer(cache["layers"], i)
            h = L.apply_norm(lp["ln1"], x, cfg.norm)
            if cfg.use_mla:
                _, lcache = attn.mla_decode_attention(
                    lp["attn"], h, cos, sin, cfg, lcache, pos)
            else:
                _, lcache = attn.decode_attention(
                    lp["attn"], h, cos, sin, cfg, lcache, pos)
            new_layer_cache = _set_layer(new_layer_cache, i, lcache)

    info = {"layers_used": used, "exited": exited,
            "flops_frac": used / n}
    return logits, dict(cache, layers=new_layer_cache), info
