"""Tiered heterogeneous KV storage (survey dim 2b-iii): InfLLM / FlexGen /
PQCache / SqueezedAttention flavors.

HBM tier = device arrays; HOST tier = numpy (stands in for CPU DRAM/NVMe).
Every cross-tier move is metered against configured link bandwidths so the
benchmarks report realistic transfer budgets (PCIe-class for host<->HBM).
Retrieval supports:
  * block-mean index      (InfLLM representative keys)
  * k-means centroids     (SqueezedAttention clustering)
  * product quantization  (PQCache codes; asymmetric distance scoring)
plus an async-prefetch simulator that overlaps fetch with compute.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.roofline.hw import KV_LINK_GBPS


@dataclasses.dataclass
class TierStats:
    bytes_to_host: int = 0
    bytes_to_hbm: int = 0
    fetches: int = 0
    offloads: int = 0

    def transfer_seconds(self, gbps: float = KV_LINK_GBPS) -> float:
        """Total transfer time at ``gbps`` GB/s (shared KV-link constant
        from repro.roofline.hw; pass ``gbps`` to model a different link)."""
        return (self.bytes_to_host + self.bytes_to_hbm) / (gbps * 1e9)


class TieredKVStore:
    """Block-granular two-tier store for one layer's K/V."""

    def __init__(self, block_size: int, num_kv_heads: int, head_dim: int,
                 hbm_capacity_blocks: int, dtype=np.float32,
                 index: str = "mean", pq_subvectors: int = 4,
                 n_centroids: int = 16):
        self.block_size = block_size
        self.h = num_kv_heads
        self.d = head_dim
        self.cap = hbm_capacity_blocks
        self.dtype = dtype
        self.index_kind = index
        self.pq_m = pq_subvectors
        self.n_centroids = n_centroids
        # tiers: block id -> array [block, H, D]
        self.hbm_k: Dict[int, np.ndarray] = {}
        self.hbm_v: Dict[int, np.ndarray] = {}
        self.host_k: Dict[int, np.ndarray] = {}
        self.host_v: Dict[int, np.ndarray] = {}
        self.reprs: Dict[int, np.ndarray] = {}   # block -> index feature
        self.lru: List[int] = []
        self.stats = TierStats()
        self._pq_codebook: Optional[np.ndarray] = None

    def _bytes(self, arr) -> int:
        return arr.nbytes * 2     # K and V

    # ------------------------------------------------------------ insert --
    def insert_block(self, blk_id: int, k: np.ndarray, v: np.ndarray):
        """k/v [block, H, D]; newest blocks live in HBM, evicting LRU."""
        self.hbm_k[blk_id] = k
        self.hbm_v[blk_id] = v
        self.lru.append(blk_id)
        self.reprs[blk_id] = self._make_repr(k)
        while len(self.hbm_k) > self.cap:
            victim = self.lru.pop(0)
            if victim not in self.hbm_k:
                continue
            self.host_k[victim] = self.hbm_k.pop(victim)
            self.host_v[victim] = self.hbm_v.pop(victim)
            self.stats.offloads += 1
            self.stats.bytes_to_host += self._bytes(self.host_k[victim])

    def _make_repr(self, k: np.ndarray) -> np.ndarray:
        flat = k.reshape(k.shape[0], -1).astype(np.float32)
        if self.index_kind == "mean":
            return flat.mean(0)
        if self.index_kind == "kmeans":
            return _kmeans_centroids(flat, min(self.n_centroids, len(flat)))
        if self.index_kind == "pq":
            if self._pq_codebook is None:
                self._pq_codebook = _pq_train(flat, self.pq_m,
                                              self.n_centroids)
            return _pq_encode(flat, self._pq_codebook, self.pq_m)
        raise ValueError(self.index_kind)

    # ----------------------------------------------------------- retrieve --
    def score_blocks(self, query: np.ndarray) -> Dict[int, float]:
        """query [H,D] (current step's mean query) -> block scores."""
        q = query.reshape(-1).astype(np.float32)
        out = {}
        for blk, rep in self.reprs.items():
            if self.index_kind == "mean":
                out[blk] = float(rep @ q)
            elif self.index_kind == "kmeans":
                out[blk] = float((rep @ q).max())
            else:  # pq: asymmetric distance via codebook lookup
                out[blk] = float(_pq_score(rep, q, self._pq_codebook,
                                           self.pq_m))
        return out

    def fetch_topk(self, query: np.ndarray, k: int
                   ) -> Tuple[List[int], np.ndarray, np.ndarray]:
        """SparQ/InfLLM retrieval: top-k blocks by index score; host blocks
        are paged back into HBM (metered)."""
        scores = self.score_blocks(query)
        top = sorted(scores, key=scores.get, reverse=True)[:k]
        ks, vs = [], []
        for blk in top:
            if blk in self.host_k:
                self.hbm_k[blk] = self.host_k.pop(blk)
                self.hbm_v[blk] = self.host_v.pop(blk)
                self.stats.fetches += 1
                self.stats.bytes_to_hbm += self._bytes(self.hbm_k[blk])
                self.lru.append(blk)
            ks.append(self.hbm_k[blk])
            vs.append(self.hbm_v[blk])
        return top, np.concatenate(ks, 0), np.concatenate(vs, 0)

    def residency(self) -> Dict:
        return {"hbm_blocks": len(self.hbm_k),
                "host_blocks": len(self.host_k),
                "stats": dataclasses.asdict(self.stats)}


# -------------------------------------------------------------------------
# prefetch overlap simulator
# -------------------------------------------------------------------------

def prefetch_schedule(compute_us_per_step: float, fetch_us_per_block: float,
                      blocks_per_step: int, steps: int,
                      overlap: bool = True) -> Dict:
    """Latency model for InfLLM-style async prefetching.

    With overlap, fetch of step t+1's blocks hides under step t's compute;
    exposed latency = max(0, fetch - compute) per step. Without, they add.
    """
    fetch = fetch_us_per_block * blocks_per_step
    if overlap:
        exposed = max(0.0, fetch - compute_us_per_step)
        total = compute_us_per_step * steps + exposed * (steps - 1) + fetch
    else:
        total = (compute_us_per_step + fetch) * steps
    return {"total_us": total,
            "exposed_fetch_frac": 0.0 if not overlap else
            max(0.0, fetch - compute_us_per_step) / max(fetch, 1e-9)}


# -------------------------------------------------------------------------
# small numpy kmeans / PQ helpers (deterministic)
# -------------------------------------------------------------------------

def _kmeans_centroids(x: np.ndarray, k: int, iters: int = 8) -> np.ndarray:
    idx = np.linspace(0, len(x) - 1, k).astype(int)
    c = x[idx].copy()
    for _ in range(iters):
        d = ((x[:, None] - c[None]) ** 2).sum(-1)
        a = d.argmin(1)
        for j in range(k):
            m = a == j
            if m.any():
                c[j] = x[m].mean(0)
    return c


def _pq_train(x: np.ndarray, m: int, k: int) -> np.ndarray:
    dim = x.shape[1]
    sub = dim // m
    books = []
    for i in range(m):
        books.append(_kmeans_centroids(x[:, i * sub:(i + 1) * sub], k))
    return np.stack(books)            # [m, k, sub]


def _pq_encode(x: np.ndarray, books: np.ndarray, m: int) -> np.ndarray:
    dim = x.shape[1]
    sub = dim // m
    codes = []
    for i in range(m):
        d = ((x[:, None, i * sub:(i + 1) * sub] - books[i][None]) ** 2).sum(-1)
        codes.append(d.argmin(1))
    return np.stack(codes, 1).astype(np.int32)     # [n, m]


def _pq_score(codes: np.ndarray, q: np.ndarray, books: np.ndarray,
              m: int) -> float:
    dim = q.shape[0]
    sub = dim // m
    # asymmetric: dot(query_sub, centroid) table lookup, max over tokens
    table = np.stack([books[i] @ q[i * sub:(i + 1) * sub]
                      for i in range(m)])          # [m, k]
    scores = table[np.arange(m)[None], codes].sum(1)   # [n]
    return float(scores.max())
