"""Sparse Mixture-of-Experts (survey dim 3: "Sparse MoE for LVLMs").

Implements the MoE-LLaVA / DeepSeek-VL2 / Arctic family of designs:
  * top-k softmax router with renormalized gates,
  * capacity-bounded sort-based dispatch (tokens sorted by expert id and
    scattered into an [E, C, d] buffer -> batched expert matmul -> combine),
    the TPU-idiomatic equivalent of GPU grouped-GEMM dispatch. Under an
    ``experts -> model`` sharding this is what produces the all-to-all /
    collective traffic the dry-run measures;
  * optional shared experts (DeepSeek-V3: always-on experts),
  * optional parallel dense residual MLP (Arctic),
  * router load-balance auxiliary loss + z-loss (the survey's §V "popular
    experts" open problem is exactly what this loss mitigates -- benchmarked
    in benchmarks/moe_balance.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, spec, apply_mlp, mlp_specs


def moe_specs(cfg) -> Dict[str, ParamSpec]:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    out = {"router": spec((d, e), ("embed", None), scale=0.02)}
    if cfg.activation == "swiglu":
        out["wi_gate"] = spec((e, d, f), ("expert", "embed", "moe_ffn"))
        out["wi_up"] = spec((e, d, f), ("expert", "embed", "moe_ffn"))
        out["wo"] = spec((e, f, d), ("expert", "moe_ffn", "embed"))
    else:
        out["wi"] = spec((e, d, f), ("expert", "embed", "moe_ffn"))
        out["wo"] = spec((e, f, d), ("expert", "moe_ffn", "embed"))
    for i in range(cfg.num_shared_experts):
        out[f"shared_{i}"] = mlp_specs(cfg, d_ff=cfg.moe_d_ff)
    if cfg.dense_residual:
        out["dense"] = mlp_specs(cfg)
    return out


def _expert_ffn(p, buf, activation):
    """buf [G,E,C,d] -> [G,E,C,d] via batched expert matmuls."""
    if activation == "swiglu":
        g = jnp.einsum("gecd,edf->gecf", buf, p["wi_gate"],
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("gecd,edf->gecf", buf, p["wi_up"],
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(buf.dtype)
    else:
        h = jnp.einsum("gecd,edf->gecf", buf, p["wi"],
                       preferred_element_type=jnp.float32)
        h = (jnp.square(jax.nn.relu(h)) if activation == "relu2"
             else jax.nn.gelu(h)).astype(buf.dtype)
    return jnp.einsum("gecf,efd->gecd", h, p["wo"],
                      preferred_element_type=jnp.float32).astype(buf.dtype)


def _mesh_groups(t: int) -> Tuple[int, Optional[Tuple[str, ...]], int]:
    """Token groups for sharded dispatch = the mesh's batch extent.

    A GLOBAL argsort over all tokens is un-partitionable: GSPMD must
    all-gather the token stream and replicate the [E, C, d] dispatch
    buffers (measured: 733 GB/device on deepseek-v3 train_4k -- the
    EXPERIMENTS.md §Perf iteration this function is the fix for). Sorting
    WITHIN per-data-shard groups keeps every dispatch op local to its
    shard (GShard's grouping), and the expert einsum then lowers to the
    expected all-to-all.
    """
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.shape:
            # Auto-axes meshes don't surface an abstract mesh at trace
            # time; fall back to the `with mesh:` context manager's mesh.
            from jax._src import mesh as _mesh_lib
            am = _mesh_lib.thread_resources.env.physical_mesh
        if am is None or not am.shape:
            return 1, None, 1
        axes = tuple(a for a in ("pod", "data") if a in am.shape)
        g = 1
        for a in axes:
            g *= am.shape[a]
        if g > 1 and t % g == 0:
            return g, axes, am.shape.get("model", 1)
    except Exception:
        pass
    return 1, None, 1


def _constrain(arr, pspec) -> jax.Array:
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(arr, P(*pspec))
    except Exception:
        return arr


def apply_moe(p, x, cfg, *, capacity_factor: Optional[float] = 1.25
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x [B,S,d] -> (y [B,S,d], aux dict with router stats/losses).

    capacity_factor=None -> DROPLESS (cap = T*k): the inference-engine
    setting (DeepSeek-style serving); bounded capacity is the training
    setting (tokens overflowing an expert are dropped, GShard-style).

    Dispatch is GROUPED: tokens are split into one group per data shard
    (1 group when no mesh is active), each group sort-dispatches into its
    own per-group capacity buffer [G, E, C_g(+1 overflow col), d]. All
    dispatch ops are group-local (shardable over "data"); the expert FFN
    einsum contracts over the model-sharded expert axis (all-to-all).
    """
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e = cfg.num_experts
    g, batch_axes, model_size = _mesh_groups(t)
    if (t // g) * k < e:
        # decode-scale token counts: per-group capacity would starve the
        # expert axis (slots/group < experts) and the grouped constraints
        # only add resharding (measured: deepseek decode_32k 22ms -> 3.3s
        # REGRESSION before this guard). Global dispatch is cheap here.
        g, batch_axes = 1, None
    tg = t // g
    xg = x.reshape(g, tg, d)
    if batch_axes:
        xg = _constrain(xg, (batch_axes, None, None))

    logits = jnp.einsum("gtd,de->gte", xg, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                     # [G,Tg,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- per-group capacity-bounded sort-based dispatch -------------------
    cap = (tg * k if capacity_factor is None
           else max(1, int(capacity_factor * tg * k / e)))

    def dispatch(xf, idx_g, gates_g):
        """One group: xf [Tg,d], idx_g [Tg,k] -> (buf [E,C+1,d], meta)."""
        flat_e = idx_g.reshape(-1).astype(jnp.int32)         # [Tg*k]
        tok_id = (jnp.arange(tg * k, dtype=jnp.int32) // k)
        order = jnp.argsort(flat_e, stable=True)
        se, st = flat_e[order], tok_id[order]
        first = jnp.searchsorted(se, se, side="left")
        pos_in_e = (jnp.arange(tg * k, dtype=jnp.int32)
                    - first.astype(jnp.int32))
        keep = pos_in_e < cap
        dest_c = jnp.where(keep, pos_in_e, cap)              # col cap = drop
        buf = jnp.zeros((e, cap + 1, d), x.dtype)
        buf = buf.at[se, dest_c].set(xf[st])
        flat_g = gates_g.reshape(-1)[order] * keep
        return buf[:, :cap], (se, dest_c, st, flat_g, keep)

    bufs, metas = jax.vmap(dispatch)(xg, idx, gates)         # [G,E,C,d]
    # groups data-sharded, experts model-sharded. (Full 2D expert
    # parallelism was tried and REFUTED -- see sharding/specs.py note.)
    if batch_axes:
        bufs = _constrain(bufs, (batch_axes, "model", None, None))
    y_buf = _expert_ffn(p, bufs, cfg.activation)             # [G,E,C,d]
    if batch_axes:
        y_buf = _constrain(y_buf, (batch_axes, "model", None, None))

    def combine(y_g, meta):
        se, dest_c, st, flat_g, keep = meta
        y_pad = jnp.pad(y_g, ((0, 0), (0, 1), (0, 0)))       # drop col back
        vals = y_pad[se, dest_c] * flat_g[:, None].astype(x.dtype)
        return jnp.zeros((tg, d), jnp.float32).at[st].add(
            vals.astype(jnp.float32)).astype(x.dtype)

    out = jax.vmap(combine)(y_buf, metas).reshape(t, d)
    keep = metas[4]
    xf = xg.reshape(t, d)

    # ---- shared experts / dense residual ---------------------------------
    for i in range(cfg.num_shared_experts):
        out = out + apply_mlp(p[f"shared_{i}"], xf, cfg.activation)
    if cfg.dense_residual:
        out = out + apply_mlp(p["dense"], xf, cfg.activation)

    # ---- router aux losses (Switch/GShard load balance + z-loss) ---------
    one_hot = jax.nn.one_hot(idx, e, dtype=jnp.float32)      # [G,Tg,k,E]
    load = one_hot.sum((0, 1, 2)) / (t * k)                  # fraction routed
    importance = probs.mean((0, 1))
    lb_loss = e * jnp.sum(load * importance)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1)))
    dropped = 1.0 - keep.mean()
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "load": load,
           "dropped_frac": dropped}
    return out.reshape(b, s, d), aux
