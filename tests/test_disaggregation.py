"""Disaggregated serving (PR tentpole): prefill/decode roles, live KV
migration, and the cluster-shared prefix tier.

Contracts locked down here:

  * roles: a ``prefill`` replica hands every request's post-compression
    KV to a ``decode`` replica after its first token -- the request
    finishes on the decode engine, streams stay contract-identical, and
    the fleet counts it exactly once,
  * the modeled KV-link transfer (``CostModel.transfer_time``) is a real
    virtual-clock cost: charged on the importer's clock before its first
    decode step there,
  * ``Router.drain`` MIGRATES live KV: the drained replica's in-flight
    streams continue on a sibling bit-identically (temperature 0) to an
    undrained run,
  * exactly-once under a decode-side import failure mid-migration: the
    router retries the next target or cancels the export and resumes on
    the source -- never zero, never two live copies,
  * the runtime sanitizer (engine + server conservation) stays clean
    across export/import handoffs (engines here run ``sanitize=True``),
  * handoff KV accounting: a prefill-role admission reserves prompt+1
    tokens, not prompt+max_new (the decode budget belongs to the
    importer),
  * ``SharedPrefixTier``: radix longest-match, LRU eviction + path
    pruning, and a prefix prefilled on one replica short-circuiting
    prefill on another (``remote_prefix_hits``),
  * satellite regressions: one shared KV-link bandwidth constant, and
    ``MetricsRegistry.expected_ttft`` cold-start prior.
"""
import asyncio
import inspect

import numpy as np
import pytest

from repro.api import (EngineConfig, GenerationConfig, LVLM, Request)
from repro.cluster import Router, SharedPrefixTier
from repro.cluster.prefix_tier import _Node
from repro.core.kv_cache.tiered import TierStats
from repro.core.serving.disaggregation import CostModel
from repro.roofline.hw import KV_LINK_GBPS
from repro.serving.metrics import MetricsRegistry

MAX_NEW = 6
GEN = GenerationConfig(decoder="greedy", temperature=0.0,
                       max_new_tokens=MAX_NEW)


@pytest.fixture(scope="module")
def lvlm():
    return LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)


def _ec(**kw):
    base = dict(max_batch=4, cache_len=96, temperature=0.0, sanitize=True)
    base.update(kw)
    return EngineConfig(**base)


def _prompts(n, seed=0, lo=8, hi=16, shared=0):
    rng = np.random.RandomState(seed)
    pre = list(rng.randint(1, 512, size=shared)) if shared else []
    return [pre + list(rng.randint(1, 512, size=rng.randint(lo, hi)))
            for _ in range(n)]


def _reqs(prompts, new=MAX_NEW):
    return [Request(rid=i, tokens=list(p), max_new_tokens=new)
            for i, p in enumerate(prompts)]


async def _consume(stream):
    return [tok async for tok in stream]


def _drive_all(front, reqs):
    async def drive():
        async with front:
            return await asyncio.gather(
                *(_consume(front.submit(r)) for r in reqs))

    outs = asyncio.run(drive())
    return {r.rid: list(o) for r, o in zip(reqs, outs)}


# --------------------------------------------------- roles: prefill/decode --


def test_prefill_decode_roles_hand_off_every_request(lvlm):
    """prefill:1,decode:1 -- every request prefills on replica 0,
    decodes (and finishes) on replica 1, exactly once; streams match the
    colocated fleet bit-for-bit at temperature 0."""
    prompts = _prompts(4, seed=3)
    ref = _drive_all(lvlm.serve_cluster(2, _ec(), gen=GEN),
                     _reqs(prompts))
    router = lvlm.serve_cluster(2, _ec(), gen=GEN,
                                roles=["prefill", "decode"])
    got = _drive_all(router, _reqs(prompts))
    assert got == ref
    pf, dec = router.replicas
    assert (pf.role, dec.role) == ("prefill", "decode")
    assert pf.dispatched == 4 and dec.dispatched == 0
    assert pf.migrated_out == 4 and dec.migrated_in == 4
    # every request FINISHED on the decode engine, none on the prefill one
    assert sorted(r.rid for r in dec.server.engine.finished) == [0, 1, 2, 3]
    assert pf.server.engine.finished == []
    # both engines fully released their pools
    assert pf.server.engine.kv_committed_tokens() == 0
    assert dec.server.engine.kv_committed_tokens() == 0
    assert len(router.migrations) == 4
    s = router.summary()
    assert s["finished"] == 4 and s["aborted"] == 0
    assert s["replica_roles"] == ["prefill", "decode"]
    assert s["disaggregation"]["migrations"] == 4
    assert s["disaggregation"]["migrated_out_by_replica"] == [4, 0]
    assert s["disaggregation"]["migrated_in_by_replica"] == [0, 4]


def test_roles_validation(lvlm):
    with pytest.raises(ValueError, match="decode-capable"):
        lvlm.serve_cluster(2, _ec(), gen=GEN, roles=["prefill", "prefill"])
    with pytest.raises(ValueError, match="prefill-capable"):
        lvlm.serve_cluster(2, _ec(), gen=GEN, roles=["decode", "decode"])
    with pytest.raises(ValueError, match="entries for"):
        lvlm.serve_cluster(2, _ec(), gen=GEN, roles=["unified"])
    with pytest.raises(ValueError, match="unknown replica role"):
        Router([lvlm.serve_async(_ec(), gen=GEN)], roles=["chonk"])
    # per-replica spec dicts may carry the role instead
    router = lvlm.serve_cluster(
        [{"role": "prefill"}, {"role": "decode"}], _ec(), gen=GEN)
    assert [rep.role for rep in router.replicas] == ["prefill", "decode"]


def test_transfer_cost_lands_on_importer_clock(lvlm):
    """With kv_bytes_per_token > 0 the KV-link transfer is a REAL
    virtual-clock cost: the importer's first decode step waits out
    ``ready_at`` = source export clock + transfer_time(kv tokens)."""
    cost = CostModel(kv_bytes_per_token=2 << 20)   # 2 MiB/token: visible
    router = lvlm.serve_cluster(2, _ec(cost=cost), gen=GEN,
                                roles=["prefill", "decode"])
    reqs = _reqs(_prompts(2, seed=4))
    got = _drive_all(router, reqs)
    assert all(len(o) == MAX_NEW for o in got.values())
    assert len(router.migrations) == 2
    dec_eng = router.replicas[1].server.engine
    for m in router.migrations:
        expect = cost.kv_bytes_per_token * m["kv_tokens"] / (
            cost.transfer_gbps * 1e9)
        assert m["transfer_s"] == pytest.approx(expect) and expect > 0
        # the decode engine's clock never finished a request before the
        # KV could possibly have arrived
        assert dec_eng.clock >= m["transfer_s"]
    d = router.summary()["disaggregation"]
    assert d["transfer_s_mean"] == pytest.approx(
        float(np.mean([m["transfer_s"] for m in router.migrations])))
    assert d["prefill_s_mean"] is not None and d["prefill_s_mean"] > 0


def test_handoff_reserves_prefill_only_kv(lvlm):
    """A handoff request's reservation on the PREFILL engine covers the
    prompt plus one token -- the decode budget belongs to the importer."""
    eng = lvlm.serve_async(_ec(), gen=GEN).engine
    req = Request(rid=0, tokens=[1] * 20, max_new_tokens=16)
    full = eng.kv_request_tokens(req)
    req.handoff = True
    light = eng.kv_request_tokens(req)
    bs = eng._kv_block()
    assert light == ((req.kv_prompt_len + 1 + bs - 1) // bs) * bs
    assert light < full
    # once imported, the full decode budget is accounted again
    req._imported = True
    assert eng.kv_request_tokens(req) == full


# ------------------------------------------------------- drain migration --


def test_drain_migrates_live_kv_token_identical(lvlm):
    """Drain with live requests: the drained replica's in-flight KV moves
    to the sibling and every stream completes BIT-IDENTICAL (temp 0) to
    an undrained run; the sanitizer (on) stays clean throughout."""
    prompts = _prompts(3, seed=5)
    baseline = _drive_all(lvlm.serve_cluster(2, _ec(), gen=GEN),
                          _reqs(prompts, new=12))

    router = lvlm.serve_cluster(2, _ec(), gen=GEN)

    async def drive():
        async with router:
            reqs = _reqs(prompts, new=12)
            streams = [router.submit(r) for r in reqs]
            assert streams[0].replica.index == 0      # round-robin
            got0 = [await streams[0].__anext__(),
                    await streams[0].__anext__()]     # rid 0 mid-decode
            router.drain(0)
            rest = await asyncio.gather(*(_consume(s) for s in streams))
            return [got0 + rest[0]] + rest[1:]

    outs = asyncio.run(drive())
    assert {i: o for i, o in enumerate(outs)} == baseline
    # rid 0 really moved: decode finished on replica 1 with its 2
    # source-side tokens intact
    assert router.replicas[0].migrated_out >= 1
    assert any(m["rid"] == 0 and m["src"] == 0 and m["dst"] == 1
               for m in router.migrations)
    assert 0 in [r.rid for r in router.replicas[1].server.engine.finished]
    assert router.replicas[0].server.engine.kv_committed_tokens() == 0
    assert router.summary()["finished"] == 3


def test_drain_without_sibling_finishes_in_place(lvlm):
    """A single-replica drain has nowhere to send KV: in-flight streams
    finish where they are (the old drain contract)."""
    router = lvlm.serve_cluster(1, _ec(), gen=GEN)

    async def drive():
        async with router:
            stream = router.submit(Request(rid=0, tokens=[1, 2, 3, 4],
                                           max_new_tokens=MAX_NEW))
            first = await stream.__anext__()
            router.drain(0)
            return [first] + await _consume(stream)

    out = asyncio.run(drive())
    assert len(out) == MAX_NEW
    assert router.migrations == [] and router.replicas[0].migrated_out == 0
    assert sorted(r.rid for r in
                  router.replicas[0].server.engine.finished) == [0]


# --------------------------------------------- exactly-once under failure --


def test_import_failure_retries_next_decode_replica(lvlm):
    """Decode replica dies mid-migration: the first import attempt fails,
    the router retries the NEXT decode target, and the request completes
    exactly once -- nothing lost, nothing duplicated."""
    router = lvlm.serve_cluster(3, _ec(), gen=GEN,
                                roles=["prefill", "decode", "decode"])

    async def broken_import(request, ticket, *, ready_at=0.0):
        raise RuntimeError("injected import failure (dead importer)")

    router.replicas[1].server.import_stream = broken_import
    reqs = _reqs(_prompts(2, seed=6))
    got = _drive_all(router, reqs)
    assert all(len(o) == MAX_NEW for o in got.values())
    fleet = sorted(r.rid for rep in router.replicas
                   for r in rep.server.engine.finished)
    assert fleet == [0, 1]                    # exactly once, fleet-wide
    assert router.replicas[1].migrated_in == 0
    assert router.replicas[2].migrated_in == 2
    assert all(m["dst"] == 2 for m in router.migrations)


def test_import_failure_with_no_alternative_resumes_on_source(lvlm):
    """Every decode target refuses: the export CANCELS and the request
    resumes decoding on its source replica -- still exactly once."""
    router = lvlm.serve_cluster(2, _ec(), gen=GEN,
                                roles=["prefill", "decode"])

    async def broken_import(request, ticket, *, ready_at=0.0):
        raise RuntimeError("injected import failure (dead importer)")

    router.replicas[1].server.import_stream = broken_import
    reqs = _reqs(_prompts(2, seed=7))
    got = _drive_all(router, reqs)
    assert all(len(o) == MAX_NEW for o in got.values())
    assert sorted(r.rid for r in
                  router.replicas[0].server.engine.finished) == [0, 1]
    assert router.replicas[1].server.engine.finished == []
    assert router.migrations == []
    assert router.replicas[0].server.engine.kv_committed_tokens() == 0
    assert router.replicas[0].server.engine._exports == {}


# --------------------------------------------------- shared prefix tier --


def test_shared_prefix_tier_radix_semantics():
    tier = SharedPrefixTier(block=4, cap=2)
    snap_a, snap_b = object(), object()
    tier.insert("none", list(range(8)), snap_a, 8)
    tier.insert("none", list(range(4)), snap_b, 4)
    # longest match wins; shorter prefix still resolvable
    k, s = tier.lookup("none", list(range(12)), block=4)
    assert (k, s) == (8, snap_a)
    k, s = tier.lookup("none", list(range(4)) + [99, 99, 99, 99], block=4)
    assert (k, s) == (4, snap_b)
    # variant isolation and block-size mismatch are misses
    assert tier.lookup("fastv-0.5", list(range(8)), block=4) == (0, None)
    assert tier.lookup("none", list(range(8)), block=8) == (0, None)
    # LRU eviction at cap, with trie-path pruning behind it
    tier.insert("none", [7] * 4, object(), 4)     # evicts the LRU entry
    assert len(tier) == 2 and tier.evictions == 1
    assert tier.stats()["entries"] == 2
    tier2 = SharedPrefixTier(block=4, cap=8)
    tier2.insert("none", list(range(8)), snap_a, 8)
    tier2._evict_one()
    assert tier2._roots == {}                     # fully pruned


def test_prefix_tier_shares_hits_across_replicas(lvlm):
    """Round-robin + shared tier: replica 1's cold prefill of a family
    replica 0 already cached short-circuits via the tier (one remote
    install), and the streams stay identical to the tier-less run."""
    prompts = _prompts(4, seed=8, lo=4, hi=8, shared=32)
    ec = dict(cache_len=128, prefix_cache=True)
    ref = _drive_all(lvlm.serve_cluster(2, _ec(**ec), gen=GEN,
                                        shared_prefix=False),
                     _reqs(prompts, new=4))
    router = lvlm.serve_cluster(2, _ec(**ec), gen=GEN, shared_prefix=True)
    assert router.prefix_tier is not None
    got = _drive_all(router, _reqs(prompts, new=4))
    assert got == ref
    assert router.prefix_tier.hits >= 1 and router.prefix_tier.inserts >= 1
    remote = [rep.server.engine.remote_prefix_hits
              for rep in router.replicas]
    assert sum(remote) >= 1
    per = router.metrics.per_replica()
    assert sum(p["remote_prefix_hits"] for p in per) == sum(remote)
    # role-split fleets get the tier by default; unified fleets do not
    assert lvlm.serve_cluster(2, _ec(**ec), gen=GEN).prefix_tier is None
    assert lvlm.serve_cluster(2, _ec(**ec), gen=GEN,
                              roles=["prefill", "decode"]
                              ).prefix_tier is not None


# ------------------------------------------------- satellite regressions --


def test_kv_link_bandwidth_is_one_shared_constant():
    """Regression: tiered.py said 32 GB/s while disaggregation.py said
    20 -- both now read ``repro.roofline.hw.KV_LINK_GBPS``."""
    assert CostModel().transfer_gbps == KV_LINK_GBPS
    sig = inspect.signature(TierStats.transfer_seconds)
    assert sig.parameters["gbps"].default == KV_LINK_GBPS


def test_expected_ttft_cold_start_prior():
    """Regression: ``expected_ttft`` returned 0.0 before any record, so
    EDF slack ordering was maximally optimistic for the whole first
    wave. A fresh registry now reports the configurable prior; real
    records wash it out."""
    m = MetricsRegistry()
    assert m.expected_ttft() == MetricsRegistry.DEFAULT_TTFT_PRIOR > 0.0
    assert MetricsRegistry(ttft_prior=1.5).expected_ttft() == 1.5
    req = Request(rid=0, tokens=[1, 2], max_new_tokens=2)
    req.arrival = 0.0
    req.generated.extend([5, 6])
    req.first_token_time = 0.03
    req.finish_time = 0.05
    m.observe(req)
    assert m.expected_ttft() == pytest.approx(0.03)   # prior washed out


def test_cold_start_slack_orders_by_deadline(lvlm):
    """Cold start (no TTFT history): the slack key must still order two
    waiters by deadline -- the uniform prior shifts values, never the
    EDF order."""
    server = lvlm.serve_async(_ec(), gen=GEN)
    tight = Request(rid=0, tokens=[1], max_new_tokens=1)
    loose = Request(rid=1, tokens=[1], max_new_tokens=1)
    tight.slo.ttft_ms = 50.0
    loose.slo.ttft_ms = 5000.0
    assert server.metrics.records == []               # truly cold
    assert server._slack(tight) < server._slack(loose)
    # the prior makes cold-start slack sign-meaningful: a 50 ms deadline
    # is already past once the expected TTFT (250 ms prior) exceeds it
    assert server._slack(tight) < 0 < server._slack(loose)
