"""Serving driver: the taxonomy engine end-to-end on synthetic requests,
through the unified ``repro.api`` facade.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-vl-2b --smoke \
        --requests 16 --scheduler chunked --compression divprune-0.5

    # per-request compression mixing (one engine, two strategies; the
    # report includes per-strategy prefill token reduction):
    PYTHONPATH=src python -m repro.launch.serve \
        --compression none,framefusion-0.25

    # decoder strategies (all batched; speculative slots share each
    # jitted draft/verify round):
    PYTHONPATH=src python -m repro.launch.serve --decoder speculative

    # open-loop async serving: Poisson arrivals at --open-loop req/s
    # (virtual clock) through AsyncLVLMServer, with KV-watermark admission
    # control; the JSON report adds queue-wait and admission counters:
    PYTHONPATH=src python -m repro.launch.serve --open-loop 2000

    # multi-engine routing: N async server replicas behind one Router
    # (--routing round_robin | least_kv | prefix_affinity), SLO-slack
    # deferred queues, optional wall-clock pacing; the report is the
    # fleet-wide ClusterMetrics summary:
    PYTHONPATH=src python -m repro.launch.serve --replicas 2 \
        --routing prefix_affinity --prefix-cache --shared-prefix 32 \
        --open-loop 2000 --admission-order slack

    # disaggregated prefill/decode fleet: prefill replicas run the
    # vision encoder + chunked prefill, hand post-compression KV to
    # decode replicas over the modeled KV link (--roles implies the
    # replica count; the report adds a "disaggregation" block):
    PYTHONPATH=src python -m repro.launch.serve \
        --roles prefill:2,decode:2 --open-loop 2000
"""
from __future__ import annotations

import argparse
import asyncio
import json

import numpy as np

from repro.api import (AdmissionConfig, EngineConfig, GenerationConfig, LVLM,
                       Request, ROUTING_POLICIES, resolve_compression)
from repro.configs import ARCHS


def synth_requests(cfg, n, *, seed=0, prompt_lo=16, prompt_hi=48,
                   new_tokens=16, shared_prefix=0):
    rng = np.random.RandomState(seed)
    shared = list(rng.randint(1, cfg.vocab_size,
                              size=shared_prefix)) if shared_prefix else []
    reqs = []
    for i in range(n):
        toks = shared + list(rng.randint(
            1, cfg.vocab_size, size=rng.randint(prompt_lo, prompt_hi)))
        ve = None
        if cfg.family == "vlm":
            ve = rng.randn(cfg.num_visual_tokens, cfg.d_model).astype(
                np.float32) * 0.02
        reqs.append(Request(rid=i, tokens=toks, max_new_tokens=new_tokens,
                            visual_embeds=ve, arrival=i * 0.01))
    return reqs


def parse_roles(spec):
    """``'prefill:2,decode:2'`` (or a bare list ``'prefill,decode'``)
    into the per-replica role list ``serve_cluster`` expects."""
    roles = []
    for part in str(spec).split(","):
        part = part.strip()
        if not part:
            continue
        name, _, count = part.partition(":")
        roles.extend([name.strip()] * (int(count) if count else 1))
    return roles


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-vl-2b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--scheduler", default="continuous",
                    choices=("static", "continuous", "mlfq", "chunked"))
    ap.add_argument("--decoder", default="sampling",
                    choices=("greedy", "sampling", "speculative",
                             "early_exit"))
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--shared-prefix", type=int, default=0)
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--compression", default="none",
                    help="preset name, e.g. none|fastv-0.5|divprune-0.5|"
                         "streaming-kv; parametric: <pruner>-<keep> or "
                         "<streaming|l2>-kv-<budget>. A comma list "
                         "(e.g. 'none,fastv-0.5') assigns strategies "
                         "PER-REQUEST round-robin -- one engine serves "
                         "the mixed-compression workload")
    ap.add_argument("--gamma", type=int, default=4,
                    help="speculative draft length")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--open-loop", type=float, default=0.0, metavar="RATE",
                    help="serve via the async server with Poisson arrivals "
                         "at RATE req/s (virtual clock); 0 = closed loop")
    ap.add_argument("--high-watermark", type=float, default=0.9,
                    help="admission high KV watermark (fraction of pool)")
    ap.add_argument("--low-watermark", type=float, default=0.7,
                    help="admission low (drain) KV watermark")
    ap.add_argument("--admission-order", default="fifo",
                    choices=("fifo", "slack"),
                    help="deferred-queue order: FIFO or SLO-slack "
                         "(earliest TTFT deadline first)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="async server replicas behind a cluster Router "
                         "(>1 forces the async path)")
    ap.add_argument("--roles", default=None, metavar="SPEC",
                    help="disaggregated fleet roles, e.g. "
                         "'prefill:2,decode:2' or 'prefill,decode' "
                         "(implies the replica count and the async "
                         "cluster path; prefill replicas hand "
                         "post-compression KV to decode replicas)")
    ap.add_argument("--routing", default="round_robin",
                    choices=sorted(ROUTING_POLICIES),
                    help="cluster routing policy (with --replicas > 1)")
    ap.add_argument("--pacing", default="virtual",
                    choices=("virtual", "wall"),
                    help="'wall' sleeps each step's virtual duration in "
                         "real time; 'virtual' is deterministic")
    ap.add_argument("--pacing-scale", type=float, default=1.0,
                    help="wall-pacing multiplier on the virtual duration")
    ap.add_argument("--disconnect-timeout", type=float, default=None,
                    metavar="S", help="abort streams whose consumer "
                    "stopped reading for S wall seconds")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable repro.obs tracing and write the run as "
                         "Chrome-trace/Perfetto JSON (open in ui.perfetto"
                         ".dev; validate with python -m repro.obs.validate)")
    ap.add_argument("--trace-events", default=None, metavar="PATH",
                    help="enable tracing and stream raw events as JSONL "
                         "(input for scripts/trace_report.py)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus text-format metrics snapshot "
                         "after the run ('-' = stdout); requires the async "
                         "path (--open-loop or --replicas > 1)")
    ap.add_argument("--control", action="store_true",
                    help="enable the SLO-adaptive quality controller "
                         "(repro.control): under KV pressure, degrade "
                         "deferred requests to aggressive compression "
                         "presets instead of queueing them")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower/compile decode_32k under the production mesh")
    args = ap.parse_args()

    if args.dry_run:
        import os
        import subprocess
        import sys
        return subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", args.arch, "--shape", "decode_32k"],
            env=dict(os.environ, PYTHONPATH="src"))

    lvlm = LVLM.from_pretrained(args.arch, smoke=True)
    # comma list = per-request mixing: the FIRST preset is the engine
    # default, the rest resolve per-request against the same registry
    # (compression is configured via the facade, never by mutating
    # EngineConfig.compression -- see the repo layering rule)
    presets = [p for p in str(args.compression).split(",") if p]
    for p in presets:
        resolve_compression(p)             # fail fast on bad names
    ec = EngineConfig(
        max_batch=args.max_batch, cache_len=args.cache_len,
        scheduler=args.scheduler, temperature=args.temperature,
        prefix_cache=args.prefix_cache)
    gen = GenerationConfig(
        decoder=args.decoder, temperature=args.temperature,
        max_new_tokens=args.new_tokens, gamma=args.gamma,
        compression=presets[0] if presets else "none")
    reqs = synth_requests(lvlm.cfg, args.requests,
                          new_tokens=args.new_tokens,
                          shared_prefix=args.shared_prefix)
    if len(presets) > 1:
        for i, r in enumerate(reqs):
            r.compression = presets[i % len(presets)]
    if args.open_loop > 0:
        rng = np.random.RandomState(0)
        arrivals = np.cumsum(rng.exponential(1.0 / args.open_loop,
                                             size=len(reqs)))
        for r, t in zip(reqs, arrivals):
            r.arrival = float(t)
    adm = AdmissionConfig(high_watermark=args.high_watermark,
                          low_watermark=args.low_watermark,
                          order=args.admission_order)
    roles = parse_roles(args.roles) if args.roles else None
    if roles:
        args.replicas = len(roles)
    tracer = None
    if args.trace_out or args.trace_events:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.open_loop > 0 or args.replicas > 1:
        front = lvlm.serve_cluster(
            args.replicas, ec, gen=gen, routing=args.routing,
            roles=roles, admission=adm, pacing=args.pacing,
            pacing_scale=args.pacing_scale,
            disconnect_timeout_s=args.disconnect_timeout,
            obs=tracer, control=args.control) \
            if args.replicas > 1 else lvlm.serve_async(
                ec, gen=gen, admission=adm, pacing=args.pacing,
                pacing_scale=args.pacing_scale,
                disconnect_timeout_s=args.disconnect_timeout,
                obs=tracer, control=args.control)

        async def drive():
            async with front:
                await asyncio.gather(
                    *(_consume(front.submit(r)) for r in reqs))
            return front.summary()

        stats = asyncio.run(drive())
        if args.metrics_out:
            text = front.metrics_snapshot()
            if args.metrics_out == "-":
                print(text, end="")
            else:
                with open(args.metrics_out, "w", encoding="utf-8") as f:
                    f.write(text)
    else:
        if args.metrics_out:
            ap.error("--metrics-out requires the async path "
                     "(--open-loop or --replicas > 1)")
        stats = lvlm.serve(reqs, engine_cfg=ec, gen=gen, obs=tracer,
                           control=args.control).stats
    if tracer is not None:
        if args.trace_out:
            from repro.obs import write_chrome_trace
            write_chrome_trace(tracer.events, args.trace_out)
        if args.trace_events:
            tracer.write_jsonl(args.trace_events)
    print(json.dumps({k: v for k, v in stats.items()
                      if not isinstance(v, (list, dict))}, indent=1,
                     default=float))
    return 0


async def _consume(stream):
    return [tok async for tok in stream]


if __name__ == "__main__":
    raise SystemExit(main())
