"""The machine-readable API tables the rules check against.

This is the single place where the repo's resource-lifecycle and
layering conventions are written down as data: which calls/stores
acquire a slot, draft row, or prefix pin; which calls release them;
which attributes are loop-shared mutable state; which calls block an
event loop. Rules interpret these tables -- adding a new resource or a
new blocking call is a table edit, not a new rule.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Optional, Sequence, Tuple

# --------------------------------------------------------------- matchers --
# A site matcher is a predicate over one *statement*: it answers whether
# the statement contains the acquire / release / handoff action.


def _own_nodes(stmt: ast.stmt):
    """Walk a statement's own expressions WITHOUT descending into nested
    statements: a compound statement (if/for/while/try/with) matches only
    on its header, since the statements in its body are separate CFG
    nodes matched individually."""
    stack: list = [stmt]
    while stack:
        n = stack.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if not isinstance(child, ast.stmt):
                stack.append(child)


def _calls(stmt: ast.stmt):
    for n in _own_nodes(stmt):
        if isinstance(n, ast.Call):
            yield n


def call_named(*names: str) -> Callable[[ast.stmt], bool]:
    """A call whose callee is ``name(...)`` or ``<expr>.name(...)``."""
    def match(stmt: ast.stmt) -> bool:
        for c in _calls(stmt):
            f = c.func
            if isinstance(f, ast.Name) and f.id in names:
                return True
            if isinstance(f, ast.Attribute) and f.attr in names:
                return True
        return False
    return match


def method_on(attr: str, *methods: str) -> Callable[[ast.stmt], bool]:
    """A call ``<expr>.<attr>.<method>(...)``, e.g. _streams.pop(...)."""
    def match(stmt: ast.stmt) -> bool:
        for c in _calls(stmt):
            f = c.func
            if (isinstance(f, ast.Attribute) and f.attr in methods
                    and isinstance(f.value, ast.Attribute)
                    and f.value.attr == attr):
                return True
        return False
    return match


def store_subscript(attr: str,
                    value_none: Optional[bool] = None
                    ) -> Callable[[ast.stmt], bool]:
    """An assignment ``<expr>.<attr>[k] = v`` (optionally requiring v to
    be / not be ``None``), or ``del <expr>.<attr>[k]``."""
    def match(stmt: ast.stmt) -> bool:
        targets: Sequence[ast.expr] = ()
        value: Optional[ast.expr] = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AugAssign):
            targets, value = (stmt.target,), stmt.value
        elif isinstance(stmt, ast.Delete):
            targets = stmt.targets
        for t in targets:
            if (isinstance(t, ast.Subscript)
                    and isinstance(t.value, ast.Attribute)
                    and t.value.attr == attr):
                if value_none is None or isinstance(stmt, ast.Delete):
                    return True
                is_none = (isinstance(value, ast.Constant)
                           and value.value is None)
                if is_none == value_none:
                    return True
        return False
    return match


def store_attr(attr: str,
               value_none: Optional[bool] = None
               ) -> Callable[[ast.stmt], bool]:
    """An assignment ``<expr>.<attr> = v`` (optionally v is/isn't None)."""
    def match(stmt: ast.stmt) -> bool:
        if not isinstance(stmt, ast.Assign):
            return False
        for t in stmt.targets:
            if isinstance(t, ast.Attribute) and t.attr == attr:
                if value_none is None:
                    return True
                is_none = (isinstance(stmt.value, ast.Constant)
                           and stmt.value.value is None)
                if is_none == value_none:
                    return True
        return False
    return match


def del_subscript(attr: str) -> Callable[[ast.stmt], bool]:
    """A ``del <expr>.<attr>[k]`` statement."""
    def match(stmt: ast.stmt) -> bool:
        if not isinstance(stmt, ast.Delete):
            return False
        for t in stmt.targets:
            if (isinstance(t, ast.Subscript)
                    and isinstance(t.value, ast.Attribute)
                    and t.value.attr == attr):
                return True
        return False
    return match


def any_of(*matchers) -> Callable[[ast.stmt], bool]:
    def match(stmt: ast.stmt) -> bool:
        return any(m(stmt) for m in matchers)
    return match


# ------------------------------------------------------------- R: resources --
@dataclasses.dataclass
class Resource:
    """One tracked resource kind for the R-rules.

    ``acquire`` marks the acquire site; every CFG path through an
    acquire (function entry -> acquire -> exit) must touch a ``release``
    or ``handoff`` site -- ``handoff`` marks ownership transfer into
    long-lived engine/server state that a later release function frees.
    ``exempt_functions`` are the release functions themselves (their
    internal stores must not count as acquires). ``module_pairing``
    relaxes the per-function CFG walk to "the module must contain at
    least one release site" for resources acquired and released in
    different functions by design.
    """
    rid: str
    description: str
    path_suffixes: Tuple[str, ...]
    acquire: Callable[[ast.stmt], bool]
    release: Callable[[ast.stmt], bool]
    handoff: Optional[Callable[[ast.stmt], bool]] = None
    exempt_functions: Tuple[str, ...] = ()
    module_pairing: bool = False


RESOURCES = [
    Resource(
        rid="slot",
        description="engine KV slot (Engine._free_slot -> slot_req bind, "
                    "freed by Engine._release_request)",
        path_suffixes=("core/serving/engine.py",),
        acquire=call_named("_free_slot"),
        release=call_named("_release_request"),
        handoff=store_subscript("slot_req", value_none=False),
    ),
    Resource(
        rid="prefix_pin",
        description="prefix-cache pin (pin-count increment + "
                    "Request._prefix_pin bind, freed by _release_request)",
        path_suffixes=("core/serving/engine.py",),
        acquire=store_subscript("_prefix_pins"),
        release=any_of(call_named("_release_request"),
                       method_on("_prefix_pins", "pop")),
        handoff=store_attr("_prefix_pin", value_none=False),
        # complete_export decrements a TICKET-owned pin: it is a release
        # function for migration state, like _release_request
        exempt_functions=("_release_request", "complete_export"),
    ),
    Resource(
        rid="migration_export",
        description="KV-migration export ticket (_exports bind pins the "
                    "source slot + request, released by complete_export/"
                    "cancel_export popping the ticket)",
        path_suffixes=("core/serving/engine.py",),
        acquire=store_subscript("_exports", value_none=False),
        release=method_on("_exports", "pop"),
        # the export pin is BORN to outlive its function: export_kv pins,
        # a sibling imports, complete/cancel_export release -- pairing is
        # a module property, enforced per-action by R001 below
        module_pairing=True,
    ),
    Resource(
        rid="retired_request",
        description="request retirement (finished/aborted append must be "
                    "paired with Engine._release_request on the same path)",
        path_suffixes=("core/serving/engine.py",),
        acquire=method_on("finished", "append"),
        release=call_named("_release_request"),
    ),
    Resource(
        rid="aborted_request",
        description="request abort (aborted append must be paired with "
                    "Engine._release_request on the same path)",
        path_suffixes=("core/serving/engine.py",),
        acquire=method_on("aborted", "append"),
        release=call_named("_release_request"),
    ),
    Resource(
        rid="stream",
        description="server TokenStream registration (_streams bind, "
                    "released by pop/del in abort/_drain/_fail)",
        path_suffixes=("serving/server.py",),
        acquire=store_subscript("_streams", value_none=False),
        release=any_of(method_on("_streams", "pop"),
                       del_subscript("_streams")),
        module_pairing=True,
    ),
    Resource(
        rid="router_inflight",
        description="router inflight assignment (Replica.inflight bind, "
                    "released by inflight.pop on retire/cancel/redispatch)",
        path_suffixes=("cluster/router.py",),
        acquire=store_subscript("inflight", value_none=False),
        release=method_on("inflight", "pop"),
        module_pairing=True,
    ),
    Resource(
        rid="admission_waiter",
        description="admission-gate waiter (deferred-queue append, "
                    "released by remove/popleft)",
        path_suffixes=("serving/admission.py",),
        acquire=method_on("_waiters", "append"),
        release=any_of(method_on("_waiters", "remove"),
                       method_on("_waiters", "popleft")),
        module_pairing=True,
    ),
    Resource(
        rid="control_override",
        description="controller preset override (_overrides bind records "
                    "a deferred request's preferred fields, consumed by "
                    "commit or restored by revert -- no request stays "
                    "permanently downgraded after pressure clears)",
        path_suffixes=("control/controller.py",),
        acquire=store_subscript("_overrides", value_none=False),
        release=method_on("_overrides", "pop"),
        # _apply_fields acquires; the server's _admit resolution paths
        # release via commit()/revert() -- pairing is a module property,
        # with the specific release actions pinned per-function by R001
        module_pairing=True,
    ),
]


# R001: canonical release functions must contain EVERY release action of
# the resources they free -- deleting any single one is a finding.
@dataclasses.dataclass
class ReleaseAction:
    name: str
    matcher: Callable[[ast.stmt], bool]


RELEASE_COMPLETENESS = {
    ("core/serving/engine.py", "_release_request"): [
        ReleaseAction("slot-unbind (slot_req[slot] = None)",
                      store_subscript("slot_req", value_none=True)),
        ReleaseAction("draft-row release (decoder release_slot hook)",
                      call_named("release", "release_slot")),
        ReleaseAction("prefix-pin decrement/pop (_prefix_pins)",
                      any_of(method_on("_prefix_pins", "pop"),
                             store_subscript("_prefix_pins"))),
        ReleaseAction("prefix-pin clear (request._prefix_pin = None)",
                      store_attr("_prefix_pin", value_none=True)),
    ],
    ("serving/server.py", "abort"): [
        ReleaseAction("engine abort (frees slot/draft row/gamma/pin)",
                      method_on("engine", "abort")),
        ReleaseAction("stream deregistration (_streams.pop)",
                      method_on("_streams", "pop")),
        ReleaseAction("admission drain (freed capacity wakes waiters)",
                      method_on("admission", "maybe_admit")),
    ],
    ("core/serving/engine.py", "complete_export"): [
        ReleaseAction("export-ticket pop (_exports.pop)",
                      method_on("_exports", "pop")),
        ReleaseAction("running-list removal (running.remove)",
                      method_on("running", "remove")),
        ReleaseAction("source-slot unbind (slot_req[slot] = None)",
                      store_subscript("slot_req", value_none=True)),
        ReleaseAction("ticket prefix-pin decrement/pop (_prefix_pins)",
                      any_of(method_on("_prefix_pins", "pop"),
                             store_subscript("_prefix_pins"))),
    ],
    ("cluster/router.py", "_retire"): [
        ReleaseAction("router stream deregistration (_streams.pop)",
                      method_on("_streams", "pop")),
        ReleaseAction("replica inflight release (inflight.pop)",
                      method_on("inflight", "pop")),
    ],
    # repro.obs span lifecycle: the engine opens the per-request trace
    # span in submit() and MUST close it -- step() at retire, abort()
    # for everything else. Deleting either close orphans every span the
    # Perfetto export renders (O-rules check reachability; these two
    # entries make the specific close calls deletion-proof like any
    # other release action).
    ("core/serving/engine.py", "abort"): [
        ReleaseAction("trace span close on abort (tracer.span_abort)",
                      call_named("span_abort")),
    ],
    ("core/serving/engine.py", "step"): [
        ReleaseAction("request-span close at retire (tracer.span_end)",
                      call_named("span_end")),
    ],
    # repro.control override lifecycle: revert() must restore EVERY field
    # the controller rewrote -- deleting any single restore leaves a
    # request permanently degraded after pressure clears (the exact bug
    # class ISSUE 10's R-table entry exists to make deletion-proof).
    ("control/controller.py", "revert"): [
        ReleaseAction("preferred-compression restore (req.compression)",
                      store_attr("compression", value_none=None)),
        ReleaseAction("preferred-decoder restore (req.decoder)",
                      store_attr("decoder", value_none=None)),
        ReleaseAction("stamped-count invalidation (nv_compressed = None)",
                      store_attr("nv_compressed", value_none=True)),
        ReleaseAction("override-record pop (_overrides.pop)",
                      method_on("_overrides", "pop")),
    ],
    ("control/controller.py", "commit"): [
        ReleaseAction("override-record pop (_overrides.pop)",
                      method_on("_overrides", "pop")),
    ],
}


# ------------------------------------------------------- O: tracing tables --
# repro.obs emission calls. Every ``span_begin`` must reach a matching
# ``span_end``/``span_abort``; the other emissions are one-shot.
SPAN_BEGIN_CALLS = ("span_begin",)
SPAN_CLOSE_CALLS = ("span_end", "span_abort")
TRACER_EMIT_CALLS = ("span_begin", "span_end", "span_abort",
                     "instant", "counter", "slice")


@dataclasses.dataclass
class SpanScope:
    """Where the O001 span-pairing walk applies and in which mode.

    ``module_pairing=False`` runs the per-function CFG walk (every path
    begin -> function exit must cross a close site); ``True`` relaxes to
    "the module must contain at least one close site" for files whose
    spans open and close in different functions by design (the engine:
    ``submit`` opens the request span, ``step``/``abort`` close it).
    """
    path_suffix: str
    module_pairing: bool
    description: str


SPAN_SCOPES = [
    SpanScope("core/serving/engine.py", True,
              "request/prefill/kv_migration spans cross method "
              "boundaries; pairing is a module property, with the "
              "specific closes pinned per-function by R001"),
    SpanScope("serving/server.py", False,
              "admission_wait spans open and close inside one "
              "coroutine on every path, including cancellation"),
]

# repro.obs.profile hot-path sites (O003). Unlike trace spans, profiler
# sites NEVER cross a function boundary -- wall time is measured around a
# synchronous region -- so every scope runs the per-function CFG walk.
PROFILE_BEGIN_CALLS = ("site_begin",)
PROFILE_CLOSE_CALLS = ("site_end",)

PROFILE_SCOPES = [
    SpanScope("core/serving/engine.py", False,
              "profiler sites (prefill_forward, decode launch, compress, "
              "kv transfer, prefix tier) open and close inside one "
              "method on every path"),
    SpanScope("control/controller.py", False,
              "the control_step site opens and closes inside "
              "Controller.on_step on every path"),
]

# ---------------------------------------------------------- A: async tables --
# Blocking calls that stall the event loop when issued inside async def.
BLOCKING_CALLS = {
    ("time", "sleep"), ("os", "system"), ("subprocess", "run"),
    ("subprocess", "call"), ("subprocess", "check_call"),
    ("subprocess", "check_output"), ("socket", "create_connection"),
    ("requests", "get"), ("requests", "post"), ("urllib.request", "urlopen"),
}

# Shared mutable serving/cluster/engine state: a read-before-await plus
# write-after-await of one of these in a single async function is an
# interleaving hazard unless fenced with `# analysis: atomic-step`.
SHARED_STATE_ATTRS = {
    "_streams", "_waiters", "_draining", "inflight", "_prefix",
    "_prefix_pins", "waiting", "running", "slot_req",
}

# Mutating method names that count as writes on those attributes.
MUTATING_METHODS = {
    "append", "remove", "pop", "popleft", "appendleft", "clear", "update",
    "extend", "insert", "add", "discard", "move_to_end", "setdefault",
}

# ------------------------------------------------------- L: layering tables --
# Path prefixes (relative to the repo root) that form the internal layer:
# repro.core imports are allowed only here.
INTERNAL_IMPORT_OK_PREFIXES = ("src/repro/", "tests/")

# The facade layer allowed to touch EngineConfig.compression.
COMPRESSION_MUTATION_OK_PREFIXES = ("src/repro/api/", "src/repro/core/")

# Engine construction stays behind the facade outside the src tree.
ENGINE_CONSTRUCTION_OK_PREFIXES = ("src/repro/", "tests/")
