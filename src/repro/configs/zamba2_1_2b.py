"""Zamba2-1.2B (hybrid: Mamba2 backbone + shared attention block). [arXiv:2411.15242]

38 Mamba2 layers with ONE shared (parameter-tied) attention+MLP block
invoked every `attn_layer_period` layers, concatenating the original
embedding with the residual stream (Zamba's design). long_500k runs
natively: SSM state is O(1); the shared attention block uses a sliding
window over its own KV.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    activation="gelu",
    norm="layernorm",
    rope_theta=1.0e4,
    ssm_state_dim=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_layer_period=6,        # shared attn block after every 6 mamba layers
    sliding_window=4096,        # window for the shared attention block
)

SMOKE_CONFIG = CONFIG.with_(
    name="zamba2-smoke",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512, ssm_state_dim=16, ssm_head_dim=32,
    attn_layer_period=2, sliding_window=64, dtype="float32",
)
