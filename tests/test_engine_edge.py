"""Engine edge cases: eos stopping, staggered arrivals, slot reuse."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.serving import Engine, EngineConfig, Request
from repro.models import build


@pytest.fixture(scope="module")
def small():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_eos_stops_generation(small):
    cfg, model, params = small
    rng = np.random.RandomState(0)
    prompt = list(rng.randint(1, cfg.vocab_size, size=16))
    # find the greedy first token, then make it the eos id
    eng = Engine(model, params, EngineConfig(max_batch=1, cache_len=64))
    r = Request(rid=0, tokens=prompt, max_new_tokens=8)
    eng.submit(r)
    eng.run()
    first = r.generated[0]
    eng2 = Engine(model, params, EngineConfig(max_batch=1, cache_len=64,
                                              eos_id=first))
    r2 = Request(rid=0, tokens=prompt, max_new_tokens=8)
    eng2.submit(r2)
    eng2.run()
    assert len(r2.generated) == 1 and r2.generated[0] == first


def test_staggered_arrivals_never_negative_ttft(small):
    cfg, model, params = small
    rng = np.random.RandomState(1)
    eng = Engine(model, params, EngineConfig(max_batch=2, cache_len=64))
    for i in range(5):
        eng.submit(Request(
            rid=i, tokens=list(rng.randint(1, cfg.vocab_size, size=10)),
            max_new_tokens=4, arrival=i * 0.05))
    eng.run()
    for r in eng.finished:
        assert r.ttft() is not None and r.ttft() >= 0, (r.rid, r.ttft())
        assert r.finish_time >= r.arrival


def test_slot_reuse_more_requests_than_slots(small):
    cfg, model, params = small
    rng = np.random.RandomState(2)
    eng = Engine(model, params, EngineConfig(max_batch=2, cache_len=64))
    n = 7
    for i in range(n):
        eng.submit(Request(
            rid=i, tokens=list(rng.randint(1, cfg.vocab_size, size=10)),
            max_new_tokens=3))
    out = eng.run()
    assert out["finished"] == n
    assert all(r is None for r in eng.slot_req), "all slots released"
    # outputs must match an unconstrained run (slot reuse is transparent)
    eng2 = Engine(model, params, EngineConfig(max_batch=8, cache_len=64))
    rng = np.random.RandomState(2)
    for i in range(n):
        eng2.submit(Request(
            rid=i, tokens=list(rng.randint(1, cfg.vocab_size, size=10)),
            max_new_tokens=3))
    eng2.run()
    g1 = {r.rid: r.generated for r in eng.finished}
    g2 = {r.rid: r.generated for r in eng2.finished}
    assert g1 == g2
