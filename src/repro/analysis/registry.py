"""Rule registry: rule ids -> checker instances, family selection.

A rule is a small class with ``rule_id``, ``family`` (L/R/A/K/O),
``severity``, ``description``, a path filter (``applies``), and a
``check(tree, src, path) -> [Finding]``. Registration is by decorator;
``select_rules`` accepts exact ids ("L001"), families ("R"), or "all".
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.analysis.findings import Finding

ALL_RULES: Dict[str, "Rule"] = {}
RULE_FAMILIES = ("L", "R", "A", "K", "O")


class Rule:
    rule_id = "X000"
    family = "X"
    severity = "error"
    description = ""

    def applies(self, path: str) -> bool:
        return True

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        raise NotImplementedError

    def finding(self, path: str, line: int, message: str,
                severity: str = None) -> Finding:
        return Finding(path=path, line=line, rule=self.rule_id,
                       severity=severity or self.severity, message=message)


def register(cls):
    inst = cls()
    if inst.rule_id in ALL_RULES:
        raise ValueError(f"duplicate rule id {inst.rule_id}")
    ALL_RULES[inst.rule_id] = inst
    return cls


def select_rules(spec=None) -> Dict[str, Rule]:
    """``spec``: None/"all", or iterable of rule ids and/or families."""
    _load()
    if spec in (None, "all", ("all",), ["all"]):
        return dict(ALL_RULES)
    out: Dict[str, Rule] = {}
    for item in spec:
        item = item.strip()
        if item in ALL_RULES:
            out[item] = ALL_RULES[item]
        elif item in RULE_FAMILIES:
            out.update({rid: r for rid, r in ALL_RULES.items()
                        if r.family == item})
        else:
            raise ValueError(
                f"unknown rule or family {item!r}; known: "
                f"{sorted(ALL_RULES)} / families {RULE_FAMILIES}")
    return out


def _load() -> None:
    """Import every rules module (registration is import-time)."""
    from repro.analysis import (rules_async, rules_kernels,  # noqa: F401
                                rules_layering, rules_obs, rules_resource)


def rule_table() -> List[Dict]:
    """[{id, family, severity, description}] for docs / --list-rules."""
    _load()
    return [{"id": r.rule_id, "family": r.family, "severity": r.severity,
             "description": r.description}
            for r in sorted(ALL_RULES.values(), key=lambda r: r.rule_id)]
