"""Benchmark: advanced decoding (survey dim 4).

  * speculative decoding: target-model calls saved vs gamma (the memory-
    bound decode loop is the cost unit) for self-draft (upper bound),
    trained-ish draft, and LANTERN relaxation,
  * early exit: layers used vs confidence threshold.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.decoding import (acceptance_rate, early_exit_decode_step,
                                 speculative_generate)
from repro.models import build


def speculative() -> None:
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    target = build(cfg)
    tp = target.init(jax.random.PRNGKey(0))
    dcfg = cfg.with_(num_layers=1, d_model=128, num_heads=4, num_kv_heads=2,
                     d_ff=256, head_dim=32)
    draft = build(dcfg)
    dp = draft.init(jax.random.PRNGKey(1))
    rng = np.random.RandomState(0)
    prompt = list(rng.randint(1, cfg.vocab_size, size=24))
    n_new = 24
    for gamma in (2, 4):
        # self-draft = acceptance upper bound
        _, s_self = speculative_generate(target, target, tp, tp, prompt,
                                         max_new_tokens=n_new, gamma=gamma)
        _, s_rand = speculative_generate(target, draft, tp, dp, prompt,
                                         max_new_tokens=n_new, gamma=gamma)
        _, s_lant = speculative_generate(target, draft, tp, dp, prompt,
                                         max_new_tokens=n_new, gamma=gamma,
                                         temperature=0.8, lantern_k=16,
                                         lantern_delta=0.3)
        for tag, st in (("self", s_self), ("draft", s_rand),
                        ("lantern", s_lant)):
            speedup = n_new / max(st.target_calls, 1)
            emit(f"decode/spec/g{gamma}/{tag}", 0.0,
                 f"accept={acceptance_rate(st):.3f};"
                 f"target_calls={st.target_calls};"
                 f"call_reduction={speedup:.2f}x")


def early_exit() -> None:
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompt = jnp.asarray(rng.randint(1, cfg.vocab_size, (1, 24)), jnp.int32)
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_len=64))(
        params, {"tokens": prompt})
    tok = jnp.asarray([[3]], jnp.int32)
    for thr in (1.1, 0.5, 0.0):
        _, _, info = early_exit_decode_step(model, params, cache, tok, 24,
                                            threshold=thr, patience=0,
                                            min_layers=1)
        emit(f"decode/early_exit/thr{thr}", 0.0,
             f"layers={info['layers_used']}/{model.cfg.num_layers};"
             f"flops_frac={info['flops_frac']:.2f}")


def run() -> None:
    speculative()
    early_exit()


if __name__ == "__main__":
    run()
