"""`repro.cluster` (PR tentpole): the multi-engine Router.

Contracts locked down here:

  * with ONE replica the router is a transparent shim: ``Router.submit``
    streams are bit-identical at temperature 0 to the bare
    ``AsyncLVLMServer`` (mixed decoder strategies included),
  * with 2+ replicas every request completes EXACTLY ONCE (each rid
    finishes on exactly one replica's engine; fleet summary agrees),
  * failover: a killed pump loses no queued-but-unstarted request --
    survivors transparently serve them; a request that already streamed
    tokens re-raises instead of re-running,
  * prefix-affinity routing yields a STRICTLY higher prefix-cache hit
    count than round-robin on a shared-prefix workload,
  * drain lifecycle: a draining replica takes no new work, finishes its
    in-flight streams, and rejoins on ``undrain``,
  * SLO-slack deferred-queue reordering never starves a request:
    property-based (hypothesis shim) over random sizes/deadlines/waves
    under constant saturation, every admitted request eventually starts,
  * ``ClusterMetrics`` merges per-replica records into fleet-wide
    percentiles/attainment and reports routing + health.
"""
import asyncio

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import (AdmissionConfig, EngineConfig, GenerationConfig,
                       LVLM, Request)
from repro.cluster import ROUTING_POLICIES, Router
from repro.serving.admission import AdmissionController

MAX_NEW = 6
GEN = GenerationConfig(decoder="greedy", temperature=0.0,
                       max_new_tokens=MAX_NEW, gamma=3)


@pytest.fixture(scope="module")
def lvlm():
    return LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)


def _prompts(n, seed=0, lo=8, hi=16, shared=0):
    rng = np.random.RandomState(seed)
    pre = list(rng.randint(1, 512, size=shared)) if shared else []
    return [pre + list(rng.randint(1, 512, size=rng.randint(lo, hi)))
            for _ in range(n)]


def _reqs(prompts, new=MAX_NEW, decoders=None):
    reqs = [Request(rid=i, tokens=list(p), max_new_tokens=new)
            for i, p in enumerate(prompts)]
    if decoders:
        for r, d in zip(reqs, decoders):
            r.decoder = d
    return reqs


def _ec(**kw):
    base = dict(max_batch=4, cache_len=96, temperature=0.0)
    base.update(kw)
    return EngineConfig(**base)


async def _consume(stream):
    return [tok async for tok in stream]


def _drive_all(front, reqs):
    async def drive():
        async with front:
            return await asyncio.gather(
                *(_consume(front.submit(r)) for r in reqs))

    outs = asyncio.run(drive())
    return {r.rid: list(o) for r, o in zip(reqs, outs)}


# ------------------------------------------------- 1-replica identity --


@pytest.mark.slow
def test_single_replica_router_bit_identical_to_server(lvlm):
    """Router(1 replica) must add NOTHING observable: same prompts, same
    mixed strategies, bit-identical streams vs the bare async server."""
    decoders = ["speculative", "greedy", "early_exit", "sampling"]
    prompts = _prompts(4, seed=3)
    ref = _drive_all(lvlm.serve_async(_ec(), gen=GEN),
                     _reqs(prompts, decoders=decoders))
    got = _drive_all(lvlm.serve_cluster(1, _ec(), gen=GEN),
                     _reqs(prompts, decoders=decoders))
    assert got == ref


# ------------------------------------------------------- exactly once --


@pytest.mark.parametrize("routing", ["round_robin", "least_kv"])
def test_multi_replica_every_request_completes_exactly_once(lvlm, routing):
    prompts = _prompts(8, seed=4)
    reqs = _reqs(prompts)
    router = lvlm.serve_cluster(2, _ec(), gen=GEN, routing=routing)
    got = _drive_all(router, reqs)
    assert all(len(got[r.rid]) == MAX_NEW for r in reqs)
    # each rid finished on EXACTLY one replica's engine
    per_engine = [sorted(r.rid for r in rep.server.engine.finished)
                  for rep in router.replicas]
    assert sorted(sum(per_engine, [])) == list(range(8))
    # both replicas actually served work
    assert all(rep.dispatched > 0 for rep in router.replicas)
    s = router.summary()
    assert s["finished"] == 8 and s["aborted"] == 0
    assert s["failovers"] == 0
    assert s["routing_policy"] == routing
    assert s["dispatched_by_replica"] == [rep.dispatched
                                          for rep in router.replicas]
    assert s["completed_by_replica"] == [len(e) for e in per_engine]
    # fleet clock = slowest replica; throughput covers all fleet tokens
    clocks = [rep.server.engine.clock for rep in router.replicas]
    assert s["virtual_time_s"] == max(clocks)
    assert s["fleet_throughput_tok_per_s"] == pytest.approx(
        s["tokens"] / max(clocks))


def test_duplicate_rid_rejected_fleet_wide(lvlm):
    router = lvlm.serve_cluster(2, _ec(), gen=GEN)

    async def drive():
        async with router:
            s = router.submit(Request(rid=0, tokens=[1, 2, 3],
                                      max_new_tokens=2))
            with pytest.raises(ValueError):
                router.submit(Request(rid=0, tokens=[4], max_new_tokens=1))
            return await _consume(s)

    assert len(asyncio.run(drive())) == 2


# ----------------------------------------------------------- failover --


def test_failover_on_killed_pump_loses_no_queued_request(lvlm):
    """Kill replica 0's pump before its requests start: every queued
    request fails over to replica 1 and completes; the dead replica is
    reported; pool accounting on the survivor returns to zero."""
    reqs = _reqs(_prompts(4, seed=5))
    router = lvlm.serve_cluster(2, _ec(), gen=GEN)   # round-robin: 0,2 -> r0

    async def drive():
        async with router:
            streams = [router.submit(r) for r in reqs]

            def boom():
                raise RuntimeError("injected replica failure")

            router.replicas[0].server.engine.step = boom
            return await asyncio.gather(*(_consume(s) for s in streams))

    outs = asyncio.run(drive())
    assert all(len(o) == MAX_NEW for o in outs)
    assert router.failovers == 2
    assert [rep.state for rep in router.replicas] == ["dead", "ok"]
    assert isinstance(router.replicas[0].error, RuntimeError)
    # everything actually ran on the survivor, exactly once each
    assert sorted(r.rid for r in
                  router.replicas[1].server.engine.finished) == [0, 1, 2, 3]
    assert router.replicas[1].server.engine.kv_committed_tokens() == 0
    s = router.summary()
    assert s["finished"] == 4 and s["failovers"] == 2
    assert s["replica_states"] == ["dead", "ok"]


def test_failover_does_not_rerun_started_streams(lvlm):
    """A stream that already emitted tokens must RE-RAISE on pump death
    (tokens cannot be un-sent), never silently re-run elsewhere."""
    req = Request(rid=0, tokens=_prompts(1, seed=6)[0], max_new_tokens=24)
    router = lvlm.serve_cluster(2, _ec(), gen=GEN)

    async def drive():
        async with router:
            stream = router.submit(req)
            got = []
            with pytest.raises(RuntimeError, match="mid-stream"):
                async for tok in stream:
                    got.append(tok)
                    if len(got) == 2:
                        stream.replica.server.engine.step = _boom
            return got

    def _boom():
        raise RuntimeError("injected mid-stream failure")

    got = asyncio.run(drive())
    assert len(got) >= 2 and router.failovers == 0
    # the OTHER replica never saw the request
    dead = next(rep for rep in router.replicas if rep.dead)
    other = next(rep for rep in router.replicas if not rep.dead)
    assert other.server.engine.finished == []
    assert dead.dispatched == 1 and other.dispatched == 0


def test_all_draining_parks_submit_until_undrain(lvlm):
    """Regression (drain->undrain race): a submit that lands while every
    live replica is transiently draining must NOT raise -- the stream
    parks router-side and dispatches when a replica rejoins."""
    router = lvlm.serve_cluster(1, _ec(), gen=GEN)

    async def drive():
        async with router:
            router.drain(0)
            stream = router.submit(Request(rid=0, tokens=[1, 2, 3],
                                           max_new_tokens=2))
            assert stream.parked and stream.replica is None
            task = asyncio.create_task(_consume(stream))
            await asyncio.sleep(0.01)     # consumer blocks while parked
            assert not task.done() and not stream._done
            router.undrain(0)
            out = await task
            assert stream.replica.index == 0
            return out

    assert len(asyncio.run(drive())) == 2
    assert router._streams == {} and router._parked == []
    assert router.summary()["finished"] == 1


def test_parked_submit_cancel_frees_router_state(lvlm):
    """A parked stream whose consumer gives up must free the rid (no
    replica ever saw the request)."""
    router = lvlm.serve_cluster(1, _ec(), gen=GEN)

    async def drive():
        async with router:
            router.drain(0)
            stream = router.submit(Request(rid=0, tokens=[1],
                                           max_new_tokens=1))
            assert stream.parked
            stream.cancel()
            assert 0 not in router._streams and router._parked == []
            router.undrain(0)             # nothing left to dispatch
            out = await _consume(router.submit(Request(
                rid=0, tokens=[1, 2], max_new_tokens=2)))
            return out

    assert len(asyncio.run(drive())) == 2


def test_all_dead_fleet_raises_on_submit(lvlm):
    """Parking is for TRANSIENT unavailability; a fleet whose every pump
    died can never recover, so submit fails fast."""
    router = lvlm.serve_cluster(1, _ec(), gen=GEN)

    async def drive():
        async with router:
            def boom():
                raise RuntimeError("injected failure")

            router.replicas[0].server.engine.step = boom
            with pytest.raises(RuntimeError):
                await _consume(router.submit(Request(rid=0, tokens=[1],
                                                     max_new_tokens=1)))
            with pytest.raises(RuntimeError, match="no live replica"):
                router.submit(Request(rid=1, tokens=[1], max_new_tokens=1))

    asyncio.run(drive())


# ----------------------------------------------------- prefix affinity --


def test_prefix_affinity_beats_round_robin_on_shared_prefix(lvlm):
    """Shared-prefix traffic: affinity routes the family to one replica
    (every request after the first reuses the cached prefix) while
    round-robin splits it (each replica pays its own cold miss) -- the
    fleet-wide hit count must be STRICTLY higher under affinity."""
    hits = {}
    for routing in ("round_robin", "prefix_affinity"):
        prompts = _prompts(6, seed=7, lo=4, hi=8, shared=32)
        router = lvlm.serve_cluster(
            2, _ec(cache_len=128, prefix_cache=True), gen=GEN,
            routing=routing)
        got = _drive_all(router, _reqs(prompts, new=4))
        assert all(len(o) == 4 for o in got.values())
        hits[routing] = router.summary()["prefix_hit_tokens"]
    assert hits["prefix_affinity"] > hits["round_robin"]
    assert hits["round_robin"] > 0          # both replicas did cache


def test_prefix_affinity_converges_cold_prefixes(lvlm):
    """Before anything is cached the policy consistent-hashes the first
    block, so one prefix family lands on ONE replica from the start."""
    prompts = _prompts(4, seed=8, lo=4, hi=8, shared=32)
    router = lvlm.serve_cluster(
        2, _ec(cache_len=128, prefix_cache=True), gen=GEN,
        routing="prefix_affinity")
    _drive_all(router, _reqs(prompts, new=4))
    assert sorted(rep.dispatched for rep in router.replicas) == [0, 4]


# -------------------------------------------------------------- drain --


def test_drain_lifecycle(lvlm):
    """Draining: in-flight streams finish, no new work; undrain rejoins."""
    router = lvlm.serve_cluster(2, _ec(), gen=GEN, routing="least_kv")
    p = _prompts(6, seed=9)

    async def drive():
        async with router:
            first = router.submit(Request(rid=0, tokens=p[0],
                                          max_new_tokens=MAX_NEW))
            assert first.replica.index == 0          # idle tie -> index 0
            router.drain(0)
            mid = await asyncio.gather(*(
                _consume(router.submit(Request(rid=i, tokens=p[i],
                                               max_new_tokens=MAX_NEW)))
                for i in (1, 2)))
            out_first = await _consume(first)        # drained, still served
            router.undrain(0)
            last = router.submit(Request(rid=3, tokens=p[3],
                                         max_new_tokens=MAX_NEW))
            out_last = await _consume(last)
            return out_first, mid, out_last, last.replica.index

    out_first, mid, out_last, last_idx = asyncio.run(drive())
    assert len(out_first) == MAX_NEW                 # in-flight finished
    assert all(len(o) == MAX_NEW for o in mid)
    assert len(out_last) == MAX_NEW
    # while draining, replica 0 got nothing new
    assert router.replicas[0].dispatched + router.replicas[1].dispatched == 4
    assert router.replicas[1].dispatched >= 2
    assert last_idx == 0                             # undrain rejoined
    assert router.summary()["finished"] == 4


# -------------------------------------------- server-initiated aborts --


def test_disconnect_through_router_frees_rid_and_inflight(lvlm):
    """Regression: a replica-initiated abort (disconnect timeout fires
    inside the pump; the hung consumer never iterates again) must drop
    the ROUTER's bookkeeping too -- the rid frees up for reuse and the
    replica's inflight map does not leak."""
    router = lvlm.serve_cluster(1, _ec(), gen=GEN,
                                disconnect_timeout_s=0.05)
    eng = router.replicas[0].server.engine
    real_step = eng.step

    def paced_step():                       # >=20ms/step: cannot finish
        import time                         # 24 tokens inside the 50ms
        time.sleep(0.02)                    # timeout window
        return real_step()

    eng.step = paced_step
    p = _prompts(2, seed=10, lo=10, hi=12)

    async def drive():
        async with router:
            hung = router.submit(Request(rid=0, tokens=p[0],
                                         max_new_tokens=24))
            await hung.__anext__()           # start it, then go silent
            for _ in range(200):             # pump aborts the hung one
                if 0 not in router._streams:
                    break
                await asyncio.sleep(0.02)
            # rid 0 is free again: resubmit works and completes
            out = await _consume(router.submit(Request(
                rid=0, tokens=p[1], max_new_tokens=MAX_NEW)))
            return out

    out = asyncio.run(drive())
    assert len(out) == MAX_NEW
    assert router._streams == {}
    assert router.replicas[0].inflight == {}
    assert router.replicas[0].server.disconnects == 1
    assert eng.kv_committed_tokens() == 0


def test_cancelled_consumer_task_frees_router_state(lvlm):
    """Regression: cancelling the CONSUMER TASK (the normal asyncio
    client-disconnect path) while the request is parked at a saturated
    replica's admission gate must free the rid and the replica's inflight
    entry -- not leak them forever."""
    # capacity 1*64; tiny watermark => second request parks at the gate
    router = lvlm.serve_cluster(
        1, _ec(max_batch=1, cache_len=64), gen=GEN,
        admission=AdmissionConfig(high_watermark=0.3, low_watermark=0.3))

    async def drive():
        async with router:
            r0 = Request(rid=0, tokens=_prompts(1, seed=11, lo=12,
                                                hi=13)[0],
                         max_new_tokens=16)     # long enough to outlive
            #                                     the cancellation dance
            r1 = Request(rid=1, tokens=[1, 2, 3], max_new_tokens=MAX_NEW)
            t0 = asyncio.create_task(_consume(router.submit(r0)))
            await asyncio.sleep(0)               # r0 enters the engine
            t1 = asyncio.create_task(_consume(router.submit(r1)))
            await asyncio.sleep(0)               # r1 parks at the gate
            t1.cancel()
            with pytest.raises(asyncio.CancelledError):
                await t1
            assert 1 not in router._streams      # rid freed immediately
            assert 1 not in router.replicas[0].inflight
            assert router.replicas[0].kv_load() > 0   # r0 still counted
            return await t0

    out0 = asyncio.run(drive())
    assert len(out0) == 16
    assert router._streams == {} and router.replicas[0].inflight == {}
    assert router.summary()["finished"] == 1


# ------------------------------------- SLO-slack starvation freedom --


class _FakeEngine:
    """Duck-typed engine for AdmissionController: KV accounting + a
    finish_one() tick, no model. Keeps the property test jit-free."""

    def __init__(self, capacity):
        self.kv_capacity_tokens = capacity
        self.waiting = []            # unused; admission checks emptiness
        self.running = []
        self.clock = 0.0

    def kv_request_tokens(self, req):
        need = req.prompt_len + req.max_new_tokens
        return ((need + 15) // 16) * 16

    def kv_committed_tokens(self, include_waiting=True):
        return sum(self.kv_request_tokens(r) for r in self.running)

    def submit(self, req):
        req.arrival = max(req.arrival, self.clock)
        self.running.append(req)

    def finish_one(self):
        if self.running:
            self.running.pop(0)
            self.clock += 1.0


@given(spec=st.lists(
    st.tuples(st.integers(min_value=1, max_value=3),      # size (x16 tok)
              st.floats(min_value=1.0, max_value=60_000.0)),  # slo ttft ms
    min_size=4, max_size=12))
@settings(max_examples=15, deadline=None)
def test_slack_reordering_never_starves(spec):
    """Property: under constant saturation (capacity ~2 requests, waiters
    always present, a second wave of fresh tight-deadline arrivals landing
    mid-run), EVERY request admitted under SLO-slack ordering eventually
    starts -- the EDF drain order plus no-bypass admission guarantees it
    within a bounded number of completions."""
    async def scenario():
        eng = _FakeEngine(capacity=96)
        ctl = AdmissionController(
            AdmissionConfig(high_watermark=0.5, low_watermark=0.5,
                            order="slack"), eng)
        ctl.order_key = lambda r: (
            max(r.arrival, getattr(r, "_gate_clock", 0.0))
            + r.slo.ttft_ms * 1e-3 - eng.clock)
        reqs = []
        for i, (blocks, slo_ms) in enumerate(spec):
            r = Request(rid=i, tokens=[1] * (blocks * 16 - 4),
                        max_new_tokens=4)
            r.slo.ttft_ms = slo_ms
            reqs.append(r)
        half = len(reqs) // 2
        tasks = [asyncio.ensure_future(ctl.admit(r)) for r in reqs[:half]]
        for tick in range(20 * len(reqs) + 20):
            await asyncio.sleep(0)
            if tick == 3:                  # second wave arrives mid-run
                tasks += [asyncio.ensure_future(ctl.admit(r))
                          for r in reqs[half:]]
            eng.finish_one()               # saturation: slots free slowly
            ctl.maybe_admit()
            if len(tasks) == len(reqs) and all(t.done() for t in tasks):
                break
        assert len(tasks) == len(reqs) and all(t.done() for t in tasks), \
            "a request starved at the admission gate"
        assert all(await asyncio.gather(*tasks))
        assert ctl.queue_depth == 0

    asyncio.run(scenario())
