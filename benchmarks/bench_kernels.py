"""Benchmark: hardware-aware attention (survey dim 3c).

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled -- correctness only), so the timing rows
compare the XLA-compiled blockwise flash-style path against naive
materialized attention, plus an interpret-mode allclose spot check. True
kernel timing belongs on a TPU runtime (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jit
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.models.attention import blockwise_sdpa


def _naive(q, k, v, pos):
    s = jnp.einsum("bqkgd,bckd->bkgqc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (q.shape[-1] ** 0.5)
    mask = pos[None, :] <= pos[:, None]
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1)


def run() -> None:
    rng = np.random.RandomState(0)
    for s in (512, 2048):
        b, kvh, g, d = 1, 2, 2, 64
        q = jnp.asarray(rng.randn(b, s, kvh, g, d), jnp.float32)
        k = jnp.asarray(rng.randn(b, s, kvh, d), jnp.float32)
        v = jnp.asarray(rng.randn(b, s, kvh, d), jnp.float32)
        pos = jnp.arange(s)
        us_naive = time_jit(jax.jit(lambda *a: _naive(*a, pos)), q, k, v,
                            iters=3)
        us_block = time_jit(jax.jit(
            lambda qq, kk, vv: blockwise_sdpa(qq, kk, vv, q_pos=pos,
                                              k_pos=pos, causal=True,
                                              block_k=512)), q, k, v,
            iters=3)
        emit(f"kern/flash_xla/s{s}", us_block,
             f"naive_us={us_naive:.0f};peak_mem_ratio~{512 / s:.2f}")
    # interpret-mode correctness spot check (the TPU kernel's oracle gate)
    q = jnp.asarray(rng.randn(1, 4, 64, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 64, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 64, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.abs(out - expect).max())
    emit("kern/pallas_interpret_allclose", 0.0, f"max_err={err:.2e}")


if __name__ == "__main__":
    run()
