"""AdamW + cosine schedule (pure pytree implementation; no optax here).

Decoupled weight decay (Loshchilov & Hutter), bias-corrected moments in
float32 regardless of param dtype (bf16-safe master moments), global-norm
gradient clipping.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3.0e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1.0e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: OptimizerConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptimizerConfig, grads, opt_state, params
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else jnp.float32(1.0)
    b1, b2 = cfg.betas
    lr = cosine_lr(cfg, step)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / c1
        nhat = nu / c2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, mu, nu) for p, g, mu, nu
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
