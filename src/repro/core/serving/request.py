"""Request lifecycle for the LVLM serving layer (survey dim 2c)."""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional

import numpy as np


class State(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"          # (possibly chunked) prompt processing
    DECODE = "decode"
    MIGRATING = "migrating"      # KV export pinned, awaiting import elsewhere
    PREEMPTED = "preempted"
    DONE = "done"


@dataclasses.dataclass
class SLO:
    ttft_ms: float = 500.0       # time-to-first-token target
    tpot_ms: float = 50.0        # time-per-output-token target


@dataclasses.dataclass
class Request:
    rid: int
    tokens: List[int]                       # prompt token ids
    max_new_tokens: int = 32
    visual_embeds: Optional[np.ndarray] = None   # [Nv, d] stub patches
    arrival: float = 0.0
    slo: SLO = dataclasses.field(default_factory=SLO)
    # per-request decode strategy (survey dim 4): None -> the engine's
    # configured default; otherwise a registered decoder name
    # ("greedy" | "sampling" | "speculative" | "early_exit" | custom).
    # The engine groups decode-phase slots by strategy each iteration, so
    # one Engine serves a mixed-strategy workload.
    decoder: Optional[str] = None
    # per-request visual-token compression strategy (survey dim 1/2a):
    # None -> the engine's default; otherwise a registered strategy name
    # or any preset/parametric name ("fastv-0.5", "framefusion-0.25",
    # "streaming-kv-64", ...) -- resolved exactly like ``decoder``, so a
    # video request can run aggressive pruning next to an uncompressed
    # chat request in the same batch.
    compression: Optional[str] = None
    # extra KV positions reserved beyond prompt+max_new (set by the engine
    # at submit: speculative verify writes up to ``gamma`` draft positions
    # ahead of the committed stream, so its slots need gamma slack).
    # Schedulers account it when admitting against KV capacity.
    lookahead: int = 0
    # disaggregated serving (survey dim 2c-ii): a handoff request runs
    # prefill on THIS engine but decodes elsewhere -- after the first token
    # it parks in MIGRATING instead of entering DECODE, and the KV snapshot
    # is exported to a decode-role replica. Its KV reservation here covers
    # only the prompt (plus the first token), not max_new_tokens.
    handoff: bool = False

    # runtime state ---------------------------------------------------------
    state: State = State.WAITING
    # POST-compression visual-token count, stamped by the engine when the
    # request's compression strategy is first resolved (submit or the
    # admission gate's kv_request_tokens probe). None until then; KV
    # accounting falls back to the full visual count.
    nv_compressed: Optional[int] = None
    prefill_done: int = 0                   # tokens of prompt processed
    generated: List[int] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    aborted: bool = False                   # cancelled via Engine.abort()
    # scheduling metadata
    priority: int = 0                        # MLFQ level
    served_tokens: int = 0
    predicted_len: Optional[int] = None      # ShuffleInfer-style estimate

    @property
    def prompt_len(self) -> int:
        nv = 0 if self.visual_embeds is None else len(self.visual_embeds)
        return len(self.tokens) + nv

    @property
    def total_len(self) -> int:
        return self.prompt_len + len(self.generated)

    @property
    def kv_prompt_len(self) -> int:
        """Prompt tokens that actually LAND in the KV cache: text plus the
        POST-compression visual count once the engine resolved the
        request's compression strategy (``prompt_len`` keeps the full
        pre-compression count for workload/latency reporting)."""
        if self.nv_compressed is None:
            return self.prompt_len
        return len(self.tokens) + self.nv_compressed

    @property
    def kv_total_len(self) -> int:
        return self.kv_prompt_len + len(self.generated)

    def is_finished(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    # metrics ----------------------------------------------------------------
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def jct(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None \
                or len(self.generated) <= 1:
            return None
        return ((self.finish_time - self.first_token_time)
                / (len(self.generated) - 1))


def percentiles(vals: List[float], prefix: str,
                ps=(50, 95, 99)) -> Dict[str, Optional[float]]:
    """``{prefix}_p50/p95/p99`` latency summary (None when empty)."""
    if not vals:
        return {f"{prefix}_p{p}": None for p in ps}
    return {f"{prefix}_p{p}": float(np.percentile(vals, p)) for p in ps}


def slo_attainment(reqs: List[Request]) -> Dict[str, Optional[float]]:
    """Fraction of finished requests meeting their OWN per-request SLO
    targets (``Request.slo``, milliseconds against the virtual clock):
    TTFT, TPOT, and both at once (DistServe-style goodput fraction)."""
    done = [r for r in reqs if r.finish_time is not None]
    if not done:
        return {"slo_ttft_attainment": None, "slo_tpot_attainment": None,
                "slo_goodput": None}
    ttft_ok = tpot_ok = both = 0
    for r in done:
        t_ok = (r.ttft() or 0.0) <= r.slo.ttft_ms * 1e-3
        p_ok = (r.tpot() or 0.0) <= r.slo.tpot_ms * 1e-3
        ttft_ok += t_ok
        tpot_ok += p_ok
        both += t_ok and p_ok
    n = len(done)
    return {"slo_ttft_attainment": ttft_ok / n,
            "slo_tpot_attainment": tpot_ok / n,
            "slo_goodput": both / n}


def summarize(reqs: List[Request]) -> Dict:
    done = [r for r in reqs if r.finish_time is not None]
    if not done:
        return {"finished": 0}
    ttfts = [r.ttft() for r in done if r.ttft() is not None]
    jcts = [r.jct() for r in done]
    tpots = [r.tpot() for r in done if r.tpot() is not None]
    tokens = sum(len(r.generated) for r in done)
    makespan = max(r.finish_time for r in done) - min(r.arrival for r in done)
    out = {
        "finished": len(done),
        "tokens": tokens,
        "throughput_tok_per_s": tokens / max(makespan, 1e-9),
        "ttft_mean": float(np.mean(ttfts)) if ttfts else None,
        "jct_mean": float(np.mean(jcts)),
        "tpot_mean": float(np.mean(tpots)) if tpots else None,
        "makespan": makespan,
    }
    out.update(percentiles(ttfts, "ttft"))
    out.update(percentiles(tpots, "tpot"))
    out.update(slo_attainment(done))
    return out
