"""Serving layer (dim 2c): schedulers, engine fidelity, disaggregation."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import CompressionConfig
from repro.core.serving import (ChunkedPrefillScheduler, ContinuousBatcher,
                                CostModel, Engine, EngineConfig,
                                MLFQScheduler, PoolConfig, Request,
                                StaticBatcher, goodput,
                                simulate_colocated, simulate_disaggregated)
from repro.models import build


def mkreqs(n, vocab=512, seed=0, lo=8, hi=24, new=6, arrival_gap=0.0):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    tokens=list(rng.randint(1, vocab,
                                            size=rng.randint(lo, hi))),
                    max_new_tokens=new, arrival=i * arrival_gap)
            for i in range(n)]


# ------------------------------------------------------------ schedulers --

def test_continuous_batcher_respects_capacity():
    sched = ContinuousBatcher(max_batch=2, kv_capacity_tokens=64,
                              block_size=8)
    reqs = mkreqs(5)
    plan = sched.plan(reqs, [])
    assert len(plan.prefill) <= 2
    # kv capacity bound: sum of rounded-up footprints <= capacity
    used = sum(((r.prompt_len + r.max_new_tokens + 7) // 8) * 8
               for r, _ in plan.prefill)
    assert used <= 64


def test_static_batcher_head_of_line():
    sched = StaticBatcher(batch_size=2)
    reqs = mkreqs(4)
    plan1 = sched.plan(reqs, [])
    assert len(plan1.prefill) == 2
    # while the batch runs, nothing new is admitted (the HOL strawman)
    plan2 = sched.plan(reqs[2:], [r for r, _ in plan1.prefill])
    assert not plan2.prefill


def test_mlfq_demotes_long_runners():
    sched = MLFQScheduler(max_batch=4, kv_capacity_tokens=4096,
                          base_quantum=4)
    reqs = mkreqs(2, new=64)
    for r in reqs:
        r.state = r.state.DECODE
        r.served_tokens = 100           # way past the quantum
        r.priority = 0
    sched.plan([], reqs)
    assert all(r.priority > 0 for r in reqs)


def test_chunked_prefill_budget():
    sched = ChunkedPrefillScheduler(max_batch=8, token_budget=32,
                                    chunk_size=16)
    reqs = mkreqs(6, lo=40, hi=60)
    plan = sched.plan(reqs, [])
    assert plan.prefill_tokens <= 32
    assert all(n <= 16 for _, n in plan.prefill)


# ---------------------------------------------------------------- engine --

@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _gen(model, params, prompts, **kw):
    eng = Engine(model, params, EngineConfig(max_batch=4, cache_len=96, **kw))
    reqs = [Request(rid=i, tokens=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.rid: tuple(r.generated) for r in eng.finished}


def test_engine_scheduler_fidelity(small_model):
    """Greedy outputs must be IDENTICAL across scheduling policies --
    scheduling must never change results, only latency."""
    cfg, model, params = small_model
    rng = np.random.RandomState(1)
    shared = list(rng.randint(1, cfg.vocab_size, size=16))
    prompts = [shared + list(rng.randint(1, cfg.vocab_size, size=12))
               for _ in range(3)]
    base = _gen(model, params, prompts, scheduler="continuous")
    assert base == _gen(model, params, prompts, scheduler="chunked",
                        chunk_size=7, token_budget=16)
    assert base == _gen(model, params, prompts, scheduler="mlfq")
    assert base == _gen(model, params, prompts, scheduler="static")
    assert base == _gen(model, params, prompts, scheduler="continuous",
                        prefix_cache=True, prefix_block=8)


def test_engine_prefix_cache_hits(small_model):
    cfg, model, params = small_model
    rng = np.random.RandomState(2)
    shared = list(rng.randint(1, cfg.vocab_size, size=32))
    eng = Engine(model, params,
                 EngineConfig(max_batch=2, cache_len=96,
                              prefix_cache=True, prefix_block=8))
    for i in range(4):
        eng.submit(Request(rid=i, tokens=shared + [int(i) + 1],
                           max_new_tokens=3))
    out = eng.run()
    assert out["prefix_token_hit_rate"] > 0.5


def test_engine_kv_compaction_runs(small_model):
    cfg, model, params = small_model
    rng = np.random.RandomState(3)
    eng = Engine(model, params, EngineConfig(
        max_batch=2, cache_len=128, scheduler="continuous",
        compression=CompressionConfig(kv_selector="streaming",
                                      kv_budget=24)))
    for i in range(2):
        eng.submit(Request(
            rid=i, tokens=list(rng.randint(1, cfg.vocab_size, size=60)),
            max_new_tokens=5))
    out = eng.run()
    assert out["finished"] == 2
    assert out["tokens"] == 10


def test_engine_rejects_oversized_request(small_model):
    cfg, model, params = small_model
    eng = Engine(model, params, EngineConfig(max_batch=1, cache_len=32))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, tokens=list(range(1, 30)),
                           max_new_tokens=8))


def test_engine_vlm_with_pruning():
    cfg = get_config("qwen2-vl-2b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    eng = Engine(model, params, EngineConfig(
        max_batch=2, cache_len=96,
        compression=CompressionConfig(token_pruner="divprune",
                                      keep_ratio=0.5)))
    ve = rng.randn(cfg.num_visual_tokens, cfg.d_model).astype(np.float32)
    eng.submit(Request(rid=0, tokens=list(rng.randint(1, 512, size=12)),
                       max_new_tokens=4, visual_embeds=ve))
    out = eng.run()
    assert out["finished"] == 1
    # compressed visual tokens: slot offset must reflect keep_ratio
    assert eng.slot_nv[0] == cfg.num_visual_tokens // 2


# ------------------------------------------------------- KV pressure --

def test_engine_near_full_kv_pool_defers_not_crashes(small_model):
    """A narrowed KV budget (EngineConfig.kv_capacity_tokens) saturates
    before the slot pool: the continuous batcher must DEFER admissions --
    per-step committed KV stays within capacity, no OutOfBlocksError /
    no-free-slot escape, and every request still finishes."""
    cfg, model, params = small_model
    rng = np.random.RandomState(11)
    eng = Engine(model, params, EngineConfig(
        max_batch=4, cache_len=64, kv_capacity_tokens=96))
    reqs = [Request(rid=i,
                    tokens=list(rng.randint(1, cfg.vocab_size, size=36)),
                    max_new_tokens=8) for i in range(4)]
    for r in reqs:
        eng.submit(r)                     # each needs 48 -> only 2 fit
    assert eng.kv_request_tokens(reqs[0]) == 48
    peak = 0
    while eng.step():
        used = eng.kv_committed_tokens(include_waiting=False)
        assert used <= eng.kv_capacity_tokens
        peak = max(peak, used)
    assert peak == 96                     # the pool really was near-full
    assert len(eng.finished) == 4
    assert all(len(r.generated) == 8 for r in reqs)


def test_spec_gamma_reservation_respected_at_boundary(small_model):
    """Watermark-boundary case: two speculative requests fit together
    WITHOUT the gamma lookahead but not WITH it -- the scheduler must
    serialize them (reservation respected), and outputs still match the
    unconstrained run."""
    cfg, model, params = small_model
    from repro.api import GenerationConfig, LVLM
    lv = LVLM(model, params)
    rng = np.random.RandomState(12)
    prompts = [list(rng.randint(1, cfg.vocab_size, size=10))
               for _ in range(2)]
    gen = GenerationConfig(decoder="greedy", temperature=0.0,
                           max_new_tokens=5, gamma=4)

    def run(kv_cap):
        reqs = [Request(rid=i, tokens=list(p), max_new_tokens=5,
                        decoder="speculative")
                for i, p in enumerate(prompts)]
        eng = lv._serve_engine(
            EngineConfig(max_batch=2, cache_len=64, temperature=0.0,
                         kv_capacity_tokens=kv_cap), gen, None)
        for r in reqs:
            eng.submit(r)
        # base need 10+5=15 -> one 16-block; +gamma 19 -> 32
        assert all(eng.kv_request_tokens(r) == 32 for r in reqs)
        peak = 0
        while eng.step():
            peak = max(peak, len(eng.running))
        return {r.rid: list(r.generated) for r in reqs}, peak

    tight, tight_peak = run(48)           # 2x32=64 > 48: must serialize
    loose, loose_peak = run(None)         # full pool: coexist
    assert tight_peak == 1
    assert loose_peak == 2
    assert tight == loose                 # serialization changes latency,
                                          # never tokens


# --------------------------------------------------------- disaggregation --

def test_disaggregation_beats_colocated_on_mixed_load():
    """DistServe's claim: separating prefill/decode pools improves TTFT+TPOT
    goodput under mixed long-prefill / decode-heavy load."""
    cost = CostModel(prefill_us_per_token=30.0, decode_us_per_token=600.0,
                     decode_us_per_ctx_token=0.01)
    reqs_a = mkreqs(24, lo=200, hi=400, new=32, arrival_gap=0.002, seed=5)
    co = simulate_colocated([Request(**_clone(r)) for r in reqs_a], cost,
                            n_instances=2, decode_batch=16)
    dis = simulate_disaggregated([Request(**_clone(r)) for r in reqs_a],
                                 cost, PoolConfig(n_prefill=1, n_decode=1,
                                                  decode_batch=16))
    # same 2 instances total: disaggregation removes prefill/decode
    # interference -> TPOT improves sharply (here ~4x); TTFT pays for the
    # halved prefill pool (the DistServe pool-sizing trade-off)
    assert dis["tpot_mean"] < co["tpot_mean"] * 0.5
    assert dis["ttft_p99"] <= co["ttft_p99"] * 3.0


def _clone(r):
    return dict(rid=r.rid, tokens=list(r.tokens),
                max_new_tokens=r.max_new_tokens, arrival=r.arrival)


def test_kv_transfer_cost_hurts_disaggregation():
    """Survey §V: multimodal KV transfer erodes disaggregation gains."""
    reqs = mkreqs(16, lo=100, hi=200, new=16, arrival_gap=0.005, seed=6)
    base = CostModel()
    heavy = CostModel(kv_bytes_per_token=2_000_000, transfer_gbps=20.0)
    fast = simulate_disaggregated([Request(**_clone(r)) for r in reqs],
                                  base, PoolConfig())
    slow = simulate_disaggregated([Request(**_clone(r)) for r in reqs],
                                  heavy, PoolConfig())
    # transfer delays decode entry: JCT degrades even though TTFT (from the
    # prefill pool) is unchanged -- exactly the survey's §V caveat
    assert slow["jct_mean"] > fast["jct_mean"]


def test_goodput_metric():
    reqs = mkreqs(4, new=4)
    for i, r in enumerate(reqs):
        r.first_token_time = r.arrival + (0.1 if i < 2 else 2.0)
        r.finish_time = r.first_token_time + 0.03 * r.max_new_tokens
        r.generated = [1] * r.max_new_tokens
    g = goodput(reqs, ttft_slo=0.5, tpot_slo=0.05)
    assert g == 0.5
