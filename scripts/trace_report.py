"""Per-stage attribution report over a repro.obs JSONL event log.

    PYTHONPATH=src python -m repro.launch.serve --roles prefill,decode \
        --open-loop 2000 --trace-events /tmp/events.jsonl
    PYTHONPATH=src python scripts/trace_report.py /tmp/events.jsonl

For every request the report splits its lifetime (virtual clock) into
the lifecycle stages the tracer spans mark -- admission wait, prefill,
compression, KV migration, decode (the remainder) -- then aggregates
mean/p50/p95 per stage plus the share of total request-seconds each
stage consumed. That attribution is the first question a latency
regression asks: did the time go to the admission gate, the chunked
prefill, the KV link, or the decode loop?

Also reports per-replica engine occupancy from the ``engine_step``
slices and the wall/virtual clock ratio (how much real time the smoke
model spends per modeled second).
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

# stage span names, innermost attribution order; "decode" is the
# request-span remainder after the named stages
STAGES = ("admission_wait", "prefill", "compress", "kv_migration")


def load_events(path):
    events = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def _pct(vals, p):
    if not vals:
        return 0.0
    vals = sorted(vals)
    idx = min(len(vals) - 1, int(round((p / 100.0) * (len(vals) - 1))))
    return vals[idx]


def attribute(events):
    """Per-rid stage durations (virtual seconds) from span pairs."""
    opens = {}                       # (rid, name) -> begin event
    stages = defaultdict(lambda: defaultdict(float))   # rid -> stage -> s
    request = {}                     # rid -> (begin_vt, end_vt, aborted)
    for ev in events:
        k, name, rid = ev.get("k"), ev.get("name"), ev.get("rid")
        if k == "B":
            opens[(rid, name)] = ev
        elif k == "E":
            b = opens.pop((rid, name), None)
            if b is None:
                continue
            dur = ev.get("vt", 0.0) - b.get("vt", 0.0)
            if name == "request":
                aborted = bool((ev.get("attrs") or {}).get("aborted"))
                request[rid] = (b.get("vt", 0.0), ev.get("vt", 0.0),
                                aborted)
            elif name in STAGES:
                stages[rid][name] += dur
    return request, stages


def occupancy(events):
    """Per-replica engine busy fraction: sum of engine_step slice
    durations over that replica's traced span of virtual time."""
    busy = defaultdict(float)
    lo, hi = {}, {}
    for ev in events:
        rep = ev.get("rep", 0)
        vt = ev.get("vt")
        if vt is not None:
            lo[rep] = min(lo.get(rep, vt), vt)
            hi[rep] = max(hi.get(rep, vt), vt)
        if ev.get("k") == "X" and ev.get("name") == "engine_step":
            busy[rep] += ev.get("dur", 0.0)
    return {rep: (busy[rep] / (hi[rep] - lo[rep])
                  if hi.get(rep, 0) > lo.get(rep, 0) else 0.0)
            for rep in sorted(set(lo) | set(busy))}


def _aggregate(events):
    """Shared stage aggregation: (n, aborted, totals, lifetimes) or None
    when the log holds no closed request spans."""
    request, stages = attribute(events)
    if not request:
        return None
    totals = defaultdict(list)       # stage -> per-request seconds
    lifetimes = []
    for rid, (b, e, _aborted) in sorted(request.items()):
        life = e - b
        lifetimes.append(life)
        named = 0.0
        for st in STAGES:
            s = stages[rid].get(st, 0.0)
            totals[st].append(s)
            named += s
        totals["decode"].append(max(0.0, life - named))
    aborted = sum(1 for _, (_, _, a) in request.items() if a)
    return len(lifetimes), aborted, totals, lifetimes


def _wall_virtual_ratio(events):
    wall = [ev["wt"] for ev in events if ev.get("wt") is not None]
    vts = [ev["vt"] for ev in events if ev.get("vt") is not None]
    if wall and vts and max(vts) > min(vts):
        return (max(wall) - min(wall)) / (max(vts) - min(vts))
    return None


def report_json(events):
    """Machine-readable stage-share attribution (``--json``): the same
    aggregation as the table, shaped so ``python -m repro.obs.regress``
    can diff two traced runs (``*_s`` leaves gate, ``share`` does not)."""
    agg = _aggregate(events)
    if agg is None:
        return None
    n, aborted, totals, lifetimes = agg
    grand = sum(lifetimes) or 1.0
    doc = {
        "schema_version": 1,
        "requests": n,
        "aborted": aborted,
        "events": len(events),
        "stages": {},
        "lifetime": {"mean_s": sum(lifetimes) / n,
                     "p50_s": _pct(lifetimes, 50),
                     "p95_s": _pct(lifetimes, 95)},
        "occupancy": {str(rep): frac
                      for rep, frac in occupancy(events).items()},
        "wall_virtual_ratio": _wall_virtual_ratio(events),
    }
    for st in STAGES + ("decode",):
        vals = totals[st]
        doc["stages"][st] = {"mean_s": sum(vals) / n,
                             "p50_s": _pct(vals, 50),
                             "p95_s": _pct(vals, 95),
                             "share": sum(vals) / grand}
    return doc


def report(events, out=sys.stdout):
    agg = _aggregate(events)
    if agg is None:
        print("no closed request spans in the event log", file=out)
        return 1
    n, aborted, totals, lifetimes = agg
    grand = sum(lifetimes) or 1.0
    wall = [ev["wt"] for ev in events]
    vts = [ev["vt"] for ev in events if ev.get("vt") is not None]
    print(f"trace_report: {n} request(s) ({aborted} aborted), "
          f"{len(events)} events", file=out)
    print(f"{'stage':>15} {'mean_s':>10} {'p50_s':>10} {'p95_s':>10} "
          f"{'share':>7}", file=out)
    for st in STAGES + ("decode",):
        vals = totals[st]
        share = sum(vals) / grand
        print(f"{st:>15} {sum(vals) / n:>10.6f} {_pct(vals, 50):>10.6f} "
              f"{_pct(vals, 95):>10.6f} {share:>6.1%}", file=out)
    print(f"{'lifetime':>15} {sum(lifetimes) / n:>10.6f} "
          f"{_pct(lifetimes, 50):>10.6f} {_pct(lifetimes, 95):>10.6f} "
          f"{'100.0%':>7}", file=out)
    for rep, frac in occupancy(events).items():
        print(f"replica {rep}: engine occupancy {frac:.1%}", file=out)
    if wall and vts and max(vts) > min(vts):
        ratio = (max(wall) - min(wall)) / (max(vts) - min(vts))
        print(f"wall/virtual clock ratio: {ratio:.1f}x "
              f"(wall {max(wall) - min(wall):.3f}s over virtual "
              f"{max(vts) - min(vts):.6f}s)", file=out)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("events", help="JSONL event log (--trace-events / "
                                   "Tracer.write_jsonl / JsonlSink)")
    ap.add_argument("--json", action="store_true",
                    help="emit the attribution as JSON (diffable with "
                         "python -m repro.obs.regress)")
    args = ap.parse_args(argv)
    events = load_events(args.events)
    if args.json:
        doc = report_json(events)
        if doc is None:
            print("no closed request spans in the event log",
                  file=sys.stderr)
            return 1
        print(json.dumps(doc, indent=1, sort_keys=True))
        return 0
    return report(events)


if __name__ == "__main__":
    raise SystemExit(main())
