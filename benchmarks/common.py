"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List

import jax

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_jit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of a jitted callable on the local device."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
