"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels import ops, ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,h,kvh,sq,sk,d", [
    (2, 4, 2, 64, 64, 32),
    (1, 8, 8, 48, 48, 16),     # MHA
    (2, 4, 1, 32, 96, 32),     # MQA, decode-block (sq < sk)
    (1, 6, 2, 64, 64, 64),     # non-pow2 heads (whisper-like grouping)
    (1, 2, 2, 100, 100, 32),   # non-multiple of block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(b, h, kvh, sq, sk, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, kvh, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, kvh, sk, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    expected = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 16])
def test_flash_masks(causal, window):
    if window and not causal:
        pytest.skip("window implies causal")
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_k=16)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5, rtol=2e-5)


def test_flash_kv_len_padding_mask():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 64, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 64, 16), jnp.float32)
    out = flash_attention(q, k, v, causal=False, kv_len=40,
                          block_q=16, block_k=16)
    expected = ref.flash_attention_ref(q, k, v, causal=False, kv_len=40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,h,kvh,d,page,pps,P", [
    (2, 4, 2, 32, 16, 4, 16),
    (3, 8, 1, 64, 8, 6, 32),    # MQA
    (1, 4, 4, 16, 32, 2, 8),    # MHA
    (4, 12, 2, 32, 16, 3, 24),  # qwen2-vl-like grouping
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_vs_ref(b, h, kvh, d, page, pps, P, dtype, rng):
    q = jnp.asarray(rng.randn(b, h, d), dtype)
    kp = jnp.asarray(rng.randn(P, page, kvh, d), dtype)
    vp = jnp.asarray(rng.randn(P, page, kvh, d), dtype)
    bt = jnp.asarray(rng.choice(P, size=(b, pps)), jnp.int32)
    sl = jnp.asarray(rng.randint(1, pps * page + 1, size=b), jnp.int32)
    out = paged_attention(q, kp, vp, bt, sl)
    expected = ref.paged_attention_ref(q, kp, vp, bt, sl)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_paged_single_token_seq(rng):
    """seq_len=1 edge: only the first slot of the first page is valid."""
    q = jnp.asarray(rng.randn(1, 2, 16), jnp.float32)
    kp = jnp.asarray(rng.randn(4, 8, 2, 16), jnp.float32)
    vp = jnp.asarray(rng.randn(4, 8, 2, 16), jnp.float32)
    bt = jnp.zeros((1, 2), jnp.int32)
    sl = jnp.ones((1,), jnp.int32)
    out = paged_attention(q, kp, vp, bt, sl)
    # attention over one key = that key's value
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(kp[0, 0] * 0
                                                              + vp[0, 0, :]),
                               atol=1e-5)


def test_ops_shape_checks():
    q = jnp.zeros((1, 4, 8, 16))
    k = jnp.zeros((1, 3, 8, 16))        # 4 % 3 != 0
    with pytest.raises(ValueError):
        ops.flash_attention(q, k, k)
    with pytest.raises(ValueError):
        ops.paged_attention(jnp.zeros((1, 4, 16)), jnp.zeros((2, 8, 3, 16)),
                            jnp.zeros((2, 8, 3, 16)),
                            jnp.zeros((1, 2), jnp.int32),
                            jnp.ones((1,), jnp.int32))


def test_flash_matches_model_attention():
    """The kernel agrees with the model's blockwise_sdpa substrate."""
    from repro.models.attention import blockwise_sdpa
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, kvh, g, s, d = 2, 2, 3, 32, 16
    q = jax.random.normal(ks[0], (b, s, kvh, g, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    pos = jnp.arange(s)
    o_model = blockwise_sdpa(q, k, v, q_pos=pos, k_pos=pos, causal=True)
    # kernel layout: [B, H, S, D]
    qk = jnp.moveaxis(q.reshape(b, s, kvh * g, d), 1, 2)
    kk = jnp.moveaxis(k, 1, 2)
    vv = jnp.moveaxis(v, 1, 2)
    o_kernel = flash_attention(qk, kk, vv, causal=True,
                               block_q=16, block_k=16)
    np.testing.assert_allclose(
        np.asarray(jnp.moveaxis(o_kernel, 2, 1).reshape(b, s, kvh * g, d)),
        np.asarray(o_model), atol=2e-5, rtol=2e-5)
