"""``repro.api.video`` -- facade surface for video-token scheduling.

The video-specific compression schedulers (temporal merge, LLaMA-VID,
DyCoke ratios, Dynamic-VLM budgeting, FrameFusion) and the streaming KV
eviction policy live in the internal layer; examples and user code
import them from here so ``repro.core`` stays private (L001).  The
generic per-request strategies remain ``repro.api.compressors``.
"""
from repro.core.kv_cache.selection import select_streaming
from repro.core.token_compression.video import (
    dycoke_ratio, dynamic_compress, frame_similarity, framefusion,
    llama_vid_compress, temporal_merge)

__all__ = [
    "select_streaming",
    "frame_similarity", "temporal_merge", "llama_vid_compress",
    "dycoke_ratio", "dynamic_compress", "framefusion",
]
