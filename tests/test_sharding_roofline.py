"""Sharding rules (divisibility over ALL full configs, no allocation) and
the roofline HLO-collective parser."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models.layers import tree_map_specs
from repro.models.registry import build
from repro.roofline.analysis import collective_bytes_from_hlo
from repro.sharding import abstract_mesh
from repro.sharding.specs import ShardingRules

SINGLE = abstract_mesh((16, 16), ("data", "model"))
MULTI = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _check_divisible(spec_tree, rules, pspec_fn):
    bad = []

    def one(path, s):
        pspec = pspec_fn(s)
        for i, axis in enumerate(pspec):
            if axis is None:
                continue
            axes = (axis,) if isinstance(axis, str) else axis
            parts = 1
            for a in axes:
                parts *= rules.mesh.shape[a]
            if s.shape[i] % parts:
                bad.append(("/".join(path), s.shape, pspec))
        return s
    tree_map_specs(one, spec_tree)
    return bad


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["1pod", "2pod"])
@pytest.mark.parametrize("arch", ARCHS)
def test_param_shardings_divide(arch, mesh):
    cfg = get_config(arch)
    rules = ShardingRules(mesh, fsdp=True)
    bad = _check_divisible(build(cfg).param_specs(), rules,
                           rules.param_pspec)
    assert not bad, bad[:5]


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_shardings_divide(arch):
    cfg = get_config(arch)
    rules = ShardingRules(SINGLE, fsdp=True)
    model = build(cfg)
    cache_len = min(32768, cfg.decoder_max_seq or 32768)
    tree = model.cache_specs(128, cache_len, windowed=False)
    bad = _check_divisible(tree, rules, rules.cache_pspec)
    assert not bad, bad[:5]


def test_big_archs_actually_shard_params():
    """123B+ archs MUST 2D-shard their big matrices (fits-in-HBM proof)."""
    for arch in ("mistral-large-123b", "nemotron-4-340b",
                 "deepseek-v3-671b"):
        cfg = get_config(arch)
        rules = ShardingRules(SINGLE, fsdp=True)
        n_2d = 0

        def one(path, s):
            nonlocal n_2d
            pspec = rules.param_pspec(s)
            used = {a for a in pspec if a is not None}
            if {"data", "model"} <= used:
                n_2d += 1
            return s
        tree_map_specs(one, build(cfg).param_specs())
        assert n_2d > 0, f"{arch}: no 2D-sharded params"


def test_moe_experts_shard_over_model():
    cfg = get_config("deepseek-v3-671b")
    rules = ShardingRules(SINGLE, fsdp=True)
    model = build(cfg)
    specs = model.param_specs()
    moe = specs["layers"]["moe"]
    for name in ("wi_gate", "wo"):
        pspec = rules.param_pspec(moe[name])
        # stacked layer dim first, expert dim second
        assert pspec[1] == "model", f"{name}: experts not model-sharded"


def test_pod_axis_shards_batch_only():
    rules = ShardingRules(MULTI, fsdp=True)
    cfg = get_config("granite-34b")

    def one(path, s):
        pspec = rules.param_pspec(s)
        flat = []
        for a in pspec:
            if isinstance(a, tuple):
                flat.extend(a)
            elif a:
                flat.append(a)
        assert "pod" not in flat, f"param {path} sharded over pod"
        return s
    tree_map_specs(one, build(cfg).param_specs())
    bsp = rules.batch_pspec(2, batch_size=256)
    assert bsp[0] == ("pod", "data")


# ---------------------------------------------------------------- parser --

HLO_SAMPLE = """
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[2048,256]{1,0} all-gather(f32[128,256]{1,0} %p0), dimensions={0}
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %ag2), to_apply=%add
  %rs = f32[8,256]{1,0} reduce-scatter(f32[128,256]{1,0} %ar), dimensions={0}
  %a2a = f32[128,256]{1,0} all-to-all(f32[128,256]{1,0} %rs), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(f32[128,256]{1,0} %a2a)
  %dot = f32[128,128]{1,0} dot(f32[128,256] %cp, f32[256,128] %w)
}
"""


def test_collective_parser_counts_and_bytes():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    c = out["counts"]
    assert c == {"all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
                 "all-to-all": 1, "collective-permute": 1}
    b = 128 * 256 * 4
    per = out["per_op_operand_bytes"]
    assert per["all-reduce"] == b
    assert per["reduce-scatter"] == b
    # weighted: AG counts output (2048x256), AR counts 2x operand
    expected = (2048 * 256 * 4) + 2 * b + b + b + b
    assert out["collective_bytes"] == expected


def test_parser_ignores_non_collectives():
    out = collective_bytes_from_hlo(
        "%x = f32[4]{0} add(f32[4] %a, f32[4] %b)\n"
        "%s = f32[4]{0} all-gather-fusion-lookalike(f32[4] %x)\n")
    assert out["collective_bytes"] == 0


def test_dryrun_results_exist_and_pass():
    """The recorded dry-run grids must show every pair compiling."""
    import json
    import os
    root = os.path.join(os.path.dirname(__file__), "..", "experiments")
    for tag, expected_chips in (("singlepod", 256), ("multipod", 512)):
        path = os.path.join(root, f"dryrun_{tag}.json")
        if not os.path.exists(path):
            pytest.skip("dry-run grid not yet recorded")
        with open(path) as f:
            results = json.load(f)
        assert len(results) == 40
        statuses = {k: v["status"] for k, v in results.items()}
        fails = [k for k, s in statuses.items() if s == "fail"]
        assert not fails, fails
        assert sum(1 for s in statuses.values() if s == "skipped") == 1
