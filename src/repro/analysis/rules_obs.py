"""O-rules: repro.obs trace-span pairing and emission placement.

O001  Span pairing: every ``tracer.span_begin(...)`` site must reach a
      matching ``span_end``/``span_abort`` site. In per-function scopes
      (``serving/server.py``) this is the R002 CFG walk -- no path
      begin -> function exit may avoid every close, including the
      CancelledError / admission-retraction paths. In module-pairing
      scopes (``core/serving/engine.py``, where submit opens the span
      that step/abort close) the module must contain a close site, and
      the R001 entries in ``RELEASE_COMPLETENESS`` pin the specific
      closes to their functions.
O002  No event emission inside a Pallas kernel body: tracer calls in a
      traced/vmapped kernel are Python side effects that fire once at
      trace time (or never, on cached executables) -- they measure
      nothing and poison the zero-overhead-when-off guarantee. Emit
      from the host wrapper around the ``pallas_call``.
O003  Profiler-site pairing: every ``profiler.site_begin(...)`` must
      reach a matching ``site_end`` on every CFG path of the SAME
      function (profiler sites measure a synchronous region, so unlike
      trace spans they never pair across function boundaries). A leaked
      begin corrupts the self/total attribution of every enclosing site.

Site matching understands the ``if <x>.enabled:`` guard idiom: the
guard's ``if`` header is the CFG site, so the infeasible
"enabled at begin, disabled at close" branch combination is not
reported (every real path crosses the guard header).
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.cfg import ENTRY, EXIT, build_cfg, function_defs
from repro.analysis.findings import Finding
from repro.analysis.registry import Rule, register
from repro.analysis.tables import (PROFILE_BEGIN_CALLS,
                                   PROFILE_CLOSE_CALLS, PROFILE_SCOPES,
                                   SPAN_BEGIN_CALLS, SPAN_CLOSE_CALLS,
                                   SPAN_SCOPES, TRACER_EMIT_CALLS,
                                   _own_nodes)


def _callee(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _subtree_calls(nodes: Iterable[ast.AST]) -> Iterable[str]:
    for root in nodes:
        for n in ast.walk(root):
            if isinstance(n, ast.Call):
                yield _callee(n)


def _is_enabled_guard(stmt: ast.stmt) -> bool:
    """``if <expr>.enabled:`` -- the tracer's zero-overhead gate."""
    return (isinstance(stmt, ast.If)
            and any(isinstance(n, ast.Attribute) and n.attr == "enabled"
                    for n in ast.walk(stmt.test)))


def _span_site(stmt: ast.stmt, names) -> bool:
    """``stmt`` emits one of ``names``: the call in its own expressions,
    or stmt is the ``if ...enabled:`` guard whose body holds the call
    (the guard header is the node every path crosses)."""
    if _is_enabled_guard(stmt):
        return any(c in names for c in _subtree_calls(stmt.body))
    return any(isinstance(n, ast.Call) and _callee(n) in names
               for n in _own_nodes(stmt))


def _pairing_findings(rule: Rule, tree: ast.AST, path: str, scopes,
                      begin_calls, close_calls, module_msg: str,
                      leak_msg: str) -> List[Finding]:
    """Shared begin/close pairing walk (O001 trace spans, O003 profiler
    sites): module-pairing scopes require at least one close site in the
    module; per-function scopes run the CFG walk -- no path from a begin
    site to the function exit may avoid every close site. Message
    templates take ``{fn}`` (function name) / ``{scope}`` (description)."""
    out: List[Finding] = []
    for scope in scopes:
        if not path.endswith(scope.path_suffix):
            continue
        if scope.module_pairing:
            stmts = [n for n in ast.walk(tree) if isinstance(n, ast.stmt)]
            begins = [s for s in stmts if _span_site(s, begin_calls)]
            if begins and not any(_span_site(s, close_calls)
                                  for s in stmts):
                out.append(rule.finding(
                    path, begins[0].lineno,
                    module_msg.format(scope=scope.description)))
            continue
        for fn in function_defs(tree):
            body = [n for n in ast.walk(fn)
                    if isinstance(n, ast.stmt) and n is not fn]
            begins = [s for s in body if _span_site(s, begin_calls)]
            if not begins:
                continue
            ok = {s for s in body if _span_site(s, close_calls)}
            graph = build_cfg(fn)
            for b in begins:
                if b not in graph.succ:
                    continue                # nested def: out of this walk
                reaches = graph.path_avoiding(ENTRY, b, ok)
                leaks = graph.path_avoiding(b, EXIT, ok - {b})
                if reaches and leaks:
                    out.append(rule.finding(
                        path, b.lineno, leak_msg.format(fn=fn.name)))
    return out


@register
class SpanPairingRule(Rule):
    rule_id = "O001"
    family = "O"
    severity = "error"
    description = ("a tracer span_begin site can reach a function exit "
                   "without a matching span_end/span_abort")

    def applies(self, path: str) -> bool:
        return any(path.endswith(s.path_suffix) for s in SPAN_SCOPES)

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        return _pairing_findings(
            self, tree, path, SPAN_SCOPES, SPAN_BEGIN_CALLS,
            SPAN_CLOSE_CALLS,
            "module opens trace spans but contains no span_end/"
            "span_abort site -- every span it begins is an orphan "
            "({scope})",
            "span opened here in `{fn}` can reach a function exit "
            "without span_end/span_abort -- orphan span on that path")


@register
class ProfileSitePairingRule(Rule):
    rule_id = "O003"
    family = "O"
    severity = "error"
    description = ("a profiler site_begin can reach a function exit "
                   "without a matching site_end")

    def applies(self, path: str) -> bool:
        return any(path.endswith(s.path_suffix) for s in PROFILE_SCOPES)

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        return _pairing_findings(
            self, tree, path, PROFILE_SCOPES, PROFILE_BEGIN_CALLS,
            PROFILE_CLOSE_CALLS,
            "module opens profiler sites but contains no site_end "
            "({scope})",
            "profiler site opened here in `{fn}` can reach a function "
            "exit without site_end -- the open frame corrupts self/"
            "total attribution for every later site")


def _mentions_tracer(expr: ast.expr) -> bool:
    return any((isinstance(n, ast.Name) and n.id == "tracer")
               or (isinstance(n, ast.Attribute) and n.attr == "tracer")
               for n in ast.walk(expr))


@register
class KernelEmissionRule(Rule):
    rule_id = "O002"
    family = "O"
    severity = "error"
    description = ("tracer event emission inside a Pallas kernel body "
                   "(fires at trace time, not per step)")

    def applies(self, path: str) -> bool:
        return "kernels/" in path or path.endswith("_kernel.py")

    def check(self, tree: ast.AST, src: str, path: str) -> List[Finding]:
        from repro.analysis.rules_kernels import _sites
        out: List[Finding] = []
        kernels = []
        for site in _sites(tree):
            kern = site.kernel_fn()
            if kern is not None and kern not in kernels:
                kernels.append(kern)
        for kern in kernels:
            for node in ast.walk(kern):
                if not isinstance(node, ast.Call):
                    continue
                name = _callee(node)
                # span_* names are distinctive; the generic names
                # (slice/counter/instant) only count on a tracer object,
                # so jax.lax.slice etc. never false-positive
                span_call = name in SPAN_BEGIN_CALLS + SPAN_CLOSE_CALLS
                tracer_call = (name in TRACER_EMIT_CALLS
                               and isinstance(node.func, ast.Attribute)
                               and _mentions_tracer(node.func.value))
                if span_call or tracer_call:
                    out.append(self.finding(
                        path, node.lineno,
                        f"kernel `{kern.name}` emits trace event "
                        f"`{name}` inside the kernel body; a traced "
                        "kernel runs this once at trace time (or never "
                        "from a cached executable) -- emit from the "
                        "host wrapper around the pallas_call"))
        return out
