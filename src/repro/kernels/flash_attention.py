"""Tiled causal GQA flash attention (Pallas TPU): the prefill hot-spot.

TPU adaptation of FlashAttention [survey dim 3c]: the CUDA version's
SRAM-resident tiling + warp specialization becomes BlockSpec VMEM tiling
over a 4D grid (batch, q-head, q-block, kv-block). The last grid dimension
is sequential on TPU ("arbitrary" semantics), so the online-softmax running
state (m, l, acc) lives in VMEM scratch carried across kv-blocks --
HBM<->VMEM movement is the implicit DMA pipeline pallas_call builds from the
BlockSpecs, replacing FA-3's explicit TMA/warp-specialization overlap.

Block shapes default to (128, 128): MXU-aligned (multiples of 128 in both
matmul dims) and small enough that q/k/v/acc tiles fit VMEM comfortably:
  bq*D + bk*D (k) + bk*D (v) + bq*bk (s) + bq*D (acc) floats
  = 128*128 * 5 * 4B = 320 KiB << 16 MiB VMEM for D=128.

GQA: the q-head grid axis maps to kv-head ``h // group`` in the k/v
index_map -- each kv tile is re-read by its group's q heads (XLA would
materialize the broadcast; here it is just an index computation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, kv_len: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0) \
        + q_offset
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    valid = k_pos < kv_len
    if causal:
        valid = valid & (k_pos <= q_pos)
        if window:
            valid = valid & (k_pos > q_pos - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                   # [bq, 1]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "kv_len", "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    kv_len: int | None = None, q_offset: int = 0,
                    interpret: bool = True) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, KVH, Sk, D]. Returns [B, H, Sq, D].

    Sq/Sk are padded to block multiples internally; ``kv_len`` marks valid
    keys (defaults to Sk). ``q_offset``: absolute position of q[...,0,:]
    for causal masking (chunked prefill / decode-block use).
    ``interpret=True`` executes the kernel body on CPU (this container);
    on a TPU runtime pass interpret=False.
    """
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    assert h % kvh == 0, "q heads must be a multiple of kv heads"
    group = h // kvh
    kv_len = sk if kv_len is None else kv_len
    if causal and q_offset == 0 and sq < kv_len:
        q_offset = kv_len - sq          # decode-block convention

    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k

    grid = (b, h, sq_p // block_q, sk_p // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (d ** 0.5), causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=kv_len, q_offset=q_offset)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),       # acc
            pltpu.VMEM((block_q, 1), jnp.float32),       # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),       # l (running sum)
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
