"""Paged decode attention (Pallas TPU): the decode hot-spot.

TPU adaptation of vLLM's PagedAttention [survey dim 2b-i]: CUDA gathers KV
per-token through the block table with scattered loads; the TPU has no
efficient MXU-adjacent gather, so the *pages become the grid dimension* and
the block table is a SCALAR-PREFETCH operand (PrefetchScalarGridSpec). The
index_map reads ``block_table[b, p]`` to pick the physical HBM page each
grid step, so the DMA engine -- not the compute core -- performs the gather,
prefetching page p+1 while page p is in the MXU. That is the TPU-idiomatic
equivalent of the CUDA kernel's shared-memory gather loop.

Grid: (batch, kv_head, pages_per_seq); the last axis is sequential, carrying
the online-softmax state (m, l, acc) for the G grouped q-heads in VMEM
scratch. One q token per request (autoregressive decode step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _paged_kernel(seq_lens_ref, block_table_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page_size: int):
    b = pl.program_id(0)
    p = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    g, d = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) / (d ** 0.5)       # [G, D]
    k = k_ref[0, :, 0].astype(jnp.float32)                  # [page, D]
    v = v_ref[0, :, 0].astype(jnp.float32)                  # [page, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, page]
    pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32,
                                                   (g, page_size), 1)
    valid = pos < seq_lens_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    pr = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(pr, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        pr, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(p == np_ - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                    interpret: bool = True) -> jax.Array:
    """q: [B, H, D]; k_pages/v_pages: [P, page, KVH, D];
    block_table: [B, pages_per_seq] int32; seq_lens: [B] int32.
    Returns [B, H, D].
    """
    b, h, d = q.shape
    p_total, page, kvh, _ = k_pages.shape
    pages_per_seq = block_table.shape[1]
    assert h % kvh == 0
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)

    grid = (b, kvh, pages_per_seq)
    kernel = functools.partial(_paged_kernel, page_size=page)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,            # seq_lens, block_table
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda bi, hi, pi, sl, bt: (bi, hi, 0, 0)),
                # the paged gather: physical page id from the block table
                pl.BlockSpec((1, page, 1, d),
                             lambda bi, hi, pi, sl, bt: (bt[bi, pi], 0, hi,
                                                         0)),
                pl.BlockSpec((1, page, 1, d),
                             lambda bi, hi, pi, sl, bt: (bt[bi, pi], 0, hi,
                                                         0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda bi, hi, pi, sl, bt: (bi, hi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret,
    )(seq_lens.astype(jnp.int32), block_table.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(b, h, d)
