"""KV cache library (dim 2a/2b): selection, budgets, merging, paging,
prefix tree, tiered storage -- invariants + hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.kv_cache.budget import (adaptive_budgets, cake_layer_scores,
                                        pyramid_budgets, uniform_budgets)
from repro.core.kv_cache.merging import chai_cluster, d2o_merge
from repro.core.kv_cache.paged import (BlockAllocator, OutOfBlocksError,
                                       PagedKVPool, SeqBlocks,
                                       fragmentation_waste)
from repro.core.kv_cache.prefix_cache import RadixPrefixCache
from repro.core.kv_cache.selection import SELECTORS, oracle_topk
from repro.core.kv_cache.tiered import TieredKVStore


def _kv(b=2, s=32, h=2, d=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return (jax.random.normal(ks[0], (b, s, h, d), jnp.float32),
            jax.random.normal(ks[1], (b, s, h, d), jnp.float32))


@pytest.mark.parametrize("name", sorted(SELECTORS))
def test_selector_invariants(name):
    b, s, h, d, budget = 2, 32, 2, 8, 10
    k, v = _kv(b, s, h, d)
    attn = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(2), (b, 4, s, s)), -1)
    k2, v2, pos = SELECTORS[name](k, v, budget=budget, attn=attn)
    assert k2.shape == (b, budget, h, d)
    assert v2.shape == (b, budget, h, d)
    assert pos.shape == (b, budget)
    p = np.asarray(pos)
    assert (np.diff(p, axis=1) > 0).all(), "positions must stay sorted"


def test_streaming_keeps_sinks_and_recent():
    k, v = _kv(1, 64)
    _, _, pos = SELECTORS["streaming"](k, v, budget=12, sinks=4)
    p = set(np.asarray(pos[0]).tolist())
    assert {0, 1, 2, 3} <= p, "attention sinks must survive"
    assert {56 + i for i in range(8)} <= p, "recent window must survive"


def test_h2o_recent_window_guarantee():
    k, v = _kv(1, 40)
    attn = jnp.ones((1, 2, 40, 40)) / 40
    _, _, pos = SELECTORS["h2o"](k, v, budget=10, attn=attn,
                                 recent_frac=0.5)
    p = set(np.asarray(pos[0]).tolist())
    assert {35, 36, 37, 38, 39} <= p


def test_snapkv_observation_window_retained():
    k, v = _kv(1, 48)
    attn = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(0), (1, 2, 48, 48)), -1)
    _, _, pos = SELECTORS["snapkv"](k, v, budget=20, attn=attn,
                                    obs_window=8)
    p = set(np.asarray(pos[0]).tolist())
    assert {40 + i for i in range(8)} <= p


def test_selector_vs_oracle_better_than_random():
    """Attention-based selectors should recall oracle-top-k tokens better
    than a random subset (the survey's core eviction claim)."""
    rng = np.random.RandomState(0)
    b, s, budget = 1, 64, 16
    k, v = _kv(b, s, seed=3)
    # synthetic attention with persistent heavy hitters
    hot = rng.choice(s, 8, replace=False)
    base = rng.rand(1, 2, s, s) * 0.05
    base[:, :, :, hot] += 1.0
    attn = jnp.asarray(base / base.sum(-1, keepdims=True), jnp.float32)
    oracle = set(np.asarray(oracle_topk(attn, budget)[0]).tolist())

    def recall(pos):
        return len(set(np.asarray(pos[0]).tolist()) & oracle) / len(oracle)

    _, _, pos_h2o = SELECTORS["h2o"](k, v, budget=budget, attn=attn)
    rand_recall = np.mean([
        len(set(rng.choice(s, budget, replace=False).tolist()) & oracle)
        / len(oracle) for _ in range(100)])
    assert recall(pos_h2o) > rand_recall + 0.2


@settings(max_examples=25, deadline=None)
@given(total=st.integers(64, 4096), layers=st.integers(1, 48),
       seed=st.integers(0, 99))
def test_budget_allocations_conserve_total(total, layers, seed):
    rng = np.random.RandomState(seed)
    for budgets in (pyramid_budgets(total, layers),
                    adaptive_budgets(total, list(rng.rand(layers)))):
        assert len(budgets) == layers
        assert sum(budgets) == total
        assert min(budgets) >= 1
    u = uniform_budgets(total, layers)
    assert len(set(u)) == 1          # equal shares (baseline)


def test_pyramid_budgets_decrease_with_depth():
    b = pyramid_budgets(1024, 16)
    assert b[0] > b[-1], "pyramid: shallow layers get more budget"
    assert all(x >= y for x, y in zip(b, b[1:]))


def test_cake_scores_and_adaptive():
    attns = [jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(i), (1, 2, 16, 16)), -1)
        for i in range(4)]
    scores = cake_layer_scores(attns)
    assert len(scores) == 4 and all(s >= 0 for s in scores)
    budgets = adaptive_budgets(256, scores)
    assert sum(budgets) == 256


def test_d2o_merge_blends_evicted():
    k, v = _kv(1, 16)
    keep_idx = jnp.asarray([[0, 2, 4, 6, 8, 10, 12, 14]], jnp.int32)
    k2, v2, info = d2o_merge(k, v, keep_idx, threshold=-1.0)
    assert k2.shape == (1, 8, 2, 8)
    # with threshold=-1 every evicted token merges somewhere -> kept keys
    # change vs plain gather
    plain = jnp.take_along_axis(k, keep_idx[..., None, None], 1)
    assert float(jnp.abs(k2 - plain).max()) > 0


def test_chai_head_clustering():
    attn = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(0), (1, 8, 12, 12)), -1)
    assign, info = chai_cluster(attn, num_clusters=3)
    assert assign.shape == (8,)
    assert set(np.asarray(assign).tolist()) <= {0, 1, 2}


# ---------------------------------------------------------------- paged --

def test_block_allocator_and_oom():
    alloc = BlockAllocator(num_blocks=8, block_size=4)
    blocks = [alloc.alloc() for _ in range(8)]
    assert len(set(blocks)) == 8
    with pytest.raises(OutOfBlocksError):
        alloc.alloc()
    alloc.free(blocks[0])
    assert alloc.alloc() == blocks[0]


def test_block_refcount_sharing():
    alloc = BlockAllocator(num_blocks=4, block_size=4)
    b0 = alloc.alloc()
    alloc.share(b0)
    alloc.free(b0)
    assert alloc.num_free == 3, "shared block must survive one free"
    alloc.free(b0)
    assert alloc.num_free == 4


def test_paged_pool_prefill_append_gather():
    L, bs = 2, 4
    alloc = BlockAllocator(num_blocks=16, block_size=bs)
    pool = PagedKVPool(num_layers=L, num_blocks=16, block_size=bs,
                       num_kv_heads=2, head_dim=8)
    rng = np.random.RandomState(0)
    s = 10
    seq = SeqBlocks(block_ids=[alloc.alloc() for _ in range(4)])
    pk = rng.randn(L, s, 2, 8).astype(np.float32)
    pv = rng.randn(L, s, 2, 8).astype(np.float32)
    pool.write_prefill(seq, jnp.asarray(pk), jnp.asarray(pv))
    assert seq.length == s
    kt = rng.randn(L, 2, 8).astype(np.float32)
    vt = rng.randn(L, 2, 8).astype(np.float32)
    pool.append_token(seq, jnp.asarray(kt), jnp.asarray(vt))
    k_all, v_all = pool.gather(seq, layer=1)
    assert k_all.shape == (11, 2, 8)
    np.testing.assert_allclose(np.asarray(k_all[:s]), pk[1], atol=1e-6)
    np.testing.assert_allclose(np.asarray(k_all[s]), kt[1], atol=1e-6)
    np.testing.assert_allclose(np.asarray(v_all[s]), vt[1], atol=1e-6)


def test_fragmentation_waste_metric():
    seqs = [SeqBlocks(block_ids=[0, 1], length=5),
            SeqBlocks(block_ids=[2], length=4)]
    w = fragmentation_waste(seqs, block_size=4)
    assert w["internal_slots_wasted"] == 3
    assert w["used_slots"] == 9
    assert 0 <= w["waste_frac"] < 0.5


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 30), min_size=1, max_size=20))
def test_paged_vs_contiguous_allocation(lengths):
    """PagedAttention's claim: block allocation wastes <= block_size-1 per
    seq vs reserve-max contiguous allocation."""
    bs = 4
    max_len = 32
    paged_tokens = sum(((l + bs - 1) // bs) * bs for l in lengths)
    contiguous = len(lengths) * max_len
    assert paged_tokens <= sum(lengths) + len(lengths) * (bs - 1)
    if all(l < max_len - bs for l in lengths):
        assert paged_tokens <= contiguous


# ---------------------------------------------------------------- radix --

def test_radix_prefix_match_insert():
    alloc = BlockAllocator(num_blocks=64, block_size=4)
    cache = RadixPrefixCache(alloc)
    sys_prompt = list(range(100, 116))          # 16 tokens = 4 blocks
    blocks = [alloc.alloc() for _ in range(4)]
    cache.insert(sys_prompt, blocks, block_size=4)
    got, matched, pinned = cache.match_prefix(sys_prompt + [1, 2, 3])
    assert matched == 16
    assert got == blocks
    cache.unpin(pinned)
    # diverging suffix shares the common prefix blocks
    got2, matched2, pinned2 = cache.match_prefix(sys_prompt[:8] + [7] * 8)
    assert matched2 == 8
    assert got2 == blocks[:2]
    cache.unpin(pinned2)


def test_radix_variant_namespacing():
    """Prefix entries are namespaced by compression variant: the same
    tokens inserted under two variants are two independent entries, and a
    lookup never crosses namespaces (a fastv-0.5 prefill must not serve a
    none lookup)."""
    alloc = BlockAllocator(num_blocks=64, block_size=4)
    cache = RadixPrefixCache(alloc)
    toks = list(range(100, 112))                 # 12 tokens = 3 blocks
    blocks_none = [alloc.alloc() for _ in range(3)]
    blocks_fastv = [alloc.alloc() for _ in range(3)]
    cache.insert(toks, blocks_none, block_size=4)
    cache.insert(toks, blocks_fastv, block_size=4, variant="fastv-0.5")
    # each variant resolves to ITS OWN blocks
    got, matched, pinned = cache.match_prefix(toks)
    assert matched == 12 and got == blocks_none
    cache.unpin(pinned)
    got, matched, pinned = cache.match_prefix(toks, variant="fastv-0.5")
    assert matched == 12 and got == blocks_fastv
    cache.unpin(pinned)
    # an unseen variant misses entirely
    _, matched, _ = cache.match_prefix(toks, variant="divprune-0.25")
    assert matched == 0
    # two entries exist (one radix path per variant); eviction can reap
    # BOTH namespaces once unpinned
    assert cache.stats()["cached_blocks"] == 6
    assert cache.evict(6) == 6


def test_radix_eviction_respects_refcount():
    alloc = BlockAllocator(num_blocks=8, block_size=4)
    cache = RadixPrefixCache(alloc)
    a = [alloc.alloc() for _ in range(2)]
    cache.insert(list(range(8)), a, block_size=4)
    _, _, pinned = cache.match_prefix(list(range(8)))
    released = cache.evict(10)
    assert released == 0, "pinned nodes must not be evicted"
    cache.unpin(pinned)
    assert cache.evict(10) > 0


# ---------------------------------------------------------------- tiered --

def test_tiered_store_offload_and_fetch():
    store = TieredKVStore(block_size=4, num_kv_heads=2, head_dim=8,
                          hbm_capacity_blocks=4)
    rng = np.random.RandomState(0)
    blocks = {}
    for i in range(10):                      # exceeds HBM -> LRU offload
        k = rng.randn(4, 2, 8).astype(np.float32)
        v = rng.randn(4, 2, 8).astype(np.float32)
        store.insert_block(i, k, v)
        blocks[i] = (k, v)
    res = store.residency()
    assert res["hbm_blocks"] <= 4
    assert res["host_blocks"] >= 6
    assert res["stats"]["offloads"] >= 6
    # fetch an offloaded block back: data intact, transfer metered
    top, ks, vs = store.fetch_topk(blocks[0][0].mean(0), k=3)
    assert store.residency()["stats"]["fetches"] >= 1
    assert ks.shape[0] == 3 * 4


@pytest.mark.parametrize("index", ["mean", "kmeans"])
def test_tiered_topk_retrieval(index):
    store = TieredKVStore(block_size=4, num_kv_heads=1, head_dim=8,
                          hbm_capacity_blocks=2, index=index)
    rng = np.random.RandomState(1)
    blocks = {i: (rng.randn(4, 1, 8).astype(np.float32),
                  rng.randn(4, 1, 8).astype(np.float32)) for i in range(8)}
    for i, (k, v) in blocks.items():
        store.insert_block(i, k, v)
    q = rng.randn(1, 8).astype(np.float32)
    top, ks, vs = store.fetch_topk(q, k=3)
    assert len(top) == 3
    # mean-index: the block whose centroid best matches q must be in top-3
    scores = {i: float(blocks[i][0].reshape(-1, 8).mean(0) @ q.reshape(-1))
              for i in blocks}
    best = max(scores, key=scores.get)
    if index == "mean":
        assert best in top


def test_prefetch_overlap_schedule():
    from repro.core.kv_cache.tiered import prefetch_schedule
    # fetch hides fully under compute
    s_ovl = prefetch_schedule(compute_us_per_step=100.0,
                              fetch_us_per_block=20.0, blocks_per_step=4,
                              steps=10, overlap=True)
    s_seq = prefetch_schedule(compute_us_per_step=100.0,
                              fetch_us_per_block=20.0, blocks_per_step=4,
                              steps=10, overlap=False)
    assert s_ovl["total_us"] < s_seq["total_us"]
    assert s_ovl["exposed_fetch_frac"] == 0.0
    # fetch slower than compute: partially exposed even with overlap
    s_bad = prefetch_schedule(compute_us_per_step=10.0,
                              fetch_us_per_block=40.0, blocks_per_step=2,
                              steps=10, overlap=True)
    assert s_bad["exposed_fetch_frac"] > 0.0
