"""int8 FFN weight quantization (serving efficiency, §Perf)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.models.layers import quantize_ffn_params


def test_quantize_roundtrip_small_error():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = quantize_ffn_params(params)
    mlp = params["layers"]["mlp"]
    qmlp = qparams["layers"]["mlp"]
    assert qmlp["wi_gate"].dtype == jnp.int8
    deq = (qmlp["wi_gate"].astype(jnp.float32)
           * qmlp["wi_gate_s"][:, None, :])
    rel = float(jnp.abs(deq - mlp["wi_gate"].astype(jnp.float32)).max()
                / jnp.abs(mlp["wi_gate"]).max())
    assert rel < 0.02, rel


def test_quantized_model_close_to_full():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qcfg = cfg.with_(weight_quant="int8_ffn")
    qmodel = build(qcfg)
    qparams = quantize_ffn_params(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    full, _ = jax.jit(model.forward)(params, {"tokens": tokens})
    quant, _ = jax.jit(qmodel.forward)(qparams, {"tokens": tokens})
    # int8 FFN: same argmax on nearly all positions, small logit drift
    agree = float(jnp.mean(jnp.argmax(full, -1) == jnp.argmax(quant, -1)))
    assert agree > 0.9, agree
    drift = float(jnp.abs(full - quant).mean() / jnp.abs(full).mean())
    assert drift < 0.05, drift


def test_quantized_specs_shapes():
    qcfg = get_config("phi4-mini-3.8b", smoke=True).with_(
        weight_quant="int8_ffn")
    model = build(qcfg)
    specs = model.param_specs()
    mlp = specs["layers"]["mlp"]
    assert mlp["wi_gate"].dtype == "int8"
    assert mlp["wi_gate_s"].shape == (qcfg.num_layers, qcfg.d_ff)
