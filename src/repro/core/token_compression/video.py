"""Video token compression (survey dim 1-2): spatiotemporal merging and
dynamic, multi-granular, task-aware compression.

Inputs are frame-patch embeddings [B, F, P, d] (F frames, P patches/frame)
from the stubbed frontend.

  * temporal_merge     -- Chat-UniVi/HoliTom-style: cluster temporally
                          adjacent similar frames, average their patches.
  * llama_vid_compress -- LLaMA-VID: 2 tokens per frame (context + content).
  * dycoke_ratio       -- DyCoke: per-window dynamic compression ratio from
                          frame-difference complexity.
  * dynamic_compress   -- dynamic pipeline: complexity-adaptive per-frame
                          patch budgets (Dynamic-VLM / FastVID flavor).
  * framefusion        -- similarity-then-importance prune+merge across the
                          flattened spatiotemporal token stream.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.token_compression.merging import prune_then_merge


def _frame_feats(video):
    """[B,F,P,d] -> normalized per-frame mean feature [B,F,d] (f32)."""
    f = video.astype(jnp.float32).mean(2)
    return f / (jnp.linalg.norm(f, axis=-1, keepdims=True) + 1e-6)


def frame_similarity(video) -> jax.Array:
    """Cosine similarity between consecutive frames: [B, F-1]."""
    f = _frame_feats(video)
    return jnp.einsum("bfd,bfd->bf", f[:, :-1], f[:, 1:])


def temporal_merge(video, num_segments: int) -> Tuple[jax.Array, Dict]:
    """Merge F frames into ``num_segments`` contiguous segments.

    Segment boundaries are placed at the ``num_segments-1`` LOWEST
    consecutive-frame similarities (scene changes), then patches are
    averaged within each segment -- the global-optimization view of
    HoliTom vs. fixed-stride pooling.

    Returns ([B, num_segments, P, d], info).
    """
    b, f, p, d = video.shape
    sim = frame_similarity(video)                           # [B,F-1]
    k = num_segments - 1
    _, cut_idx = jax.lax.top_k(-sim, k)                     # lowest sim
    # boundary mask: frame i starts a new segment if cut at i-1
    starts = jnp.zeros((b, f), jnp.int32).at[
        jnp.arange(b)[:, None], cut_idx + 1].set(1)
    starts = starts.at[:, 0].set(1)
    seg_id = jnp.cumsum(starts, axis=1) - 1                 # [B,F] in [0,S)

    seg_sum = jnp.zeros((b, num_segments, p, d), jnp.float32)
    seg_cnt = jnp.zeros((b, num_segments), jnp.float32)
    bidx = jnp.arange(b)[:, None]
    seg_sum = seg_sum.at[bidx, seg_id].add(video.astype(jnp.float32))
    seg_cnt = seg_cnt.at[bidx, seg_id].add(1.0)
    out = seg_sum / seg_cnt[..., None, None]
    return out.astype(video.dtype), {"segments": num_segments}


def llama_vid_compress(video, query=None) -> Tuple[jax.Array, Dict]:
    """LLaMA-VID: each frame -> [context token, content token].

    context token = query-conditioned attention pool over patches (mean
    pool without query); content token = plain mean pool. Output
    [B, F*2, d].
    """
    b, f, p, d = video.shape
    x = video.astype(jnp.float32)
    content = x.mean(2)                                     # [B,F,d]
    if query is not None:
        q = query.astype(jnp.float32).mean(1)               # [B,d]
        att = jax.nn.softmax(
            jnp.einsum("bd,bfpd->bfp", q, x) / (d ** 0.5), -1)
        context = jnp.einsum("bfp,bfpd->bfd", att, x)
    else:
        context = content
    out = jnp.stack([context, content], 2).reshape(b, f * 2, d)
    return out.astype(video.dtype), {"tokens_per_frame": 2}


def dycoke_ratio(video, *, min_ratio=0.1, max_ratio=1.0) -> jax.Array:
    """DyCoke: dynamic per-frame keep ratio from temporal complexity.

    Static scenes (high consecutive similarity) compress hard; motion
    keeps more. Returns keep ratio per frame [B, F] in [min, max].
    """
    sim = frame_similarity(video)                           # [B,F-1]
    complexity = 1.0 - sim
    complexity = jnp.concatenate(
        [complexity[:, :1], complexity], 1)                 # [B,F]
    # ABSOLUTE complexity (clipped), not per-video max-normalized: a fully
    # static video must compress hard everywhere, not keep its "most
    # complex" frame at max_ratio (bug caught by examples/stream_video.py)
    c = jnp.clip(complexity, 0.0, 1.0)
    return min_ratio + (max_ratio - min_ratio) * c


def dynamic_compress(video, token_budget: int) -> Tuple[jax.Array, Dict]:
    """Complexity-adaptive compression to a fixed total ``token_budget``.

    Per-frame budgets proportional to DyCoke complexity; within each frame
    the top-|budget_f| patches by distance-from-frame-mean are kept (static
    background drops first). Fixed output shape [B, token_budget, d]
    (XLA-friendly): frames are ranked patch-wise, then a global top-k over
    weighted saliency picks exactly ``token_budget`` tokens.
    """
    b, f, p, d = video.shape
    x = video.astype(jnp.float32)
    ratios = dycoke_ratio(video)                            # [B,F]
    mean = x.mean(2, keepdims=True)
    sal = jnp.linalg.norm(x - mean, axis=-1)                # [B,F,P]
    sal = sal / (sal.max(-1, keepdims=True) + 1e-6)
    weighted = (sal * ratios[..., None]).reshape(b, f * p)
    _, idx = jax.lax.top_k(weighted, token_budget)
    idx = jnp.sort(idx, -1)
    flat = x.reshape(b, f * p, d)
    out = jnp.take_along_axis(flat, idx[..., None], 1)
    return out.astype(video.dtype), {"budget": token_budget,
                                     "ratios_mean": ratios.mean()}


def framefusion(video, keep: int) -> Tuple[jax.Array, Dict]:
    """FrameFusion: merge near-duplicate spatiotemporal tokens, prune the
    unimportant remainder, down to ``keep`` tokens."""
    b, f, p, d = video.shape
    flat = video.reshape(b, f * p, d)
    x = flat.astype(jnp.float32)
    mean = x.mean(1, keepdims=True)
    importance = jnp.linalg.norm(x - mean, axis=-1)         # distance = info
    merged, idx, info = prune_then_merge(flat, keep, scores=importance)
    return merged, {"keep": keep, **info}
