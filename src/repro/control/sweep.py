"""EffiVLM-BENCH-style Pareto sweep harness (the OFFLINE half of
``repro.control``).

Grid-runs the facade over (compression preset x decoder strategy x
replica mix x Poisson arrival rate), reusing ``LVLM.serve_cluster`` and
the same open-loop machinery ``benchmarks/bench_serving.py`` drives: one
real smoke-model fleet per grid point, per-request visual embeds,
Poisson arrivals on the virtual clock (fully deterministic, so CI's
bench job can re-measure and gate the committed frontier with
``python -m repro.obs.regress``).

Each point records a QUALITY PROXY next to its latency/SLO metrics:

    quality_proxy = retained_visual_ratio * acceptance

where ``retained_visual_ratio`` is the exact fraction of visual tokens
the preset keeps (``CompressionStrategy.compressed_token_count`` -- the
same accounting admission uses) and ``acceptance`` is the speculative
acceptance rate (1.0 for non-speculative decoders). That is the
training-free stand-in EffiVLM-BENCH motivates: dropped visual evidence
and rejected drafts are the two places these methods can cost quality.

The non-dominated frontier is computed in plain code over
(quality_proxy UP, slo_goodput UP, ttft_p95_s DOWN, tpot_p95_s DOWN)
and committed as schema-v1 ``BENCH_pareto.json``; the online
``AdaptivePolicy`` ladder is readable against it (each rung names a
preset the sweep has priced).

CLI::

    PYTHONPATH=src python -m repro.control.sweep --out BENCH_pareto.json
    PYTHONPATH=src python -m repro.control.sweep \\
        --presets none,fastv-0.5,fastv-0.25 --decoders greedy,speculative \\
        --mixes 2x --rates 800,4000 --requests 10
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def _api():
    # lazy: repro.api re-exports repro.control, so a module-level import
    # here would be circular
    import repro.api as api
    return api

#: (metric leaf, sign): +1 = higher is better. A point is dominated iff
#: some other point is no worse on EVERY axis and strictly better on one.
FRONTIER_AXES: Tuple[Tuple[str, float], ...] = (
    ("quality_proxy", 1.0),
    ("slo_goodput", 1.0),
    ("ttft_p95_s", -1.0),
    ("tpot_p95_s", -1.0),
)

#: replica-mix name -> serve_cluster spec (int replica count or role list)
REPLICA_MIXES: Dict[str, object] = {
    "1x": 1,
    "2x": 2,
    "disagg": [{"role": "prefill"}, {"role": "decode"}],
}


@dataclasses.dataclass
class SweepConfig:
    """The grid. Defaults give 3 x 2 x 1 x 2 = 12 points (the committed
    baseline; acceptance floor is >= 8)."""
    presets: Sequence[str] = ("none", "fastv-0.5", "fastv-0.25")
    decoders: Sequence[str] = ("greedy", "speculative")
    mixes: Sequence[str] = ("2x",)
    rates: Sequence[float] = (800.0, 4000.0)
    n_requests: int = 10
    max_new_tokens: int = 6
    seed: int = 40
    model: str = "qwen2-vl-2b"
    # tight virtual-clock SLO so attainment actually separates the grid
    # (the facade default of 500ms/50ms is trivially met on the cost
    # model's clock)
    ttft_slo_ms: float = 20.0
    tpot_slo_ms: float = 2.0


def point_key(pt: Dict) -> str:
    return (f"{pt['compression']}|{pt['decoder']}|{pt['mix']}"
            f"|r{pt['rate_rps']:g}")


def _point_requests(vlm, cfg: SweepConfig, preset: str,
                    decoder: str, rate: float, salt: int) -> List:
    api = _api()
    rng = np.random.RandomState(cfg.seed + salt)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=cfg.n_requests))
    reqs = []
    for i in range(cfg.n_requests):
        toks = list(rng.randint(1, vlm.cfg.vocab_size,
                                size=rng.randint(6, 16)))
        r = api.Request(rid=i, tokens=toks,
                        max_new_tokens=cfg.max_new_tokens,
                        arrival=float(arrivals[i]),
                        slo=api.SLO(ttft_ms=cfg.ttft_slo_ms,
                                    tpot_ms=cfg.tpot_slo_ms))
        r.visual_embeds = rng.randn(
            vlm.cfg.num_visual_tokens, vlm.cfg.d_model
        ).astype(np.float32) * 0.02
        r.compression = preset
        r.decoder = decoder
        reqs.append(r)
    return reqs


def run_point(vlm, cfg: SweepConfig, preset: str, decoder: str,
              mix: str, rate: float, salt: int = 0) -> Dict:
    """One grid point: a fresh fleet, an open-loop Poisson run, the
    quality proxy + tail-latency/SLO record."""
    api = _api()
    reqs = _point_requests(vlm, cfg, preset, decoder, rate, salt)
    router = vlm.serve_cluster(
        REPLICA_MIXES[mix],
        api.EngineConfig(max_batch=4, cache_len=256, temperature=0.0),
        gen=api.GenerationConfig(decoder="greedy", temperature=0.0,
                                 max_new_tokens=cfg.max_new_tokens,
                                 gamma=3),
        routing="least_kv",
        admission=api.AdmissionConfig(high_watermark=0.9,
                                      low_watermark=0.7))

    async def drive():
        async def consume(r):
            return [t async for t in router.submit(r)]
        async with router:
            await asyncio.gather(*(consume(r) for r in reqs))
        return router.summary()

    out = asyncio.run(drive())
    nv = vlm.cfg.num_visual_tokens
    retained = (api.make_compressor(preset).compressed_token_count(nv)
                / float(nv)) if nv else 1.0
    acceptance = 1.0
    if decoder == "speculative":
        # fleet acceptance = accepted/proposed pooled over every replica
        # that ran the speculative strategy (the cluster summary carries
        # only latency aggregates, so read the decoders directly)
        proposed = accepted = 0
        for rep in router.replicas:
            stats = rep.server.engine.decoder_stats()
            proposed += stats.get("speculative/proposed",
                                  stats.get("proposed", 0))
            accepted += stats.get("speculative/accepted",
                                  stats.get("accepted", 0))
        if proposed:
            acceptance = accepted / float(proposed)
    pt = {
        "compression": preset,
        "decoder": decoder,
        "mix": mix,
        "rate_rps": float(rate),
        "replicas": out["replicas"],
        "quality_proxy": retained * acceptance,
        "retained_visual_ratio": retained,
        "acceptance": acceptance,
        "ttft_p50_s": out.get("ttft_p50"),
        "ttft_p95_s": out.get("ttft_p95"),
        "tpot_p95_s": out.get("tpot_p95"),
        "slo_ttft_attainment": out.get("slo_ttft_attainment"),
        "slo_tpot_attainment": out.get("slo_tpot_attainment"),
        "slo_goodput": out.get("slo_goodput"),
        "throughput_tok_per_s": out.get("fleet_throughput_tok_per_s"),
        "finished": out["finished"],
        "deferred": out["deferred"],
        "virtual_time_s": out["virtual_time_s"],
    }
    return pt


# ------------------------------------------------------------- frontier --
def dominates(a: Dict, b: Dict,
              axes: Tuple[Tuple[str, float], ...] = FRONTIER_AXES) -> bool:
    """True iff ``a`` is no worse than ``b`` on every axis and strictly
    better on at least one (missing metrics count as worst)."""
    strictly = False
    for key, sign in axes:
        av = sign * float(a.get(key) if a.get(key) is not None
                          else -1e30 * sign)
        bv = sign * float(b.get(key) if b.get(key) is not None
                          else -1e30 * sign)
        if av < bv:
            return False
        if av > bv:
            strictly = True
    return strictly


def pareto_frontier(points: List[Dict]) -> List[Dict]:
    """The non-dominated subset, in input order. O(n^2) on purpose --
    the grid is tens of points and plain code beats a dependency."""
    return [p for p in points
            if not any(dominates(q, p) for q in points if q is not p)]


# ----------------------------------------------------------------- sweep --
def run_sweep(cfg: Optional[SweepConfig] = None,
              progress=None) -> Dict:
    """Run the full grid and return the schema-v1 pareto document."""
    cfg = cfg if cfg is not None else SweepConfig()
    vlm = _api().LVLM.from_pretrained(cfg.model, smoke=True)
    points: List[Dict] = []
    salt = 0
    for preset in cfg.presets:
        for decoder in cfg.decoders:
            for mix in cfg.mixes:
                for rate in cfg.rates:
                    salt += 1
                    pt = run_point(vlm, cfg, preset, decoder, mix, rate,
                                   salt=salt)
                    points.append(pt)
                    if progress is not None:
                        progress(pt)
    frontier = pareto_frontier(points)
    frontier_keys = {point_key(p) for p in frontier}
    for p in points:
        p["on_frontier"] = point_key(p) in frontier_keys
    return {
        "schema_version": 1,
        "kind": "pareto_sweep",
        "model": cfg.model,
        "axes": [list(ax) for ax in FRONTIER_AXES],
        "slo": {"ttft_ms": cfg.ttft_slo_ms, "tpot_ms": cfg.tpot_slo_ms},
        "points": points,
        "frontier": sorted(frontier_keys),
    }


def write_pareto(doc: Dict, path: str) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1, default=float)
        f.write("\n")


def main(argv: Optional[Sequence[str]] = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_pareto.json", metavar="PATH",
                    help="where to write the schema-v1 pareto document")
    ap.add_argument("--presets", default=None,
                    help="comma-separated compression presets")
    ap.add_argument("--decoders", default=None,
                    help="comma-separated decoder strategies")
    ap.add_argument("--mixes", default=None,
                    help=f"comma-separated replica mixes "
                         f"({','.join(REPLICA_MIXES)})")
    ap.add_argument("--rates", default=None,
                    help="comma-separated Poisson arrival rates (req/s)")
    ap.add_argument("--requests", type=int, default=None,
                    help="open-loop requests per grid point")
    ap.add_argument("--model", default=None, help="smoke model name")
    args = ap.parse_args(argv)
    cfg = SweepConfig()
    if args.presets:
        cfg.presets = tuple(p for p in args.presets.split(",") if p)
    if args.decoders:
        cfg.decoders = tuple(d for d in args.decoders.split(",") if d)
    if args.mixes:
        cfg.mixes = tuple(m for m in args.mixes.split(",") if m)
        for m in cfg.mixes:
            if m not in REPLICA_MIXES:
                ap.error(f"unknown mix {m!r} (have "
                         f"{','.join(REPLICA_MIXES)})")
    if args.rates:
        cfg.rates = tuple(float(r) for r in args.rates.split(",") if r)
    if args.requests:
        cfg.n_requests = args.requests
    if args.model:
        cfg.model = args.model

    def progress(pt):
        print(f"# pareto_point {json.dumps(pt, default=float)}",
              flush=True)

    doc = run_sweep(cfg, progress=progress)
    write_pareto(doc, args.out)
    n_front = sum(1 for p in doc["points"] if p["on_frontier"])
    print(f"# pareto written to {args.out}: {len(doc['points'])} points, "
          f"{n_front} on frontier", flush=True)


if __name__ == "__main__":
    main()
