"""Public jit'd kernel entry points with shape checks + backend dispatch.

On a TPU runtime the Pallas kernels compile natively (interpret=False); on
this CPU container they run in interpret mode, and callers that want XLA-
compiled speed on CPU can force the pure-jnp reference (``impl='ref'``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import paged_attention as _paged


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    kv_len: int | None = None, impl: str = "auto"):
    """GQA flash attention. q [B,H,Sq,D]; k,v [B,KVH,Sk,D] -> [B,H,Sq,D]."""
    if q.ndim != 4 or k.ndim != 4 or v.ndim != 4:
        raise ValueError("flash_attention expects rank-4 q/k/v")
    if k.shape != v.shape:
        raise ValueError(f"k/v shape mismatch: {k.shape} vs {v.shape}")
    if q.shape[0] != k.shape[0] or q.shape[3] != k.shape[3]:
        raise ValueError(f"q/k incompatible: {q.shape} vs {k.shape}")
    if q.shape[1] % k.shape[1]:
        raise ValueError("H must be a multiple of KVH")
    if impl == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal,
                                        kv_len=kv_len, window=window)
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, kv_len=kv_len, interpret=not _on_tpu())


def paged_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                    impl: str = "auto"):
    """Paged decode attention. q [B,H,D] -> [B,H,D]."""
    if q.ndim != 3 or k_pages.ndim != 4:
        raise ValueError("paged_attention expects q rank-3, pages rank-4")
    if k_pages.shape != v_pages.shape:
        raise ValueError("k_pages/v_pages shape mismatch")
    if block_table.ndim != 2 or block_table.shape[0] != q.shape[0]:
        raise ValueError("block_table must be [B, pages_per_seq]")
    if q.shape[1] % k_pages.shape[2]:
        raise ValueError("H must be a multiple of KVH")
    if impl == "ref":
        return _ref.paged_attention_ref(q, k_pages, v_pages, block_table,
                                        seq_lens)
    return _paged(q, k_pages, v_pages, block_table, seq_lens,
                  interpret=not _on_tpu())
