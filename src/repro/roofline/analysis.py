"""Three-term roofline from a compiled (dry-run) artifact.

    compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
    memory term     = HLO_bytes / HBM_bw                 (per chip)
    collective term = collective_bytes / ICI link bw     (per chip)

FLOPs/bytes come from ``compiled.cost_analysis()`` on the SPMD-partitioned
module (already per-device). collective_bytes is NOT in cost_analysis: we
parse ``compiled.as_text()`` (post-partitioner HLO, real collectives with
per-device shapes) and sum operand sizes per collective op, weighted by the
ring-algorithm transfer factor:

    all-gather          : output bytes       (each chip receives the gather)
    reduce-scatter      : operand bytes
    all-reduce          : 2 x operand        (ring = RS + AG)
    all-to-all          : operand bytes
    collective-permute  : operand bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.roofline.hw import HW, TPU_V5E

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# "%all-reduce.17 = f32[...] all-reduce(" -> opcode after " = type "
_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = _DTYPE_BYTES[dt]
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-op-type byte totals + the weighted per-chip transfer estimate."""
    out = {op: 0.0 for op in _COLLECTIVES}
    counts = {op: 0 for op in _COLLECTIVES}
    weighted = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        if "-done(" in line:        # async pair: count the -start only
            continue
        shapes = list(_SHAPE_RE.finditer(line))
        if not shapes:
            continue
        split = m.start(1)          # opcode position: before = output types
        out_shapes = [s for s in shapes if s.start() < split]
        operand_shapes = [s for s in shapes if s.start() >= split]
        out_b = sum(_shape_bytes(s) for s in out_shapes)
        opr_b = sum(_shape_bytes(s) for s in operand_shapes)
        counts[op] += 1
        out[op] += opr_b
        if op == "all-gather":
            weighted += out_b
        elif op == "all-reduce":
            weighted += 2 * opr_b
        else:
            weighted += opr_b
    return {"per_op_operand_bytes": out, "counts": counts,
            "collective_bytes": weighted}


@dataclasses.dataclass
class RooflineReport:
    name: str
    flops: float                    # per device
    bytes_accessed: float           # per device
    collective_bytes: float         # per device (weighted)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0        # 6ND / 2ND useful-work estimate
    useful_frac: float = 0.0        # model_flops / (flops * chips)
    collective_counts: Optional[Dict[str, int]] = None
    peak_memory_bytes: Optional[float] = None

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_from_compiled(name: str, compiled, *, chips: int,
                           model_flops: float = 0.0,
                           analytic_bytes: float = 0.0,
                           hw: HW = TPU_V5E) -> RooflineReport:
    """Three-term roofline.

    flops + collective bytes come from the trip-count-aware HLO walk
    (hlo_cost.py) -- ``cost_analysis()`` counts scan bodies once and
    under-reports 61--96-layer models by ~2 orders of magnitude. The
    memory term uses max(cost_analysis bytes, analytic steady-state
    traffic / chips): fusion-level traffic is not recoverable from HLO
    text, and the analytic term (weights + cache + optimizer) is the
    dependable lower bound at scale.
    """
    from repro.roofline.hlo_cost import walk_costs
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax returns [dict]
        cost = cost[0]
    walk = walk_costs(compiled.as_text())
    flops = float(walk["flops"])
    byts = max(float(cost.get("bytes accessed", 0.0)),
               analytic_bytes / max(chips, 1))
    compute_s = flops / hw.peak_flops_bf16
    memory_s = byts / hw.hbm_bw
    collective_s = float(walk["collective_bytes"]) / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(ma.temp_size_in_bytes + ma.argument_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    except Exception:
        pass
    return RooflineReport(
        name=name, flops=flops, bytes_accessed=byts,
        collective_bytes=float(walk["collective_bytes"]),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_frac=(model_flops / (flops * chips)) if flops else 0.0,
        collective_counts=walk["collective_counts"],
        peak_memory_bytes=peak)


def model_flops_estimate(cfg, shape_cfg) -> float:
    """6·N·D (train) or 2·N·D (inference), N = active params."""
    n = cfg.active_param_count()
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n * tokens
    # decode: one token per request
    return 2.0 * n * shape_cfg.global_batch
