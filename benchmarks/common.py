"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Sequence

import jax

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


class Timing(float):
    """Per-call wall time in microseconds. The float VALUE is the
    minimum over the measured iterations (the least-noise statistic, so
    existing ``emit(name, us)`` call sites keep working); ``min_us`` /
    ``mean_us`` / ``std_us`` carry the full spread for BENCH_*.json
    rows."""

    min_us: float
    mean_us: float
    std_us: float

    def __new__(cls, samples_us: Sequence[float]) -> "Timing":
        mn = min(samples_us)
        mean = sum(samples_us) / len(samples_us)
        t = super().__new__(cls, mn)
        t.min_us = mn
        t.mean_us = mean
        t.std_us = (sum((x - mean) ** 2 for x in samples_us)
                    / len(samples_us)) ** 0.5
        return t

    def stats(self) -> Dict[str, float]:
        return {"min": self.min_us, "mean": self.mean_us,
                "std": self.std_us}


def time_jit(fn: Callable, *args, warmup: int = 2,
             iters: int = 5) -> Timing:
    """Wall-time stats (us) of a jitted callable on the local device.

    Every warmup call is synchronized with ``block_until_ready`` so
    compilation and first-dispatch cost can never leak into the measured
    iterations (an async-dispatch backend would otherwise overlap
    unfinished warmup work with the first timed call)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return Timing(samples)
