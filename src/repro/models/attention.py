"""Attention: GQA/MQA full + sliding-window + cache decode, MLA (DeepSeek).

Three execution paths per layer:
  * ``full_attention``     -- training / prefill, blockwise (flash-style)
                              online-softmax over KV blocks; causal or
                              bidirectional; optional sliding window.
  * ``prefill_into_cache`` -- prefill that also materializes the KV cache.
  * ``decode_attention``   -- one token vs a cache (full or ring-buffer
                              window). Dense serve_step uses this; the paged
                              engine uses kernels/paged_attention instead.

GQA is computed grouped (q reshaped [B,S,K,G,D]) so KV heads are never
materialized repeated -- this matters for both HLO bytes and the roofline.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, spec, apply_rope

NEG_INF = -1e30


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------

def attn_specs(cfg) -> Dict[str, ParamSpec]:
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.use_mla:
        qk_hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        out = {
            "wkv_a": spec((d, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
                          ("embed", None)),
            "kv_norm": spec((cfg.kv_lora_rank,), (None,), init="ones"),
            "wk_b": spec((cfg.kv_lora_rank, h, cfg.qk_nope_head_dim),
                         (None, "heads", None)),
            "wv_b": spec((cfg.kv_lora_rank, h, cfg.v_head_dim),
                         (None, "heads", None)),
            "wo": spec((h, cfg.v_head_dim, d), ("heads", None, "embed")),
        }
        if cfg.q_lora_rank:
            out["wq_a"] = spec((d, cfg.q_lora_rank), ("embed", None))
            out["q_norm"] = spec((cfg.q_lora_rank,), (None,), init="ones")
            out["wq_b"] = spec((cfg.q_lora_rank, h, qk_hd),
                               (None, "heads", None))
        else:
            out["wq"] = spec((d, h, qk_hd), ("embed", "heads", None))
        return out
    return {
        "wq": spec((d, h, hd), ("embed", "heads", None)),
        "wk": spec((d, k, hd), ("embed", "kv_heads", None)),
        "wv": spec((d, k, hd), ("embed", "kv_heads", None)),
        "wo": spec((h, hd, d), ("heads", None, "embed")),
    }


def cross_attn_specs(cfg) -> Dict[str, ParamSpec]:
    return attn_specs(cfg)


# --------------------------------------------------------------------------
# core grouped SDPA, blockwise over KV (flash-style online softmax)
# --------------------------------------------------------------------------

def _grouped(q, num_kv: int):
    """[B,S,H,D] -> [B,S,K,G,D]."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def blockwise_sdpa(q, k, v, *, q_pos, k_pos, causal: bool,
                   window: int = 0, block_k: int = 1024,
                   bias: Optional[jax.Array] = None):
    """Grouped-query flash-style attention in pure jnp.

    q: [B,Sq,K,G,D]; k,v: [B,Sk,K,D]; q_pos [Sq], k_pos [Sk] absolute
    positions (int32) used for causal/window masking (k_pos < 0 = invalid
    slot). Online softmax over KV blocks keeps peak memory at
    O(Sq * block_k) instead of O(Sq * Sk).
    """
    b, sq, kh, g, d = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale

    nblocks = max(1, (sk + block_k - 1) // block_k)
    pad = nblocks * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)
    kb = k.reshape(b, nblocks, block_k, kh, d)
    vb = v.reshape(b, nblocks, block_k, kh, dv)
    kpb = k_pos.reshape(nblocks, block_k)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, kp = blk
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kblk.astype(jnp.float32))
        valid = kp[None, :] >= 0
        if causal:
            valid = valid & (kp[None, :] <= q_pos[:, None])
        if window:
            valid = valid & (kp[None, :] > q_pos[:, None] - window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        if bias is not None:
            s = s + bias
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kh, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kpb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # [B,K,G,Sq,Dv] -> [B,Sq,K*G,Dv]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, kh * g, dv)
    return out.astype(q.dtype)


def simple_sdpa(q, k, v, *, q_pos, k_pos, causal: bool, window: int = 0):
    """One-shot grouped SDPA (decode / tiny seqs): q [B,Sq,K,G,D].

    q_pos [B,Sq] or [Sq]; k_pos [B,Sk] or [Sk] (per-request ragged decode
    positions supported -- continuous batching needs them).
    """
    b, sq, kh, g, d = q.shape
    dv = v.shape[-1]
    sk = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    q_pos = jnp.broadcast_to(jnp.atleast_1d(q_pos), (b, sq)) \
        if q_pos.ndim <= 1 else q_pos
    k_pos = jnp.broadcast_to(jnp.atleast_1d(k_pos), (b, sk)) \
        if k_pos.ndim <= 1 else k_pos
    s = jnp.einsum("bqkgd,bckd->bkgqc", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    valid = k_pos[:, None, :] >= 0                              # [B,Sq,Sk]
    if causal:
        valid = valid & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window:
        valid = valid & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bkgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(out, 3, 1).reshape(b, sq, kh * g, dv).astype(q.dtype)


# --------------------------------------------------------------------------
# standard GQA layer
# --------------------------------------------------------------------------

def qkv_proj(p, x):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dke->bske", x, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dke->bske", x, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return q, k, v


def out_proj(p, o):
    return jnp.einsum("bshe,hed->bsd", o, p["wo"],
                      preferred_element_type=jnp.float32).astype(o.dtype)


def full_attention(p, x, cos, sin, cfg, *, causal=True, window=0,
                   positions=None, block_k=1024):
    """Training/prefill attention (no cache returned)."""
    b, s, _ = x.shape
    q, k, v = qkv_proj(p, x)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    pos = positions if positions is not None else jnp.arange(s, dtype=jnp.int32)
    qg = _grouped(q, cfg.num_kv_heads)
    o = blockwise_sdpa(qg, k, v, q_pos=pos, k_pos=pos, causal=causal,
                       window=window, block_k=block_k)
    return out_proj(p, o)


# ---------------------------- KV cache ------------------------------------

def kv_cache_specs(cfg, batch: int, cache_len: int, windowed: bool):
    """ParamSpec tree for one layer's cache (shape + logical axes)."""
    k = cfg.num_kv_heads
    hd = cfg.head_dim
    length = min(cache_len, cfg.sliding_window) if windowed else cache_len
    if cfg.use_mla:
        tree = {
            "ckv": spec((batch, length, cfg.kv_lora_rank),
                        ("batch", "cache_seq", None), init="zeros"),
            "k_rope": spec((batch, length, cfg.qk_rope_head_dim),
                           ("batch", "cache_seq", None), init="zeros"),
        }
    else:
        tree = {
            "k": spec((batch, length, k, hd),
                      ("batch", "cache_seq", "kv_heads", None), init="zeros"),
            "v": spec((batch, length, k, hd),
                      ("batch", "cache_seq", "kv_heads", None), init="zeros"),
        }
    if windowed:
        tree["slot_pos"] = spec((batch, length), ("batch", "cache_seq"),
                                init="zeros", dtype="int32")
    return tree


def init_kv_cache(cfg, batch, cache_len, windowed, dtype):
    specs = kv_cache_specs(cfg, batch, cache_len, windowed)

    def _one(path, s):
        dt = jnp.dtype(s.dtype or dtype)
        arr = jnp.zeros(s.shape, dt)
        if path[-1] == "slot_pos":
            arr = arr - 1  # -1 = empty slot
        return arr
    from repro.models.layers import tree_map_specs
    return tree_map_specs(_one, specs)


def _cache_write_prefill(cache, new_k, new_v, windowed):
    """Write the whole prompt starting at position 0."""
    length = cache["k"].shape[1]
    b, s_new = new_k.shape[0], new_k.shape[1]
    if windowed:
        # keep only the last ``length`` entries if the prompt overflows
        take = min(s_new, length)
        src_k, src_v = new_k[:, -take:], new_v[:, -take:]
        pos0 = s_new - take
        idx = jnp.mod(pos0 + jnp.arange(take), length)
        k = cache["k"].at[:, idx].set(src_k)
        v = cache["v"].at[:, idx].set(src_v)
        sp = cache["slot_pos"].at[:, idx].set(
            (pos0 + jnp.arange(take, dtype=jnp.int32))[None])
        return dict(cache, k=k, v=v, slot_pos=sp)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], new_k, 0, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], new_v, 0, axis=1)
    return dict(cache, k=k, v=v)


def _cache_write_decode(cache, new_k, new_v, pos, windowed):
    """Write ONE token per request at per-request position ``pos [B]``."""
    length = cache["k"].shape[1]
    b = new_k.shape[0]
    bidx = jnp.arange(b)
    slot = jnp.mod(pos, length) if windowed else pos
    k = cache["k"].at[bidx, slot].set(new_k[:, 0])
    v = cache["v"].at[bidx, slot].set(new_v[:, 0])
    if windowed:
        sp = cache["slot_pos"].at[bidx, slot].set(pos.astype(jnp.int32))
        return dict(cache, k=k, v=v, slot_pos=sp)
    return dict(cache, k=k, v=v)


def prefill_into_cache(p, x, cos, sin, cfg, cache, *, window=0,
                       positions=None, block_k=1024):
    """Prefill attention that also fills the cache starting at pos 0."""
    b, s, _ = x.shape
    q, k, v = qkv_proj(p, x)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    pos = positions if positions is not None else jnp.arange(s, dtype=jnp.int32)
    windowed = "slot_pos" in cache
    cache = _cache_write_prefill(cache, k, v, windowed)
    qg = _grouped(q, cfg.num_kv_heads)
    o = blockwise_sdpa(qg, k, v, q_pos=pos, k_pos=pos, causal=True,
                       window=window, block_k=block_k)
    return out_proj(p, o), cache


def _extend_positions(start, s_new: int):
    """Positions written by an extend: start scalar -> [1,S_new] (shared by
    the batch); start [B] -> [B,S_new] per-request block offsets (batched
    speculative verify)."""
    start = jnp.asarray(start, jnp.int32)
    pos = start[..., None] + jnp.arange(s_new, dtype=jnp.int32)
    return pos[None] if pos.ndim == 1 else pos


def _cache_write_extend(cache, new_k, new_v, start, windowed):
    """Write S_new entries at offset ``start`` -- scalar (chunked prefill /
    prefix-cache continuation) or [B] per-request starts (batched
    speculative block verify). Per-request rows routed past the end are
    clipped onto the last position, the engine's reserved scratch slot."""
    length = cache["k"].shape[1]
    s_new = new_k.shape[1]
    if jnp.asarray(start).ndim:                  # per-request starts [B]
        pos = _extend_positions(start, s_new)    # [B, S_new]
        idx = jnp.mod(pos, length) if windowed \
            else jnp.clip(pos, 0, length - 1)
        bidx = jnp.arange(new_k.shape[0])[:, None]
        k = cache["k"].at[bidx, idx].set(new_k)
        v = cache["v"].at[bidx, idx].set(new_v)
        if windowed:
            sp = cache["slot_pos"].at[bidx, idx].set(pos)
            return dict(cache, k=k, v=v, slot_pos=sp)
        return dict(cache, k=k, v=v)
    if windowed:
        idx = jnp.mod(start + jnp.arange(s_new), length)
        k = cache["k"].at[:, idx].set(new_k)
        v = cache["v"].at[:, idx].set(new_v)
        sp = cache["slot_pos"].at[:, idx].set(
            (start + jnp.arange(s_new, dtype=jnp.int32))[None])
        return dict(cache, k=k, v=v, slot_pos=sp)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], new_k, start, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], new_v, start, axis=1)
    return dict(cache, k=k, v=v)


def append_attention(p, x, cos, sin, cfg, cache, start, *, window=0):
    """Multi-token cache continuation: x [B,S_new,d] appended at ``start``
    (scalar, or [B] per-request starts); attends causally against the whole
    cache (prefix + chunk).

    Enables Sarathi-style chunked prefill, RadixAttention prefix reuse, and
    batched speculative block verification on the dense-slot engine."""
    b, s_new, _ = x.shape
    q, k, v = qkv_proj(p, x)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    windowed = "slot_pos" in cache
    cache = _cache_write_extend(cache, k, v, start, windowed)
    k_pos = (cache["slot_pos"] if windowed
             else jnp.arange(cache["k"].shape[1], dtype=jnp.int32))
    q_pos = _extend_positions(start, s_new)
    qg = _grouped(q, cfg.num_kv_heads)
    o = simple_sdpa(qg, cache["k"], cache["v"], q_pos=q_pos,
                    k_pos=k_pos, causal=True, window=window)
    return out_proj(p, o), cache


def mla_append_attention(p, x, cos, sin, cfg, cache, start, *, window=0):
    """MLA chunk continuation against the latent cache. ``start`` scalar or
    [B] per-request block offsets (batched speculative verify)."""
    b, s_new, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, cfg, cos, sin)
    ckv_t, k_rope_t = _mla_latent(p, x, cfg, cos, sin)
    windowed = "slot_pos" in cache
    length = cache["ckv"].shape[1]
    if jnp.asarray(start).ndim:              # per-request starts [B]
        pos = _extend_positions(start, s_new)
        idx = jnp.mod(pos, length) if windowed \
            else jnp.clip(pos, 0, length - 1)
        bidx = jnp.arange(b)[:, None]
        cache = dict(cache,
                     ckv=cache["ckv"].at[bidx, idx].set(ckv_t),
                     k_rope=cache["k_rope"].at[bidx, idx].set(k_rope_t))
        if windowed:
            cache = dict(cache,
                         slot_pos=cache["slot_pos"].at[bidx, idx].set(pos))
        k_pos = (cache["slot_pos"] if windowed
                 else jnp.arange(length, dtype=jnp.int32)[None])
    elif windowed:
        idx = jnp.mod(start + jnp.arange(s_new), length)
        cache = dict(cache,
                     ckv=cache["ckv"].at[:, idx].set(ckv_t),
                     k_rope=cache["k_rope"].at[:, idx].set(k_rope_t),
                     slot_pos=cache["slot_pos"].at[:, idx].set(
                         (start + jnp.arange(s_new, dtype=jnp.int32))[None]))
        k_pos = cache["slot_pos"]
    else:
        cache = dict(cache,
                     ckv=jax.lax.dynamic_update_slice_in_dim(
                         cache["ckv"], ckv_t, start, axis=1),
                     k_rope=jax.lax.dynamic_update_slice_in_dim(
                         cache["k_rope"], k_rope_t, start, axis=1))
        k_pos = jnp.arange(length, dtype=jnp.int32)[None]
    # naive (non-absorbed) form over the latent cache
    k_nope = jnp.einsum("bsr,rhe->bshe", cache["ckv"], p["wk_b"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    vfull = jnp.einsum("bsr,rhe->bshe", cache["ckv"], p["wv_b"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    h = cfg.num_heads
    kr = jnp.broadcast_to(cache["k_rope"][:, :, None, :],
                          k_nope.shape[:2] + (h, cfg.qk_rope_head_dim))
    kfull = jnp.concatenate([k_nope, kr], -1)
    # MLA "kv heads" = all heads; fold K into head axis with G=1
    b_, sk = kfull.shape[0], kfull.shape[1]
    kflat = kfull
    q = jnp.concatenate([q_nope, q_rope], -1)
    qg = q.reshape(b_, s_new, h, 1, q.shape[-1])
    q_pos = _extend_positions(start, s_new)
    o = simple_sdpa(qg, kflat, vfull, q_pos=q_pos, k_pos=k_pos,
                    causal=True, window=window)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, cache


def decode_attention(p, x, cos, sin, cfg, cache, pos, *, window=0):
    """One-token decode vs cache. x [B,1,d]; pos [B] per-request int32."""
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (b,))
    q, k, v = qkv_proj(p, x)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    windowed = "slot_pos" in cache
    cache = _cache_write_decode(cache, k, v, pos, windowed)
    k_pos = (cache["slot_pos"] if windowed
             else jnp.arange(cache["k"].shape[1], dtype=jnp.int32))
    qg = _grouped(q, cfg.num_kv_heads)
    o = simple_sdpa(qg, cache["k"], cache["v"], q_pos=pos[:, None],
                    k_pos=k_pos, causal=True, window=window)
    return out_proj(p, o), cache


# --------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# --------------------------------------------------------------------------

def _mla_q(p, x, cfg, cos, sin):
    if cfg.q_lora_rank:
        ql = jnp.einsum("bsd,dr->bsr", x, p["wq_a"],
                        preferred_element_type=jnp.float32)
        ql = _rms(ql, p["q_norm"]).astype(x.dtype)
        q = jnp.einsum("bsr,rhe->bshe", ql, p["wq_b"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    q_nope = q[..., :cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], cos, sin)
    return q_nope, q_rope


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return y * scale.astype(jnp.float32)


def _mla_latent(p, x, cfg, cos, sin):
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"],
                    preferred_element_type=jnp.float32)
    ckv = _rms(kv[..., :cfg.kv_lora_rank], p["kv_norm"]).astype(x.dtype)
    k_rope = kv[..., cfg.kv_lora_rank:].astype(x.dtype)
    # rope applied to the shared (MQA-style) rope key
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return ckv, k_rope


def mla_full_attention(p, x, cos, sin, cfg, *, window=0, positions=None,
                       block_k=1024, cache=None):
    """Naive (non-absorbed) MLA for train/prefill; optionally fills cache."""
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, cfg, cos, sin)
    ckv, k_rope = _mla_latent(p, x, cfg, cos, sin)
    if cache is not None:
        windowed = "slot_pos" in cache
        if windowed:
            length = cache["ckv"].shape[1]
            take = min(s, length)
            idx = jnp.mod((s - take) + jnp.arange(take), length)
            cache = dict(cache,
                         ckv=cache["ckv"].at[:, idx].set(ckv[:, -take:]),
                         k_rope=cache["k_rope"].at[:, idx].set(
                             k_rope[:, -take:]),
                         slot_pos=cache["slot_pos"].at[:, idx].set(
                             ((s - take)
                              + jnp.arange(take, dtype=jnp.int32))[None]))
        else:
            cache = dict(cache,
                         ckv=jax.lax.dynamic_update_slice_in_dim(
                             cache["ckv"], ckv, 0, axis=1),
                         k_rope=jax.lax.dynamic_update_slice_in_dim(
                             cache["k_rope"], k_rope, 0, axis=1))
    k_nope = jnp.einsum("bsr,rhe->bshe", ckv, p["wk_b"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsr,rhe->bshe", ckv, p["wv_b"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    h = cfg.num_heads
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                (b, s, h, cfg.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope_b], -1)
    pos = positions if positions is not None else jnp.arange(s, dtype=jnp.int32)
    # heads ungrouped (K=H, G=1)
    qg = q.reshape(b, s, h, 1, q.shape[-1])
    o = blockwise_sdpa(qg, k, v, q_pos=pos, k_pos=pos, causal=True,
                       window=window, block_k=block_k)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return (out, cache) if cache is not None else out


def mla_decode_attention(p, x, cos, sin, cfg, cache, pos, *, window=0):
    """Absorbed-form MLA decode: attention runs in the latent space.

    The per-head key projection wk_b is absorbed into the query and wv_b
    into the output -- the cache holds only [B,S,r] + [B,S,rope]; this IS
    the survey's dim-2 cache compression realized architecturally.
    pos: [B] per-request int32 (or scalar, broadcast).
    """
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos, jnp.int32)), (b,))
    q_nope, q_rope = _mla_q(p, x, cfg, cos, sin)       # [B,1,H,*]
    ckv_t, k_rope_t = _mla_latent(p, x, cfg, cos, sin)  # [B,1,r],[B,1,rope]
    windowed = "slot_pos" in cache
    length = cache["ckv"].shape[1]
    bidx = jnp.arange(b)
    slot = jnp.mod(pos, length) if windowed else pos
    cache = dict(cache,
                 ckv=cache["ckv"].at[bidx, slot].set(ckv_t[:, 0]),
                 k_rope=cache["k_rope"].at[bidx, slot].set(k_rope_t[:, 0]))
    if windowed:
        cache["slot_pos"] = cache["slot_pos"].at[bidx, slot].set(pos)
        k_pos = cache["slot_pos"]                      # [B,S]
    else:
        k_pos = jnp.broadcast_to(jnp.arange(length, dtype=jnp.int32)[None],
                                 (b, length))
    # absorb wk_b into q: [B,1,H,nope] x [r,H,nope] -> [B,1,H,r]
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, p["wk_b"],
                       preferred_element_type=jnp.float32)
    scale = 1.0 / ((cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** 0.5)
    s_lat = jnp.einsum("bshr,bcr->bhsc", q_lat,
                       cache["ckv"].astype(jnp.float32))
    s_rope = jnp.einsum("bshe,bce->bhsc", q_rope.astype(jnp.float32),
                        cache["k_rope"].astype(jnp.float32))
    s = (s_lat + s_rope) * scale
    valid = (k_pos >= 0) & (k_pos <= pos[:, None])     # [B,S]
    if window:
        valid = valid & (k_pos > (pos - window)[:, None])
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhsc,bcr->bshr", pr, cache["ckv"].astype(jnp.float32))
    o = jnp.einsum("bshr,rhe->bshe", o_lat, p["wv_b"].astype(jnp.float32))
    out = jnp.einsum("bshe,hed->bsd", o.astype(x.dtype), p["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, cache
