"""Compression policy: maps CompressionConfig -> a callable applied to the
visual token stream before (encoder-side) or inside (decoder-side) the
backbone. This is the single integration point the serving engine and the
examples use."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CompressionConfig
from repro.core.token_compression import merging, pruning


def compress_visual_tokens(cc: CompressionConfig, embeds, *,
                           query=None, scores=None
                           ) -> Tuple[jax.Array, Optional[jax.Array], Dict]:
    """Apply the configured encoder-side compressor.

    embeds [B,N,d]; query [B,Q,d] (text embeddings) for cross-modal
    pruners; scores [B,N] externally computed salience (e.g. encoder
    attention for PruMerge/VisionZip-style reduction).

    Returns (compressed, kept_idx or None, info).
    """
    n = embeds.shape[1]
    keep = max(1, int(round(n * cc.keep_ratio)))
    if cc.keep_ratio >= 1.0 and cc.token_merger == "none":
        return embeds, None, {"keep": n, "method": "none"}

    if cc.token_merger == "tome":
        out, sizes = merging.tome_to_count(embeds, keep)
        return out, None, {"keep": out.shape[1], "method": "tome"}
    if cc.token_merger == "framefusion":
        out, idx, info = merging.prune_then_merge(embeds, keep, scores=scores)
        return out, idx, {"method": "prune+merge", **info}

    if cc.token_pruner == "none":
        return embeds, None, {"keep": n, "method": "none"}
    if cc.token_pruner == "fastv" and scores is None:
        # the scanned production path never materializes attention matrices
        # (survey §V), so score-free callers (the engine) use the L2-norm
        # salience proxy: low-norm keys receive high attention [L2Compress]
        scores = -jnp.linalg.norm(embeds, axis=-1)
    fn = pruning.PRUNERS[cc.token_pruner]
    out, idx, info = fn(embeds, keep, scores=scores, query=query)
    return out, idx, {"keep": keep, "method": cc.token_pruner, **info}


def fastv_scores_from_attention(attn_probs, visual_slice) -> jax.Array:
    """FastV salience from a decoder layer's attention probabilities.

    attn_probs [B, H, Sq, Sk]; visual_slice = (start, stop) of the visual
    tokens inside the key axis. Score = mean over heads and queries of the
    attention each visual key receives.
    """
    start, stop = visual_slice
    return attn_probs[..., start:stop].mean(axis=(1, 2))
