"""Trace validation: ``python -m repro.obs.validate TRACE [--require-migrations]``.

Machine-checks a trace is complete and well-formed -- the CI gate for
the traced disagg-burst run:

  * **balanced spans**: every ``b`` has a matching ``e`` per
    ``(id, name)`` -- zero orphans (an aborted request still closes;
    see ``Tracer.span_abort``);
  * **monotonic clocks**: per request, the virtual (``ts``) and wall
    (``args.wall_s``) timestamps never go backwards across its span
    boundary events -- the migration hand-off may not rewind either
    clock;
  * **Perfetto-loadable**: top-level ``traceEvents`` list, every event
    carries ``name``/``ph``/``pid``/``ts``, ``X`` events carry ``dur``;
  * with ``--require-migrations``: every request span saw >= 1
    ``kv_migration`` span (the disaggregated-fleet acceptance shape).

Accepts the Chrome-trace JSON written by ``repro.obs.perfetto`` or the
raw tracer JSONL (one event dict per line, converted on the fly).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

from repro.obs.perfetto import to_chrome_trace


def load_trace(path: str) -> Dict:
    """Load Chrome-trace JSON, or tracer JSONL (converted)."""
    with open(path, "r", encoding="utf-8") as f:
        head = f.read(1)
        f.seek(0)
        if head == "{":
            try:
                doc = json.load(f)
            except json.JSONDecodeError:
                doc = None               # JSONL: one dict per line
            if isinstance(doc, dict) and "traceEvents" in doc:
                return doc
            f.seek(0)
        events = [json.loads(line) for line in f if line.strip()]
    return to_chrome_trace(events)


def validate_trace(doc: Dict, *,
                   require_migrations: bool = False) -> List[str]:
    """Return a list of problems (empty == valid)."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["not Perfetto-loadable: no top-level traceEvents list"]

    open_spans: Dict[Tuple, Dict] = {}
    # per-rid last-seen clocks over span boundary events
    last_vt: Dict = {}
    last_wt: Dict = {}
    migrated: set = set()
    requests: set = set()

    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue
        for field in ("name", "ph", "pid", "ts"):
            if field not in ev:
                problems.append(
                    f"event {i}: not Perfetto-loadable, missing {field!r}")
        if ph == "X" and "dur" not in ev:
            problems.append(f"event {i}: X event missing dur")
        if ph not in ("b", "e"):
            continue

        rid = ev.get("id")
        name = ev.get("name")
        key = (rid, name)
        if name == "request":
            requests.add(rid)
        elif name == "kv_migration":
            migrated.add(rid)
        if ph == "b":
            if key in open_spans:
                problems.append(f"event {i}: double-begin {key}")
            open_spans[key] = ev
        else:
            if key not in open_spans:
                problems.append(f"event {i}: end without begin {key}")
            else:
                del open_spans[key]

        vt = ev.get("ts", 0.0)
        wt = (ev.get("args") or {}).get("wall_s")
        if rid in last_vt and vt < last_vt[rid]:
            problems.append(
                f"event {i}: rid {rid} virtual clock went backwards "
                f"({last_vt[rid]} -> {vt})")
        last_vt[rid] = vt
        if wt is not None:
            if rid in last_wt and wt < last_wt[rid]:
                problems.append(
                    f"event {i}: rid {rid} wall clock went backwards "
                    f"({last_wt[rid]} -> {wt})")
            last_wt[rid] = wt

    for key in open_spans:
        problems.append(f"orphan span (never closed): {key}")
    if require_migrations:
        for rid in sorted(requests - migrated):
            problems.append(f"rid {rid}: no kv_migration span "
                            "(disaggregated fleet expected one)")
    if not requests:
        problems.append("trace contains no request spans")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.validate",
        description="Validate a repro.obs trace (Chrome JSON or JSONL).")
    ap.add_argument("trace", help="trace file to validate")
    ap.add_argument("--require-migrations", action="store_true",
                    help="fail unless every request migrated >= once")
    args = ap.parse_args(argv)

    doc = load_trace(args.trace)
    problems = validate_trace(
        doc, require_migrations=args.require_migrations)
    n = sum(1 for e in doc["traceEvents"] if e.get("ph") != "M") \
        if isinstance(doc.get("traceEvents"), list) else 0
    if problems:
        for p in problems:
            print(f"FAIL: {p}", file=sys.stderr)
        print(f"{args.trace}: {len(problems)} problem(s) in {n} events",
              file=sys.stderr)
        return 1
    print(f"{args.trace}: OK ({n} events, 0 orphan spans)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
