"""Perf-regression gate over ``BENCH_*.json`` (and other metric) pairs.

    python -m repro.obs.regress CURRENT BASELINE --tolerance 0.5

Compares every numeric leaf the two JSON documents share and exits 1 if
any *gated* metric regressed beyond the tolerance. The comparator is
generic over nested dicts/lists -- it handles ``BENCH_kernels.json``
(per-kernel timing rows), ``BENCH_serving.json`` (virtual/wall serving
stats + the embedded profile block), and ``scripts/trace_report.py
--json`` stage-attribution documents with the same code path:

  * documents are flattened to dotted paths; list elements are keyed by
    an identifying field (``kernel``/``scenario``/``name``/``site``,
    plus ``shape`` when present) so reordering rows is not a diff;
  * a leaf is gated HIGHER-IS-WORSE when its path looks like a latency
    (``us_per_call``, ``*_s``, ``*_us``, ``ttft``/``tpot``, ``wall``,
    ``seconds``, ``queue_wait``) and HIGHER-IS-BETTER when it looks like
    a rate (``throughput``, ``tok_per_s``, ``goodput``, ``attainment``,
    ``hit_rate``); everything else (counts, schema versions, shares,
    noise stats like ``std``) is informational only;
  * regression means ``ratio > 1 + tolerance`` where ratio is
    current/baseline for higher-is-worse and baseline/current for
    higher-is-better -- symmetric, and safe for tolerances > 1 (CI uses
    a generous tolerance so a committed same-machine baseline gates
    hosted runners without flaking).

Leaves present in only one document are reported but never gate (a new
kernel row must not fail the gate that introduces it).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

# path substrings that decide gating direction (checked on the full
# dotted path, lowercase)
_HIGHER_WORSE = ("us_per_call", "_us", "_s.", "time_s", "ttft", "tpot",
                 "seconds", "wall", "queue_wait", "jct")
_HIGHER_BETTER = ("throughput", "tok_per_s", "goodput", "attainment",
                  "hit_rate", "quality_proxy")
# leaf names that are never gated even under a matching path (noise or
# bookkeeping, not performance). rate_rps/retained_visual_ratio are a
# pareto row's identity/configuration, not measurements; acceptance is
# folded into quality_proxy.
_UNGATED_LEAVES = ("std", "count", "iters", "schema_version", "share",
                   "rate_rps", "retained_visual_ratio", "acceptance",
                   "replicas")

_ID_FIELDS = ("kernel", "scenario", "name", "site", "stage")

# composite identity of a BENCH_pareto.json sweep row: one grid point is
# (compression preset x decoder x replica mix x arrival rate), so a row
# keyed this way matches its baseline row regardless of sweep order
_PARETO_ID_FIELDS = ("compression", "decoder", "mix", "rate_rps")


def _item_key(item, i: int) -> str:
    if isinstance(item, dict):
        if all(f in item for f in _PARETO_ID_FIELDS):
            return "|".join(str(item[f]) for f in _PARETO_ID_FIELDS)
        for f in _ID_FIELDS:
            if f in item and isinstance(item[f], str):
                key = item[f]
                if isinstance(item.get("shape"), str):
                    key += "/" + item["shape"]
                return key
    return str(i)


def flatten(doc, prefix: str = "") -> Dict[str, float]:
    """Numeric leaves of a nested JSON document as {dotted.path: value}."""
    out: Dict[str, float] = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(doc, list):
        for i, item in enumerate(doc):
            out.update(flatten(item, f"{prefix}{_item_key(item, i)}."))
    elif isinstance(doc, bool):
        pass
    elif isinstance(doc, (int, float)):
        out[prefix[:-1]] = float(doc)
    return out


def _direction(path: str) -> int:
    """+1 higher-is-worse, -1 higher-is-better, 0 informational."""
    p = path.lower()
    leaf = p.rsplit(".", 1)[-1]
    if leaf in _UNGATED_LEAVES:
        return 0
    if any(s in p for s in _HIGHER_BETTER):
        return -1
    # trailing "_s" needs the sentinel dot trick to also match leaves
    if any(s in p + "." for s in _HIGHER_WORSE):
        return 1
    return 0


def compare(current: Dict, baseline: Dict, tolerance: float
            ) -> Tuple[List[Tuple[str, float, float, float]],
                       List[Tuple[str, float, float, float]]]:
    """Returns (regressions, compared): each entry is
    (path, current, baseline, ratio) with ratio oriented so that > 1
    means worse. Only gated leaves present in BOTH documents appear."""
    cur = flatten(current)
    base = flatten(baseline)
    compared, regressions = [], []
    for path in sorted(set(cur) & set(base)):
        d = _direction(path)
        if d == 0:
            continue
        c, b = cur[path], base[path]
        if b <= 0.0 or c <= 0.0:
            continue          # zero/negative timings carry no signal
        ratio = (c / b) if d > 0 else (b / c)
        compared.append((path, c, b, ratio))
        if ratio > 1.0 + tolerance:
            regressions.append((path, c, b, ratio))
    return regressions, compared


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="Gate a BENCH_*.json (or trace_report --json) "
                    "document against a committed baseline.")
    ap.add_argument("current", help="freshly produced metrics JSON")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed relative slowdown (0.5 = 50%% worse "
                         "passes; default %(default)s)")
    ap.add_argument("--list", action="store_true",
                    help="print every compared metric, not just "
                         "regressions")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    regressions, compared = compare(current, baseline, args.tolerance)

    only = set(flatten(current)) ^ set(flatten(baseline))
    if args.list:
        for path, c, b, ratio in compared:
            print(f"  {path}: {c:.6g} vs {b:.6g} (x{ratio:.3f})")
    print(f"regress: {len(compared)} gated metrics compared, "
          f"{len(only)} present in one document only, "
          f"tolerance {args.tolerance:g}")
    if regressions:
        for path, c, b, ratio in regressions:
            print(f"REGRESSION {path}: {c:.6g} vs baseline {b:.6g} "
                  f"(x{ratio:.3f} > x{1.0 + args.tolerance:.3f})")
        return 1
    print("regress: OK (no metric beyond tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
