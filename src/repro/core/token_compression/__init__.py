from repro.core.token_compression.pruning import (
    PRUNERS, prune_fastv, prune_sparsevlm, prune_l2, prune_divprune,
    prune_cdpruner, pyramiddrop_schedule)
from repro.core.token_compression.merging import (
    tome_merge, tome_to_count, prune_then_merge)
from repro.core.token_compression.video import (
    temporal_merge, llama_vid_compress, dycoke_ratio, dynamic_compress,
    framefusion, frame_similarity)
from repro.core.token_compression.policy import (
    compress_visual_tokens, fastv_scores_from_attention)
