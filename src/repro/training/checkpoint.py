"""Sharded npz checkpointing for param/optimizer pytrees.

Leaves are flattened to ``path.to.leaf`` keys and split across multiple npz
shards capped at ``shard_bytes`` (a real multi-host framework writes one
shard per host; here sharding keeps single files bounded and proves the
layout). A small json manifest records the tree structure, dtypes, and step.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=()):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
    else:
        out[".".join(prefix)] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(".")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save_checkpoint(path: str, tree, step: int,
                    shard_bytes: int = 512 * 1024 * 1024) -> Dict:
    os.makedirs(path, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    shards, cur, cur_bytes = [], {}, 0
    for k, v in flat.items():
        if cur and cur_bytes + v.nbytes > shard_bytes:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        cur[k] = v
        cur_bytes += v.nbytes
    if cur:
        shards.append(cur)
    manifest = {"step": step, "num_shards": len(shards),
                "keys": {k: {"shard": i, "dtype": str(v.dtype),
                             "shape": list(v.shape)}
                         for i, sh in enumerate(shards)
                         for k, v in sh.items()}}
    for i, sh in enumerate(shards):
        np.savez(os.path.join(path, f"shard_{i:05d}.npz"), **sh)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return manifest


def load_checkpoint(path: str) -> Tuple[Any, int]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    for i in range(manifest["num_shards"]):
        with np.load(os.path.join(path, f"shard_{i:05d}.npz")) as z:
            for k in z.files:
                flat[k] = z[k]
    tree = _unflatten({k: jax.numpy.asarray(v) for k, v in flat.items()})
    return tree, manifest["step"]
