"""Logical-axis -> mesh sharding rules (GSPMD via NamedSharding).

Every ParamSpec carries logical axis names ("embed", "heads", "ffn",
"expert", "vocab", "batch", "cache_seq", ...). This module maps them onto
the production mesh:

  single pod : ("data", "model") = (16, 16)          -- 256 chips
  multi-pod  : ("pod", "data", "model") = (2, 16, 16) -- 512 chips

Rules (DESIGN.md §5):

  * params: tensor-parallel over "model" via a PRIORITY list (experts first
    -- expert parallelism -- then heads / ffn / vocab, falling back to the
    d_model axis when the preferred axis does not divide, e.g. qwen2-vl's
    12 heads or whisper's 51865 vocab on a 16-way axis). With ``fsdp=True``
    a SECOND (different) axis is sharded over "data" (MaxText-style
    fsdp+tensor 2D sharding) -- required for the 123B--671B archs whose
    bf16 weights exceed one chip's HBM even 16-way sharded.
  * the "pod" axis shards BATCH only (pure data parallel across the DCN;
    params replicate across pods -- gradient all-reduce is the only
    cross-pod collective, the standard multi-pod pattern).
  * KV caches: batch -> "data", kv_heads -> "model" when divisible
    (zamba2's 32 kv heads), else cache_seq -> "model" (sequence-parallel
    cache: GSPMD turns the attention contraction into partial-softmax +
    all-reduce, flash-decoding style) -- GQA kv<=8 archs cannot head-shard
    a 16-way axis.
  * divisibility is always checked; non-divisible axes stay replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ParamSpec, tree_map_specs

# logical axes eligible for the tensor ("model") dimension, in priority
MODEL_PRIORITY = ("expert", "heads", "heads_flat", "kv_heads", "ffn",
                  "moe_ffn", "ssm_inner", "vocab", "embed_out", "embed")
# logical axes eligible for the fsdp ("data") dimension, in priority
FSDP_PRIORITY = ("embed", "vocab", "ffn", "moe_ffn", "ssm_inner", "expert",
                 "heads", "heads_flat", "embed_out")


class ShardingRules:
    def __init__(self, mesh: Mesh, *, fsdp: bool = True,
                 cache_model_shard_threshold: float = 0.5e9):
        self.mesh = mesh
        self.fsdp = fsdp
        self.model_size = mesh.shape.get("model", 1)
        self.data_size = mesh.shape.get("data", 1)
        self.pod = "pod" in mesh.shape
        self.batch_axes: Tuple[str, ...] = (
            ("pod", "data") if self.pod else ("data",))
        # KV caches only shard their seq axis over "model" when the
        # batch-sharded leaf exceeds this (bytes); small caches replicate
        # over model and skip the per-attention KV gather (§Perf,
        # qwen2-vl prefill_32k iteration)
        self.cache_model_shard_threshold = cache_model_shard_threshold

    # ------------------------------------------------------------ params --
    def param_pspec(self, s: ParamSpec) -> P:
        axes = list(s.axes)
        assign: Dict[int, str] = {}
        # NOTE (§Perf iteration 2, REFUTED): sharding the expert axis over
        # the whole ("data","model") grid -- full expert parallelism, no
        # fsdp gathers for expert weights -- made the collective term 3-20x
        # WORSE under GSPMD: the grouped dispatch buffers ([G,E,C,d],
        # G data-sharded) then need a full reshard against the expert
        # layout every layer. expert->model + fsdp is the measured optimum
        # of this family (EXPERIMENTS.md §Perf).
        # tensor axis
        for name in MODEL_PRIORITY:
            if name in axes:
                i = axes.index(name)
                if s.shape[i] % self.model_size == 0 and s.shape[i] > 0:
                    assign[i] = "model"
                    break
        # fsdp axis (a different dim)
        if self.fsdp:
            for name in FSDP_PRIORITY:
                if name in axes:
                    i = axes.index(name)
                    if i in assign:
                        continue
                    if s.shape[i] % self.data_size == 0 and s.shape[i] > 0:
                        assign[i] = "data"
                        break
        return P(*[assign.get(i) for i in range(len(axes))])

    # ------------------------------------------------------------- cache --
    def cache_pspec(self, s: ParamSpec) -> P:
        axes = list(s.axes)
        out: list = [None] * len(axes)
        for i, name in enumerate(axes):
            if name == "batch":
                # batch=1 long-context: replicate rather than 0-size shards
                parts = 1
                for a in self.batch_axes:
                    parts *= self.mesh.shape[a]
                if s.shape[i] % parts == 0:
                    out[i] = self.batch_axes if len(self.batch_axes) > 1 \
                        else self.batch_axes[0]
        # model axis: kv_heads if divisible, else cache_seq
        def try_axis(name):
            if name in axes:
                i = axes.index(name)
                if out[i] is None and s.shape[i] % self.model_size == 0 \
                        and s.shape[i] > 0:
                    out[i] = "model"
                    return True
            return False
        # per-device leaf bytes after batch sharding (dtype <= 4B assumed);
        # batch=1 long-context caches CANNOT batch-shard, so don't divide
        elems = 1
        for d in s.shape:
            elems *= d
        batch_parts = 1
        for a in self.batch_axes:
            batch_parts *= self.mesh.shape[a]
        if "batch" in axes and s.shape[axes.index("batch")] % batch_parts:
            batch_parts = 1
        approx_bytes = elems * 2 / batch_parts
        is_attn_kv = "cache_seq" in axes or "kv_heads" in axes
        if is_attn_kv:
            # attention KV: replicating caches over "model" skips the
            # per-attention KV gather, but un-shards the attention einsums
            # too -- at prefill scale that is 16x REDUNDANT quadratic
            # compute (measured: qwen2-vl prefill compute 0.17s -> 1.93s
            # before this threshold was tightened to 0.5 GB; §Perf pair C
            # iteration 2 verdict). Only truly tiny caches replicate.
            if approx_bytes >= self.cache_model_shard_threshold:
                try_axis("kv_heads") or try_axis("cache_seq")
        else:
            # SSM/recurrent states are rewritten EVERY decode step:
            # replication would all-gather them per step -- always shard
            try_axis("ssm_inner") or try_axis("heads")
        return P(*out)

    # ------------------------------------------------------------- batch --
    def batch_pspec(self, ndim: int, batch_dim: int = 0,
                    batch_size: Optional[int] = None) -> P:
        parts = 1
        for a in self.batch_axes:
            parts *= self.mesh.shape[a]
        spec: list = [None] * ndim
        if batch_size is None or batch_size % parts == 0:
            spec[batch_dim] = (self.batch_axes if len(self.batch_axes) > 1
                               else self.batch_axes[0])
        elif batch_size % (dp := self.mesh.shape.get("data", 1)) == 0:
            spec[batch_dim] = "data"
        return P(*spec)

    def named(self, pspec: P) -> NamedSharding:
        return NamedSharding(self.mesh, pspec)


# --------------------------------------------------------------------------
# tree builders
# --------------------------------------------------------------------------

def param_shardings(rules: ShardingRules, spec_tree) -> Any:
    return tree_map_specs(
        lambda path, s: rules.named(rules.param_pspec(s)), spec_tree)


def opt_state_shardings(rules: ShardingRules, spec_tree) -> Any:
    ps = param_shardings(rules, spec_tree)
    return {"mu": ps, "nu": ps,
            "step": rules.named(P())}


def cache_shardings(rules: ShardingRules, cache_spec_tree) -> Any:
    return tree_map_specs(
        lambda path, s: rules.named(rules.cache_pspec(s)), cache_spec_tree)


def batch_shardings(rules: ShardingRules, batch_struct: Dict[str, Any]
                    ) -> Dict[str, Any]:
    out = {}
    for k, v in batch_struct.items():
        out[k] = rules.named(rules.batch_pspec(v.ndim,
                                               batch_size=v.shape[0]))
    return out


def logits_sharding(rules: ShardingRules, shape: Tuple[int, ...]
                    ) -> NamedSharding:
    """[B, (S,) V] logits: batch -> data(+pod), vocab -> model."""
    ndim = len(shape)
    spec: list = [None] * ndim
    spec[0] = rules.batch_pspec(ndim, batch_size=shape[0])[0]
    if shape[-1] % rules.model_size == 0:
        spec[-1] = "model"
    return rules.named(P(*spec))


def replicated(rules: ShardingRules) -> NamedSharding:
    return rules.named(P())
