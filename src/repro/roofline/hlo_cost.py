"""Trip-count-aware HLO cost walk.

``compiled.cost_analysis()`` counts a ``while`` body ONCE -- useless for
scanned (lax.scan) layer stacks where the body runs num_layers times. This
module parses the post-SPMD HLO text into computations, recovers each while
loop's trip count from the comparison constant in its condition
computation, and walks the call graph multiplying per-computation costs by
the product of enclosing trip counts:

  * flops: every ``dot`` contributes 2 * prod(output_shape) * K, K = the
    product of lhs contracting-dim sizes (operand shapes resolved through
    a per-computation symbol table -- modern HLO dumps print operand NAMES
    only). Exact for matmul-dominated models; elementwise flops ignored.
  * collective bytes: all-gather(output) / 2x all-reduce(operand) /
    reduce-scatter / all-to-all / collective-permute (operand), times the
    enclosing trip multiplier. Ring-transfer weighting as in analysis.py.

Shapes in the post-SPMD module are PER-DEVICE, so all results are
per-device costs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_WHILE_ATTR = re.compile(r"condition=%([\w\.\-]+).*?body=%([\w\.\-]+)")
_CALLS_ATTR = re.compile(r"(?:calls|to_apply)=%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_COLL_OP_RE = re.compile(
    r"=\s+(?:\([^=]*?\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _dims(shape_match) -> List[int]:
    return ([int(d) for d in shape_match.group(2).split(",")]
            if shape_match.group(2) else [])


def _prod(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _shape_bytes(shape_match) -> int:
    return _prod(_dims(shape_match)) * _DTYPE_BYTES[shape_match.group(1)]


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool = False
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    coll_bytes_by_op: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    calls: List[str] = dataclasses.field(default_factory=list)
    max_const: int = 1


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    symtab: Dict[str, List[int]] = {}
    depth = 0
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                is_entry = stripped.startswith("ENTRY")
                body = stripped[6:] if is_entry else stripped
                name = body.strip().lstrip("%").split(" ")[0].split("(")[0]
                cur = Computation(name, is_entry=is_entry)
                comps[name] = cur
                symtab = {}
                # parameters: map names to their (first) shape in the header
                for pm in re.finditer(r"([\w\.\-]+):\s*(\([^)]*\)|"
                                      + _SHAPE_RE.pattern + r")", stripped):
                    sm = _SHAPE_RE.search(pm.group(2))
                    if sm:
                        symtab[pm.group(1)] = _dims(sm)
                depth = 1
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            cur = None
            continue
        dm = _DEF_RE.match(line)
        first_shape = _SHAPE_RE.search(line)
        if dm and first_shape:
            symtab[dm.group(1)] = _dims(first_shape)
        # constants (trip-count recovery for conditions)
        for c in _CONST_RE.findall(stripped):
            cur.max_const = max(cur.max_const, int(c))
        # whiles / calls
        wm = _WHILE_ATTR.search(stripped)
        if wm and " while(" in stripped:
            cur.whiles.append((wm.group(1), wm.group(2)))
        elif " fusion(" in stripped or " call(" in stripped:
            cm = _CALLS_ATTR.search(stripped)
            if cm:
                cur.calls.append(cm.group(1))
        # dot flops
        if " dot(" in stripped and dm and first_shape:
            out_elems = _prod(_dims(first_shape))
            inside = stripped[stripped.index(" dot(") + 5:]
            inside = inside.split(")")[0]
            opnds = _OPND_RE.findall(inside)
            k = 1
            cm2 = _CONTRACT_RE.search(stripped)
            if cm2 and opnds:
                lhs = symtab.get(opnds[0], [])
                for ci in cm2.group(1).split(","):
                    if ci and int(ci) < len(lhs):
                        k *= lhs[int(ci)]
            cur.flops += 2.0 * out_elems * k
        # collectives
        cmatch = _COLL_OP_RE.search(stripped)
        if cmatch and "-done(" not in stripped:
            op = cmatch.group(1)
            shapes = list(_SHAPE_RE.finditer(stripped))
            split = cmatch.start(1)
            out_b = sum(_shape_bytes(s) for s in shapes if s.start() < split)
            opr_b = sum(_shape_bytes(s) for s in shapes
                        if s.start() >= split)
            if op == "all-gather":
                inc = out_b
            elif op == "all-reduce":
                inc = 2 * opr_b
            else:
                inc = opr_b
            cur.coll_bytes += inc
            cur.coll_counts[op] = cur.coll_counts.get(op, 0) + 1
            cur.coll_bytes_by_op[op] = cur.coll_bytes_by_op.get(op, 0.0) + inc
    return comps


def walk_costs(hlo: str) -> Dict[str, object]:
    """Per-device totals with while-loop trip multipliers applied."""
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None and comps:
        referenced = set()
        for c in comps.values():
            referenced.update(n for w in c.whiles for n in w)
            referenced.update(c.calls)
        entry = next((c for c in comps.values()
                      if c.name not in referenced), None)
    if entry is None:
        return {"flops": 0.0, "collective_bytes": 0.0,
                "collective_counts": {}, "entry": None}

    memo: Dict[str, Tuple[float, float, Dict[str, int], Dict[str, float]]] \
        = {}

    def visit(name: str, seen=()):
        if name not in comps or name in seen or len(seen) > 64:
            return 0.0, 0.0, {}, {}
        if name in memo:
            return memo[name]
        c = comps[name]
        fl, cb = c.flops, c.coll_bytes
        counts = dict(c.coll_counts)
        by_op = dict(c.coll_bytes_by_op)
        for callee in c.calls:
            cf, cc, cn, cbo = visit(callee, seen + (name,))
            fl += cf
            cb += cc
            for k, v in cn.items():
                counts[k] = counts.get(k, 0) + v
            for k, v in cbo.items():
                by_op[k] = by_op.get(k, 0.0) + v
        for cond, body in c.whiles:
            trip = comps[cond].max_const if cond in comps else 1
            bf, bc, bn, bbo = visit(body, seen + (name,))
            fl += trip * bf
            cb += trip * bc
            for k, v in bn.items():
                counts[k] = counts.get(k, 0) + trip * v
            for k, v in bbo.items():
                by_op[k] = by_op.get(k, 0.0) + trip * v
        memo[name] = (fl, cb, counts, by_op)
        return memo[name]

    flops, coll, counts, by_op = visit(entry.name)
    return {"flops": flops, "collective_bytes": coll,
            "collective_counts": counts, "collective_bytes_by_op": by_op,
            "entry": entry.name}
