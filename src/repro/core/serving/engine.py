"""The LVLM serving engine: composes the survey's taxonomy end-to-end.

One ``Engine`` drives a REAL jitted model (fixed-shape slot pool, the XLA
analogue of vLLM's preallocated physical blocks) under any scheduler from
scheduler.py, with the taxonomy dimensions as config switches:

  dim 1  visual token compression  -- pluggable ``CompressionStrategy``
         objects applied to each request's visual embeddings before
         prefill. Like decoders, compression is PER-REQUEST: the engine
         keeps a compressor registry (``Engine(compressors=...)``), each
         request may name its own strategy (``Request.compression``), and
         KV accounting / admission / prefix-cache keys all use the
         POST-compression token counts of the resolved strategy.
  dim 2a KV selection              -- post-prefill cache compaction with
         position-exact masking (slot_pos caches); attention-free selectors
         (l2 / streaming) run live in the engine; attention-score selectors
         (snapkv/h2o) are library-level (they need the attention matrices
         the scanned production path deliberately never materializes --
         the survey's §V "alternative proxy for token salience" point).
  dim 2b prefix caching            -- RadixAttention-style longest-prefix
         reuse backed by host snapshots of the dense slot cache.
  dim 2c scheduling                -- static | continuous | mlfq | chunked
         (chunked prefill runs real ``model.extend`` chunk continuation).
  dim 4  decoding                  -- pluggable ``Decoder`` strategies: the
         per-iteration token emission is a hook (``decoder.engine_decode``)
         so greedy/sampling/speculative/early-exit all run behind one
         interface (adapters in ``repro.api.decoders``; the standalone
         drivers in core/decoding remain the library layer). Every request
         may carry its OWN strategy (``Request.decoder``): the engine keeps
         a decoder registry, groups the decode-phase slots by strategy each
         iteration, and charges each group its true virtual-clock cost --
         speculative runs all its slots per jitted draft/verify call
         (draft caches live in a second slot pool), early-exit slices each
         slot to a batch-1 cache for its host-side layer loop.

NOTE: ``repro.api`` (``LVLM`` / ``GenerationConfig``) is the public surface;
construct ``Engine`` directly only for internal-layer control.

Time is a virtual clock advanced by an analytic per-iteration cost model, so
TTFT/TPOT/JCT metrics are deterministic and hardware-independent (the
container has no TPU); FLOPs/bytes fidelity lives in the roofline pass.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CompressionConfig
from repro.core.decoding.sampling import sample_token
from repro.core.kv_cache.selection import SELECTORS
from repro.core.serving.disaggregation import CostModel
from repro.core.serving.request import Request, State, summarize
from repro.core.serving.scheduler import SCHEDULERS
from repro.core.token_compression.policy import (CompressionStrategy,
                                                 LIVE_KV_SELECTORS)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    cache_len: int = 256
    scheduler: str = "continuous"
    # KV token capacity the continuous/mlfq schedulers (and the serving
    # layer's admission watermarks) budget against; None = the dense slot
    # pool's size, max_batch * cache_len. Setting it LOWER creates KV
    # pressure before the slot pool binds -- the admission-deferral tests
    # and the async server's watermarks use exactly that.
    kv_capacity_tokens: Optional[int] = None
    chunk_size: int = 32                 # chunked-prefill chunk
    token_budget: int = 128              # chunked-prefill per-iter budget
    temperature: float = 0.0
    top_k: int = 0                       # 0 = no top-k warp
    top_p: float = 0.0                   # 0 = no nucleus warp
    eos_id: int = -1                     # -1 = never stop on eos
    seed: int = 0
    decoder: str = "sampling"            # sampling|greedy|speculative|early_exit
    #   DEFAULT strategy; any request may override it per-request via
    #   ``Request.decoder`` (speculative/early_exit resolve via
    #   repro.api.decoders; an explicit Decoder instance passed to
    #   Engine(..., decoder=) takes precedence for the default, and
    #   Engine(..., decoders={name: inst}) registers named strategies)
    compression: CompressionConfig = dataclasses.field(
        default_factory=CompressionConfig)
    #   DEFAULT compression config for the internal layer; the facade now
    #   passes a CompressionStrategy object instead (Engine(compressor=))
    #   and leaves this at its default. Any request may override the
    #   strategy per-request via ``Request.compression``.
    prefix_cache: bool = False
    prefix_block: int = 16               # reuse granularity (tokens)
    prefix_cap: int = 64                 # max cached prefixes (LRU-evicted)
    cost: CostModel = dataclasses.field(default_factory=CostModel)
    # runtime sanitizer (repro.analysis.sanitizer): conservation asserts
    # at step/abort boundaries -- slot table, draft-pool rows, prefix
    # pins, kv accounting. None = follow the REPRO_SANITIZE env var
    # (CI's smoke job sets it); True/False force it per engine.
    sanitize: Optional[bool] = None


class SamplingEngineDecoder:
    """Default decoder hook: one fixed-shape jitted decode step over the
    whole slot pool, then temperature/top-k/top-p sampling (dim 4 baseline).

    The hook contract (duck-typed; richer adapters live in
    ``repro.api.decoders``):

      engine_decode(engine, reqs) -> {slot: [emitted tokens]}

    The decoder owns the forward pass AND the slot bookkeeping
    (``pool`` / ``slot_pos`` / ``slot_last_tok``); the engine handles
    request bookkeeping (generated, eos, DONE) from the emitted map.
    An optional ``validate(engine)`` runs once at Engine construction.
    """
    name = "sampling"

    def __init__(self, greedy: bool = False):
        self.greedy = greedy
        # instance name follows the mode so the engine's decoder registry
        # never routes "sampling" requests to a greedy instance (or splits
        # one strategy into two groups); subclasses' class attrs agree
        self.name = "greedy" if greedy else "sampling"

    def stats(self) -> Dict:
        return {}

    def engine_decode(self, eng: "Engine", reqs: List[Request]) -> Dict:
        ec = eng.ec
        toks = np.zeros((ec.max_batch, 1), np.int32)
        # fixed-shape decode runs EVERY slot; inactive slots (empty or
        # mid-prefill) must not corrupt real cache entries, so their write
        # lands on the reserved scratch position cache_len-1 (requests are
        # capacity-checked to never reach it).
        pos = np.full(ec.max_batch, ec.cache_len - 1, np.int32)
        for r in reqs:
            toks[r._slot, 0] = eng.slot_last_tok[r._slot]
            pos[r._slot] = eng.slot_pos[r._slot]
        logits, eng.pool = eng._jit_decode(
            eng.params, eng.pool, jnp.asarray(toks), jnp.asarray(pos))
        eng.key, k1 = jax.random.split(eng.key)
        temp = 0.0 if self.greedy else ec.temperature
        nxt = np.asarray(sample_token(k1, logits, temperature=temp,
                                      top_k=ec.top_k, top_p=ec.top_p))
        emitted: Dict[int, List[int]] = {}
        for r in reqs:
            s = r._slot
            tok = int(nxt[s])
            eng.slot_last_tok[s] = tok
            eng.slot_pos[s] += 1
            emitted[s] = [tok]
        return emitted


def _make_default_decoder(name: str):
    if name in ("sampling", "greedy"):
        return SamplingEngineDecoder(greedy=(name == "greedy"))
    # strategy adapters live one layer up; resolve lazily to keep
    # repro.core importable without repro.api
    from repro.api.decoders import make_decoder
    return make_decoder(name)


def _make_compressor(name: str):
    # preset/parametric names ("fastv-0.5", "streaming-kv-64") resolve
    # one layer up; lazy for the same importability reason as decoders
    from repro.api.compressors import make_compressor
    return make_compressor(name)


def _slot_get(pool, slot):
    """Slice one slot's cache out of the pool as a batch-1 cache."""
    return jax.tree.map(lambda a: a[:, slot:slot + 1], pool)


def _slot_set(pool, slot, one):
    return jax.tree.map(lambda a, s: a.at[:, slot].set(s[:, 0]), pool, one)


class Engine:
    def __init__(self, model, params, ec: EngineConfig, *, decoder=None,
                 decoders: Optional[Dict] = None, compressor=None,
                 compressors: Optional[Dict] = None, tracer=None,
                 profiler=None):
        cfg = model.cfg
        self.ec = ec
        self.params = params
        # default compression strategy: an explicit strategy object wins;
        # otherwise wrap EngineConfig.compression (internal-layer path)
        self.compressor = compressor if compressor is not None \
            else CompressionStrategy(ec.compression)
        cc0 = getattr(self.compressor, "cc", ec.compression)
        compacting = (cc0.kv_selector in LIVE_KV_SELECTORS
                      and cc0.kv_budget > 0)
        if compacting and cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("KV compaction needs an attention-cache family")
        if compacting and cfg.use_mla:
            raise ValueError("engine KV compaction on the MLA latent cache "
                             "is not implemented (it is itself compressed)")
        if compacting and ec.prefix_cache:
            raise ValueError("prefix reuse + live compaction not composable "
                             "(compacted caches are request-specific)")
        self.compacting = compacting
        if compacting:
            # position-exact caches: full-length slot_pos ring (window off)
            cfg = cfg.with_(sliding_window=ec.cache_len)
            from repro.models.registry import build
            model = build(cfg)
        self.model = model
        self.cfg = cfg
        self.windowed = compacting

        self.pool = model.init_cache(ec.max_batch, ec.cache_len,
                                     windowed=self.windowed)
        self.slot_req: List[Optional[Request]] = [None] * ec.max_batch
        self.slot_pos = np.zeros(ec.max_batch, np.int64)   # next write pos
        self.slot_last_tok = np.zeros(ec.max_batch, np.int64)
        self.slot_nv = np.zeros(ec.max_batch, np.int64)    # visual offset

        kw: Dict = {}
        if ec.scheduler in ("continuous", "mlfq"):
            kw = dict(max_batch=ec.max_batch,
                      kv_capacity_tokens=self.kv_capacity_tokens)
        elif ec.scheduler == "chunked":
            kw = dict(max_batch=ec.max_batch, token_budget=ec.token_budget,
                      chunk_size=ec.chunk_size)
        elif ec.scheduler == "static":
            kw = dict(batch_size=ec.max_batch)
        self.sched = SCHEDULERS[ec.scheduler](**kw)

        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self.finished: List[Request] = []
        self.aborted: List[Request] = []
        self.clock = 0.0
        self.key = jax.random.PRNGKey(ec.seed)
        self.iters = 0
        # cumulative decode-phase virtual-clock cost per strategy group
        # (prefill cost is request-, not strategy-, attributed)
        self.group_costs: Dict[str, float] = {}
        # prefix cache: host map keyed by (compression variant, tokens) --
        # a prefill is only reusable under the SAME variant -- longest
        # block-aligned prefix match, true-LRU eviction (lookup hits
        # move-to-end; see _prefix_lookup)
        self._prefix: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        # in-flight pin counts, keyed like _prefix by (variant, tokens):
        # entries a live request hit stay resident (LRU eviction skips
        # them); released at retire/abort
        self._prefix_pins: Dict[Tuple[str, Tuple[int, ...]], int] = {}
        self.prefix_hit_tokens = 0
        self.prefix_total_tokens = 0
        # cluster-shared prefix tier (duck-typed: lookup/insert; installed
        # by repro.cluster so a prefix cached on ANY replica short-circuits
        # prefill here). Remote hits are installed locally and pay one
        # modeled KV-link transfer on this engine's clock.
        self.prefix_share = None
        self.remote_prefix_hits = 0
        self._iter_transfer_cost = 0.0
        # live KV migration (disaggregated serving): rid -> export ticket.
        # The ticket owns the source slot and any prefix pin from
        # ``export_kv`` until ``complete_export`` (source release) or
        # ``cancel_export`` (ownership back to the request).
        self._exports: Dict[int, Dict] = {}
        self.migrated_in = 0
        self.migrated_out = 0

        self._jit_prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len=ec.cache_len,
                                            windowed=self.windowed))
        self._jit_extend = jax.jit(self.model.extend)
        self._jit_decode = jax.jit(
            partial(self.model.decode_step, windowed=self.windowed))

        # decoder registry: the configured default plus named per-request
        # strategies; unknown names resolve lazily via repro.api.decoders
        # (validated on first use, so registering e.g. early_exit alongside
        # a compacting engine only errors if a request actually asks for it)
        self.decoder = decoder if decoder is not None \
            else _make_default_decoder(ec.decoder)
        self._decoders: Dict[str, object] = {}
        if decoders:
            self._decoders.update(decoders)
        self._default_name = getattr(self.decoder, "name", ec.decoder)
        self._decoders[self._default_name] = self.decoder
        self._validated = set()
        # names marked at submit: only strategies that actually serve a
        # request count toward decoder_stats()'s flat-vs-prefixed choice
        self._used_decoders: set = set()
        self._validate_decoder(self._default_name, self.decoder)

        # compressor registry: the default strategy plus named per-request
        # strategies; unknown names resolve lazily via repro.api (preset /
        # parametric grammar), validated on first use like decoders
        self._compressors: Dict[str, object] = {}
        if compressors:
            self._compressors.update(compressors)
        self._default_comp_name = getattr(self.compressor, "name", "none")
        self._compressors[self._default_comp_name] = self.compressor
        self._validated_comps: set = set()
        # per-strategy visual-token counters: name -> [in, out] (the
        # prefill-token-reduction signal compression_stats() reports)
        self._comp_counts: Dict[str, List[int]] = {}
        self._validate_compressor(self._default_comp_name, self.compressor)

        # observability: the tracer every instrumentation site guards on
        # (``if self.tracer.enabled:`` -- NULL_TRACER keeps the disabled
        # hot path call-free). ``trace_replica`` is this engine's track in
        # a fleet-shared trace; the Router assigns real indices.
        if tracer is None:
            from repro.obs.trace import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer
        self.trace_replica = 0

        # continuous profiling: same zero-overhead-when-off discipline as
        # the tracer -- every hot-path site guards on ``profiler.enabled``
        # and sites only read clocks, so profiled runs stay bit-identical
        if profiler is None:
            from repro.obs.profile import NULL_PROFILER
            profiler = NULL_PROFILER
        self.profiler = profiler

        # runtime sanitizer: resolved once (config wins over env)
        if ec.sanitize is not None:
            self.sanitize = bool(ec.sanitize)
        else:
            from repro.analysis.sanitizer import sanitize_enabled
            self.sanitize = sanitize_enabled()

    def _sanitize_check(self, where: str) -> None:
        """Raise ``SanitizerError`` if a conservation invariant is
        violated (slot/draft-row/pin/kv accounting; see
        repro.analysis.sanitizer). Called at step and abort boundaries
        when ``sanitize`` is on."""
        from repro.analysis.sanitizer import (assert_conserved,
                                              check_engine_conservation)
        assert_conserved(self, check_engine_conservation, where)

    # ----------------------------------------------------------- decoders --
    def _validate_decoder(self, name: str, dec) -> None:
        if name in self._validated:
            return
        validate = getattr(dec, "validate", None)
        if validate is not None:
            validate(self)
        self._validated.add(name)

    def _resolve_decoder(self, name: Optional[str]) -> Tuple[str, object]:
        """Per-request strategy resolution: None -> the engine default."""
        if name is None:
            return self._default_name, self.decoder
        dec = self._decoders.get(name)
        if dec is None:
            dec = _make_default_decoder(name)
            self._decoders[name] = dec
        self._validate_decoder(name, dec)
        return name, dec

    def decoder_stats(self) -> Dict:
        """Counters of every strategy that served a request. A single
        strategy reports flat keys (back-compat); a mixed run prefixes
        them with the strategy name."""
        names = [n for n in self._decoders if n in self._used_decoders]
        if not names:                     # nothing submitted yet
            names = [self._default_name]
        if len(names) == 1:
            return dict(self._decoders[names[0]].stats())
        out: Dict = {}
        for n in names:
            for k, v in self._decoders[n].stats().items():
                out[f"{n}/{k}"] = v
        return out

    # -------------------------------------------------------- compressors --
    def _validate_compressor(self, name: str, comp) -> None:
        if name in self._validated_comps:
            return
        validate = getattr(comp, "validate", None)
        if validate is not None:
            validate(self)
        self._validated_comps.add(name)

    def _resolve_compressor(self, name: Optional[str]) -> Tuple[str, object]:
        """Per-request compression resolution: None -> the engine default;
        otherwise a registered strategy or any preset/parametric name
        (resolved lazily, mirror of ``_resolve_decoder``)."""
        if name is None:
            return self._default_comp_name, self.compressor
        comp = self._compressors.get(name)
        if comp is None:
            comp = _make_compressor(name)
            self._compressors[name] = comp
        self._validate_compressor(name, comp)
        return name, comp

    def _stamp_compressed_nv(self, req: Request) -> None:
        """Resolve the request's strategy and stamp its POST-compression
        visual count (idempotent; the basis of all KV accounting)."""
        if req.nv_compressed is not None or req.visual_embeds is None:
            return
        _, comp = self._resolve_compressor(req.compression)
        req.nv_compressed = int(
            comp.compressed_token_count(len(req.visual_embeds)))

    def compression_stats(self) -> Dict[str, Dict]:
        """Per-strategy visual-token reduction of every strategy that
        compressed a request's prefill: ``{name: {visual_tokens_in,
        visual_tokens_out, prefill_token_reduction}}``."""
        out: Dict[str, Dict] = {}
        for name, (vin, vout) in self._comp_counts.items():
            out[name] = {
                "visual_tokens_in": vin,
                "visual_tokens_out": vout,
                "prefill_token_reduction":
                    (1.0 - vout / vin) if vin else 0.0,
            }
        return out

    # ------------------------------------------------------------- intake --
    def submit(self, req: Request) -> None:
        name, dec = self._resolve_decoder(req.decoder)
        self._used_decoders.add(name)
        cname, _comp = self._resolve_compressor(req.compression)
        req._comp_name = cname
        self._stamp_compressed_nv(req)
        # speculative slots verify up to gamma positions past the committed
        # stream: reserve that slack so block writes stay clear of the
        # scratch position (and schedulers account it as KV footprint)
        req.lookahead = max(req.lookahead,
                            int(getattr(dec, "lookahead_tokens", 0)))
        # capacity is checked against what actually lands in the cache:
        # the POST-compression prompt length
        need = req.kv_prompt_len + req.max_new_tokens + req.lookahead
        if need > self.ec.cache_len - 1:
            raise ValueError(
                f"request {req.rid} needs {need} tokens"
                f" (incl. {req.lookahead} decode lookahead);"
                f" cache_len-1 = {self.ec.cache_len - 1} available"
                " (last position is the inactive-slot scratch)")
        req.arrival = max(req.arrival, self.clock)
        self.waiting.append(req)
        if self.tracer.enabled:
            self.tracer.span_begin(
                "request", req.rid, replica=self.trace_replica,
                vt=self.clock, prompt_len=req.prompt_len,
                decoder=name, compression=cname)

    # -------------------------------------------------- kv accounting --
    @property
    def kv_capacity_tokens(self) -> int:
        """Token capacity admission budgets against (dense pool size unless
        EngineConfig.kv_capacity_tokens narrows it)."""
        if self.ec.kv_capacity_tokens is not None:
            return self.ec.kv_capacity_tokens
        return self.ec.max_batch * self.ec.cache_len

    def _kv_block(self) -> int:
        return int(getattr(self.sched, "block_size", 16))

    def kv_request_tokens(self, req: Request) -> int:
        """Block-rounded KV reservation one request commits the pool to:
        POST-compression prompt + max_new + decode lookahead (speculative
        gamma AND the compression strategy resolve via the request even
        before submit, so admission watermarks and ``least_kv`` routing
        never over-reserve for tokens the pruner will drop)."""
        la = req.lookahead
        if req.decoder is not None or la == 0:
            _, dec = self._resolve_decoder(req.decoder)
            la = max(la, int(getattr(dec, "lookahead_tokens", 0)))
        self._stamp_compressed_nv(req)
        bs = self._kv_block()
        if req.handoff and not getattr(req, "_imported", False):
            # prefill-role accounting: a handoff request decodes on the
            # importing engine -- this pool only ever holds its prompt KV
            # plus the first token, so reserving max_new here would let
            # one video burst starve the prefill replica's admission
            need = req.kv_prompt_len + 1
        else:
            need = req.kv_prompt_len + req.max_new_tokens + la
        return ((need + bs - 1) // bs) * bs

    def kv_committed_tokens(self, include_waiting: bool = True) -> int:
        """Total KV reservation of live requests (the admission-control
        pressure signal; returns to baseline after finish/abort)."""
        live = [r for r in self.running if r.state != State.DONE]
        if include_waiting:
            live += [r for r in self.waiting if r.state != State.DONE]
        return sum(self.kv_request_tokens(r) for r in live)

    # -------------------------------------------------------- lifecycle --
    def _release_request(self, r: Request) -> None:
        """Free every resource a request holds: its slot in the main pool,
        any strategy-held per-slot state (speculative draft-pool row), and
        its prefix-cache pin. The gamma lookahead reservation is freed
        implicitly: capacity accounting only counts live requests."""
        slot = getattr(r, "_slot", None)
        if slot is not None and self.slot_req[slot] is r:
            self.slot_req[slot] = None
            for dec in self._decoders.values():
                release = getattr(dec, "release_slot", None)
                if release is not None:
                    release(slot)
        key = getattr(r, "_prefix_pin", None)
        if key is not None:
            n = self._prefix_pins.get(key, 0) - 1
            if n > 0:
                self._prefix_pins[key] = n
            else:
                self._prefix_pins.pop(key, None)
            r._prefix_pin = None

    def abort(self, rid: int) -> bool:
        """Cancel a request mid-flight (the serving layer's cancellation
        path). Frees the main KV slot, the speculative draft-pool slot,
        the reserved lookahead, and any prefix-cache pin; the request is
        marked ``aborted`` and never reaches ``finished``. Returns False
        if ``rid`` is unknown or already retired."""
        for pool in (self.waiting, self.running):
            for r in pool:
                if r.rid == rid and r.state != State.DONE:
                    pool.remove(r)
                    self._release_request(r)
                    r.state = State.DONE
                    r.aborted = True
                    self.aborted.append(r)
                    if self.tracer.enabled:
                        # closes the request span AND any open stage span
                        # (prefill, kv_migration) so an abort never
                        # orphans part of the trace
                        self.tracer.span_abort(rid,
                                               replica=self.trace_replica,
                                               vt=self.clock)
                    if self.sanitize:
                        self._sanitize_check(f"Engine.abort(rid={rid})")
                    return True
        return False

    # ---------------------------------------------------------- migration --
    # Live KV migration protocol (disaggregated prefill/decode, drain):
    #   export_kv (source pin) -> import_kv (target commit) ->
    #   complete_export (source release), or cancel_export to back out.
    # The exporting request stays in ``running`` in State.MIGRATING and
    # keeps its slot until the source release, so a target-side failure
    # before commit loses nothing (exactly-once: the request either
    # resumes here via cancel_export or decodes exactly once over there).

    def can_export(self, req: Request) -> bool:
        """True when this engine can hand the request's KV to a sibling:
        compacted caches are request-specific (position-masked rings) and
        decoders with per-slot state (speculative draft-pool rows) cannot
        be rebuilt from a bare KV snapshot on the importing side."""
        if self.compacting:
            return False
        _, dec = self._resolve_decoder(req.decoder)
        return getattr(dec, "release_slot", None) is None

    def export_kv(self, rid: int) -> Dict:
        """Pin a live request for migration and snapshot its KV.

        Returns the export ticket: the host-side snapshot of the slot's
        cache up to the current position plus the per-slot cursors and the
        source clock (the transfer-time anchor). The ticket owns the
        source slot and any prefix pin until ``complete_export`` /
        ``cancel_export``; the request stops decoding here (MIGRATING)."""
        req = next((r for r in self.running
                    if r.rid == rid
                    and r.state in (State.DECODE, State.MIGRATING)), None)
        if req is None:
            raise KeyError(f"export_kv: rid {rid} is not migratable here")
        if rid in self._exports:
            raise RuntimeError(f"export_kv: rid {rid} already has an "
                               "export pin")
        if not self.can_export(req):
            raise RuntimeError(
                f"export_kv: rid {rid} is not exportable (compacted cache "
                "or per-slot decoder state)")
        slot = req._slot
        pos = int(self.slot_pos[slot])
        if self.profiler.enabled:
            self.profiler.site_begin("kv_export")
        snap = jax.tree.map(lambda a: a[:, :, :pos],
                            _slot_get(self.pool, slot))
        if self.profiler.enabled:
            self.profiler.site_end("kv_export")
        ticket = {
            "rid": rid, "req": req, "snap": snap, "pos": pos,
            "last_tok": int(self.slot_last_tok[slot]),
            "nv": int(self.slot_nv[slot]),
            "slot": slot, "clock": self.clock,
            "prefix_pin": getattr(req, "_prefix_pin", None),
        }
        # pin ownership moves to the ticket: the target never inherits the
        # source's prefix pin, and the source release must still find it
        # after the target overwrites the request's slot binding
        req._prefix_pin = None
        req._export_pin = rid
        req.state = State.MIGRATING
        self._exports[rid] = ticket
        if self.tracer.enabled:
            self.tracer.span_begin(
                "kv_migration", rid, replica=self.trace_replica,
                vt=self.clock, kv_tokens=pos)
            self.tracer.counter(
                "migration_bytes_inflight", self._export_bytes_inflight(),
                replica=self.trace_replica, vt=self.clock)
        return ticket

    def _export_bytes_inflight(self) -> int:
        """Modeled bytes of every KV snapshot currently pinned for
        migration out of this engine (a trace counter track)."""
        bpt = int(getattr(self.ec.cost, "kv_bytes_per_token", 0))
        return sum(int(t["pos"]) for t in self._exports.values()) * bpt

    def complete_export(self, rid: int) -> None:
        """Source-side release of a migrated request: the importing engine
        has committed, so free everything the export ticket owns -- the
        slot (and any decoder per-slot row), the prefix pin, and the
        running-list entry. Never touches ``req.state``: the importing
        engine owns the request now."""
        ticket = self._exports.pop(rid)
        req = ticket["req"]
        self.running.remove(req)
        slot = ticket["slot"]
        if self.slot_req[slot] is req:
            self.slot_req[ticket["slot"]] = None
            for dec in self._decoders.values():
                release = getattr(dec, "release_slot", None)
                if release is not None:
                    release(slot)
        key = ticket["prefix_pin"]
        if key is not None:
            n = self._prefix_pins.get(key, 0) - 1
            if n > 0:
                self._prefix_pins[key] = n
            else:
                self._prefix_pins.pop(key, None)
        req._export_pin = None
        self.migrated_out += 1
        if self.tracer.enabled:
            self.tracer.instant("kv_export_complete", rid,
                                replica=self.trace_replica, vt=self.clock)
            self.tracer.counter(
                "migration_bytes_inflight", self._export_bytes_inflight(),
                replica=self.trace_replica, vt=self.clock)
        if self.sanitize:
            self._sanitize_check(f"Engine.complete_export(rid={rid})")

    def cancel_export(self, rid: int) -> None:
        """Back out an export (no sibling could import): the request
        resumes decoding HERE -- pin ownership returns to it, and its
        handoff flag clears so KV accounting covers the in-place decode."""
        ticket = self._exports.pop(rid, None)
        if ticket is None:
            return
        req = ticket["req"]
        req._prefix_pin = ticket["prefix_pin"]
        req._export_pin = None
        req.handoff = False
        req.state = State.DECODE
        if self.tracer.enabled:
            self.tracer.span_end("kv_migration", rid,
                                 replica=self.trace_replica,
                                 vt=self.clock, cancelled=True)
            self.tracer.counter(
                "migration_bytes_inflight", self._export_bytes_inflight(),
                replica=self.trace_replica, vt=self.clock)
        if self.sanitize:
            self._sanitize_check(f"Engine.cancel_export(rid={rid})")

    def import_kv(self, req: Request, ticket: Dict, *,
                  ready_at: float = 0.0) -> None:
        """Import-commit side of a migration: bind a free slot, restore
        the exported KV snapshot and per-slot cursors, and resume the
        request in DECODE. Its first decode step here is gated on
        ``ready_at`` (source export clock + modeled KV-link transfer), so
        the transfer cost lands on this engine's virtual clock before the
        request's next token. Raises when no slot is free or the snapshot
        cannot fit -- the caller still holds the source pin and may try a
        sibling or cancel."""
        if self.compacting:
            raise RuntimeError("import_kv: compacting engines cannot host "
                               "migrated KV (position-masked caches)")
        if any(r.rid == req.rid for r in self.running + self.waiting):
            raise ValueError(f"import_kv: rid {req.rid} already live here")
        name, _dec = self._resolve_decoder(req.decoder)
        self._used_decoders.add(name)
        cname, _comp = self._resolve_compressor(req.compression)
        req._comp_name = cname
        pos = int(ticket["pos"])
        remaining = req.max_new_tokens - len(req.generated)
        if pos + remaining > self.ec.cache_len - 1:
            raise ValueError(
                f"import_kv: rid {req.rid} needs {pos + remaining} tokens; "
                f"cache_len-1 = {self.ec.cache_len - 1} available")
        slot = self._free_slot()
        req._slot = slot
        self.slot_req[slot] = req
        if self.profiler.enabled:
            self.profiler.site_begin("kv_transfer")
        self._install_snap(slot, ticket["snap"])
        if self.profiler.enabled:
            # virtual attribution: the modeled KV-link transfer this
            # import pays on the target clock (cf. ``ready_at``)
            self.profiler.site_end(
                "kv_transfer", vt=self.ec.cost.transfer_time(pos))
        self.slot_pos[slot] = pos
        self.slot_last_tok[slot] = ticket["last_tok"]
        self.slot_nv[slot] = ticket["nv"]
        req._imported = True
        req._ready_at = max(self.clock, ready_at)
        req.state = State.DECODE
        req.prefill_done = len(req.tokens)
        self.migrated_in += 1
        self.running.append(req)
        if self.tracer.enabled:
            # the import commit closes the migration span ON THE TARGET
            # replica and hands the request's trace track over with it
            # (Tracer ownership follows the kv_migration end). ``vt`` is
            # the transfer-complete time -- >= the source's export clock,
            # so the request's virtual timeline never rewinds across the
            # replica boundary.
            self.tracer.span_end(
                "kv_migration", req.rid, replica=self.trace_replica,
                vt=req._ready_at, kv_tokens=pos)
        if self.sanitize:
            self._sanitize_check(f"Engine.import_kv(rid={req.rid})")

    # ------------------------------------------------------------- prefix --
    def _prefix_variant(self, name: Optional[str]) -> str:
        """Compression-variant component of every prefix-cache key: the
        request's strategy name (None -> the engine default). A cached
        prefill is only reusable under the SAME compression variant -- a
        ``fastv-0.5`` prefill must never serve a ``none`` lookup."""
        return name if name is not None else self._default_comp_name

    def _prefix_lookup(self, tokens: List[int], touch: bool = True,
                       variant: Optional[str] = None
                       ) -> Tuple[int, Optional[Tuple]]:
        """Longest block-aligned cached prefix of ``tokens`` under the
        given compression ``variant``.

        Inserted keys are always multiples of ``prefix_block``, so probing
        descending block-aligned lengths is exact and O(len/block) probes
        per prefill instead of the old O(#entries x prefix_len) scan. A hit
        is an LRU touch (move-to-end) unless ``touch=False`` -- the pure
        probe routing layers use (cluster prefix-affinity), where only a
        real prefill hit should refresh recency."""
        bs = self.ec.prefix_block
        v = self._prefix_variant(variant)
        t = tuple(tokens)
        best_k, best = 0, None
        for k in range((len(t) // bs) * bs, 0, -bs):
            hit = self._prefix.get((v, t[:k]))
            if hit is not None:
                best_k, best = k, hit
                break
        if self.prefix_share is not None:
            if self.profiler.enabled:
                self.profiler.site_begin("prefix_tier_probe")
            rk, rsnap = self.prefix_share.lookup(v, t, block=bs, touch=touch)
            if self.profiler.enabled:
                self.profiler.site_end("prefix_tier_probe")
            if rk > best_k:
                # remote hit beats the local one: install it locally (one
                # modeled KV-link transfer, charged to this step's clock)
                # so later lookups here are local
                if touch:
                    if self.profiler.enabled:
                        self.profiler.site_begin("prefix_tier_install")
                    self._prefix_store((v, t[:rk]), rsnap, rk)
                    self._iter_transfer_cost += self.ec.cost.transfer_time(rk)
                    self.remote_prefix_hits += 1
                    if self.profiler.enabled:
                        self.profiler.site_end(
                            "prefix_tier_install",
                            vt=self.ec.cost.transfer_time(rk))
                return rk, (rsnap, rk)
        if best is not None:
            if touch:
                self._prefix.move_to_end((v, t[:best_k]))
            return best_k, best
        return 0, None

    def _prefix_insert(self, tokens: List[int], slot: int, length: int,
                       variant: Optional[str] = None):
        bs = self.ec.prefix_block
        k = (min(length, len(tokens)) // bs) * bs
        if k == 0:
            return
        key = (self._prefix_variant(variant), tuple(tokens[:k]))
        if key in self._prefix:
            self._prefix.move_to_end(key)            # re-insert = LRU touch
            return
        snap = jax.tree.map(lambda a: a[:, :, :k], _slot_get(self.pool, slot))
        self._prefix_store(key, snap, k)
        if self.prefix_share is not None:
            # publish to the cluster-shared tier: a sibling replica's next
            # prefill of this prefix short-circuits via the tier
            if self.profiler.enabled:
                self.profiler.site_begin("prefix_tier_install")
            self.prefix_share.insert(key[0], key[1], snap, k)
            if self.profiler.enabled:
                self.profiler.site_end("prefix_tier_install")

    def _prefix_store(self, key: Tuple, snap, k: int) -> None:
        """Insert an entry into the LOCAL prefix cache with LRU eviction
        (shared by local inserts and shared-tier hit installs)."""
        if key in self._prefix:
            self._prefix.move_to_end(key)
            return
        self._prefix[key] = (snap, k)
        while len(self._prefix) > self.ec.prefix_cap:
            # least-recent UNPINNED entry; pinned ones (a live request hit
            # them) stay resident until their requests retire/abort
            victim = next((c for c in self._prefix
                           if not self._prefix_pins.get(c)), None)
            if victim is None:
                break
            del self._prefix[victim]

    def _install_snap(self, slot: int, snap) -> None:
        def put(a, s):
            return a.at[:, slot].set(
                jax.lax.dynamic_update_slice_in_dim(a[:, slot], s[:, 0], 0,
                                                    axis=1))
        self.pool = jax.tree.map(put, self.pool, snap)

    # ------------------------------------------------------------ prefill --
    def _free_slot(self) -> int:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        raise RuntimeError("no free slot (scheduler overcommitted)")

    def _prompt_query_embeds(self, req: Request):
        """Text-prompt embeddings [1, Q, d] for cross-modal pruners
        (sparsevlm / cdpruner rank visual tokens by instruction
        relevance). The prompt IS known at prefill time, so the engine
        threads it instead of the old silent ``query=None`` degradation
        to query-free behavior."""
        if not req.tokens or not isinstance(self.params, dict) \
                or "embed" not in self.params:
            return None
        from repro.models.layers import embed_tokens
        return embed_tokens(self.params["embed"],
                            jnp.asarray([req.tokens], jnp.int32))

    def _do_prefill_chunk(self, req: Request, n: int) -> None:
        ec = self.ec
        n = min(n, len(req.tokens) - req.prefill_done)
        if n <= 0:
            return
        first_chunk = req.prefill_done == 0
        # hot-path site: the whole chunk (compression, prefix probe and
        # forward) -- nested sites (compress, prefix_tier_*) subtract from
        # this site's SELF time, leaving the forward pass itself
        if self.profiler.enabled:
            self.profiler.site_begin("prefill_forward")
        comp_name = getattr(req, "_comp_name", None) \
            or self._default_comp_name
        if req.prefill_done == 0:
            slot = self._free_slot()
            req._slot = slot
            self.slot_req[slot] = req
            if self.tracer.enabled:
                self.tracer.span_begin("prefill", req.rid,
                                       replica=self.trace_replica,
                                       slot=slot, vt=self.clock)
            # dim 1: the request's compression strategy runs before the
            # visual tokens enter the backbone
            ve = req.visual_embeds
            if ve is not None:
                _, comp = self._resolve_compressor(req.compression)
                nv_in = len(ve)
                if self.tracer.enabled:
                    # vision tokens entering the backbone: the wall-time
                    # delta of this span is the real compression cost the
                    # virtual clock does not model
                    self.tracer.span_begin("compress", req.rid,
                                           replica=self.trace_replica,
                                           vt=self.clock, strategy=comp_name,
                                           nv_in=nv_in)
                if self.profiler.enabled:
                    self.profiler.site_begin("compress")
                if getattr(comp, "encoder_active", True):
                    # the query embed is only built for strategies that
                    # consume it (custom strategies default to yes)
                    q = self._prompt_query_embeds(req) \
                        if getattr(comp, "needs_query", True) else None
                    ve_j, _, _ = comp.compress_prefill(
                        jnp.asarray(ve)[None], query=q)
                    ve = np.asarray(ve_j[0])
                if self.profiler.enabled:
                    self.profiler.site_end("compress")
                cnt = self._comp_counts.setdefault(comp_name, [0, 0])
                cnt[0] += nv_in
                cnt[1] += len(ve)
                if self.tracer.enabled:
                    self.tracer.span_end("compress", req.rid,
                                         replica=self.trace_replica,
                                         vt=self.clock, nv_out=len(ve))
            req._ve = ve
            self.slot_nv[slot] = 0 if ve is None else len(ve)
            # visual tokens are prefill work too (the dim-1 latency claim)
            self._iter_visual_tokens += int(self.slot_nv[slot])
        slot = req._slot
        nv = int(self.slot_nv[slot])
        start, end = req.prefill_done, req.prefill_done + n

        if req.prefill_done == 0:
            # dim 2b: prefix reuse (text-token prompts), keyed by the
            # request's compression variant
            use, hit = 0, None
            if ec.prefix_cache and req._ve is None:
                hit_k, hit = self._prefix_lookup(req.tokens,
                                                 variant=comp_name)
                self.prefix_total_tokens += len(req.tokens)
                # always recompute >=1 token so we have last-position logits
                use = min(hit_k, len(req.tokens) - 1, end - 1)
            if hit is not None and use > 0:
                key = (comp_name, tuple(req.tokens[:hit_k]))
                self._prefix_pins[key] = self._prefix_pins.get(key, 0) + 1
                req._prefix_pin = key
                snap, _k = hit
                self._install_snap(
                    slot, jax.tree.map(lambda a: a[:, :, :use], snap))
                self.prefix_hit_tokens += use
                one = _slot_get(self.pool, slot)
                sub = jnp.asarray(req.tokens[use:end], jnp.int32)[None]
                logits, one = self._jit_extend(self.params, one, sub,
                                               jnp.int32(use))
                self.pool = _slot_set(self.pool, slot, one)
            else:
                chunk = jnp.asarray(req.tokens[:end], jnp.int32)[None]
                batch = {"tokens": chunk}
                if req._ve is not None:
                    batch["visual_embeds"] = jnp.asarray(req._ve)[None]
                logits, one = self._jit_prefill(self.params, batch)
                self.pool = _slot_set(self.pool, slot, one)
        else:
            chunk = jnp.asarray(req.tokens[start:end], jnp.int32)[None]
            one = _slot_get(self.pool, slot)
            logits, one = self._jit_extend(self.params, one, chunk,
                                           jnp.int32(nv + start))
            self.pool = _slot_set(self.pool, slot, one)

        req.prefill_done = end
        self.slot_pos[slot] = nv + end
        if self.tracer.enabled:
            self.tracer.instant("prefill_chunk", req.rid,
                                replica=self.trace_replica, slot=slot,
                                vt=self.clock, tokens=n)
        if req.prefill_done >= len(req.tokens):
            # prompt complete: first token comes from the last logits
            if ec.prefix_cache and req._ve is None:
                self._prefix_insert(req.tokens, slot, end,
                                    variant=comp_name)
            if self.compacting:
                # dim 2a: KV-side hook of the request's strategy -- on a
                # compacting (windowed) engine each request compacts to
                # its OWN budget; strategies without one skip compaction
                _, comp = self._resolve_compressor(req.compression)
                budget = getattr(comp, "decode_budget", lambda: None)()
                if budget:
                    self._compact_slot(
                        slot, getattr(comp, "kv_selector", "streaming"),
                        budget)
            self.key, k1 = jax.random.split(self.key)
            _, dec = self._resolve_decoder(req.decoder)
            temp = 0.0 if getattr(dec, "greedy", False) else ec.temperature
            tok = int(sample_token(k1, logits[:, -1], temperature=temp,
                                   top_k=ec.top_k, top_p=ec.top_p)[0])
            req.generated.append(tok)
            req._needs_ttft = True
            self.slot_last_tok[slot] = tok
            if self.tracer.enabled:
                self.tracer.span_end("prefill", req.rid,
                                     replica=self.trace_replica, slot=slot,
                                     vt=self.clock)
            if req.is_finished() or tok == ec.eos_id:
                req.state = State.DONE
            elif req.handoff and self.can_export(req):
                # disaggregated prefill: park for KV export (the serving
                # layer migrates it to a decode replica) instead of
                # entering this engine's decode loop
                req.state = State.MIGRATING
            else:
                req.handoff = False       # not exportable: decode in place
                req.state = State.DECODE
            if req in self.waiting:
                self.waiting.remove(req)
            self.running.append(req)
        if self.profiler.enabled:
            # virtual attribution: the chunk's share of this step's
            # modeled prefill cost (visual tokens enter on chunk 0)
            nv_chunk = int(self.slot_nv[slot]) if first_chunk else 0
            self.profiler.site_end(
                "prefill_forward", vt=ec.cost.prefill_time(n + nv_chunk))

    # ------------------------------------------------------ KV compaction --
    def _compact_slot(self, slot: int, selector: str, budget: int) -> None:
        """dim 2a: evict down to ``budget`` with exact position bookkeeping
        (selector/budget come from the REQUEST's compression strategy).

        Retained entries keep their ORIGINAL positions in ``slot_pos`` (the
        RoPE-consistency requirement the survey's §V flags); evicted slots
        are masked with -1. Dense-slot memory is not reclaimed (that is the
        paged pool's job) -- what the engine proves is output fidelity under
        the eviction policy.
        """
        pos_end = int(self.slot_pos[slot])
        if pos_end <= budget:
            return
        sel = SELECTORS[selector]
        lc = self.pool["layers"]
        k = lc["k"][:, slot, :pos_end]            # [L, S, H, D]
        v = lc["v"][:, slot, :pos_end]
        sp = lc["slot_pos"][:, slot, :pos_end]    # [L, S]

        def one(k_l, v_l, sp_l):
            nk, nv_, kept = sel(k_l[None], v_l[None], budget=budget,
                                pos=sp_l)
            return nk[0], nv_[0], kept[0]

        nk, nv_, kept = jax.vmap(one)(k, v, sp)   # [L,budget,...]
        s_full = lc["k"].shape[2]
        pad = s_full - budget
        nk = jnp.pad(nk, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nv_ = jnp.pad(nv_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nsp = jnp.pad(kept.astype(jnp.int32), ((0, 0), (0, pad)),
                      constant_values=-1)
        self.pool = dict(self.pool, layers=dict(
            lc,
            k=lc["k"].at[:, slot].set(nk.astype(lc["k"].dtype)),
            v=lc["v"].at[:, slot].set(nv_.astype(lc["v"].dtype)),
            slot_pos=lc["slot_pos"].at[:, slot].set(nsp)))

    # ------------------------------------------------------------- decode --
    def _decode_iteration(self, reqs: List[Request]) -> None:
        """One decode iteration through the pluggable decoder hooks.

        Decode-phase slots are GROUPED by each request's resolved strategy
        (``Request.decoder`` or the engine default) and each group's
        decoder runs once over its whole group -- batched speculative runs
        every speculative slot per jitted draft/verify call. Decoders run
        the forward pass(es) and slot bookkeeping and may emit MULTIPLE
        tokens per request per iteration (speculative); the engine applies
        request bookkeeping and stop conditions (eos emitted mid-block
        truncates the block: nothing is appended past DONE).

        Each group is charged its TRUE virtual-clock cost: the group's
        decoder may report one via ``_iter_decode_cost`` (speculative's
        block-verify + amortized draft steps, early-exit's executed-layer
        fraction); otherwise the group pays one plain batched decode step.
        Costs sum into the iteration's total.
        """
        groups: Dict[str, List[Request]] = {}
        for r in reqs:
            name, _ = self._resolve_decoder(r.decoder)
            groups.setdefault(name, []).append(r)
        total_cost = 0.0
        emitted_all: Dict[int, List[int]] = {}
        for name, group in groups.items():
            dec = self._decoders[name]
            self._iter_decode_cost = None
            if self.profiler.enabled:
                self.profiler.site_begin(f"decode:{name}")
            emitted_all.update(dec.engine_decode(self, group))
            if self._iter_decode_cost is None:
                ctx = float(np.mean([self.slot_pos[r._slot] for r in group]))
                cost = self.ec.cost.decode_step_time(len(group), ctx)
            else:
                cost = self._iter_decode_cost
            if self.profiler.enabled:
                # per-group launch: wall covers the decoder's jitted
                # forward(s); virtual is the group's true modeled cost
                self.profiler.site_end(f"decode:{name}", vt=cost)
            total_cost += cost
            self.group_costs[name] = self.group_costs.get(name, 0.0) + cost
            if self.tracer.enabled:
                # one lane slice per decoder group per iteration: where
                # the virtual decode cost of a mixed fleet actually goes
                self.tracer.slice(f"decode:{name}", self.clock, cost,
                                  replica=self.trace_replica,
                                  batch=len(group))
        self._iter_decode_cost = total_cost
        for r in reqs:
            for tok in emitted_all.get(r._slot, ()):
                r.generated.append(tok)
                r.served_tokens += 1
                if r.is_finished() or tok == self.ec.eos_id:
                    r.state = State.DONE
                    break

    # --------------------------------------------------------------- step --
    def step(self) -> bool:
        """One scheduler iteration. Returns False when fully idle."""
        self.running = [r for r in self.running if r.state != State.DONE]
        visible = [r for r in self.waiting if r.arrival <= self.clock]
        plan = self.sched.plan(visible, self.running)
        # decode only requests whose KV is resident AND ready: an imported
        # request waits out its modeled transfer (``_ready_at``) first, a
        # MIGRATING request is frozen until export completes or cancels
        decode_reqs = [r for r in plan.decode if r.state == State.DECODE
                       and getattr(r, "_ready_at", 0.0) <= self.clock]
        if not plan.prefill and not decode_reqs:
            future = [r.arrival for r in self.waiting
                      if r.arrival > self.clock]
            future += [r._ready_at for r in self.running
                       if r.state == State.DECODE
                       and getattr(r, "_ready_at", 0.0) > self.clock]
            if future:                  # idle until arrival / KV readiness
                self.clock = min(future)
                return True
            return False
        self._iter_visual_tokens = 0
        self._iter_transfer_cost = 0.0    # shared-prefix-tier installs
        for req, n in plan.prefill:
            self._do_prefill_chunk(req, n)
        self._iter_decode_cost = 0.0      # summed per strategy group
        if decode_reqs:
            self._decode_iteration(decode_reqs)
        # virtual clock
        vt0 = self.clock
        dt = self.ec.cost.prefill_time(plan.prefill_tokens
                                       + self._iter_visual_tokens)
        dt += self._iter_decode_cost + self._iter_transfer_cost
        self.clock += dt
        self.iters += 1
        if self.tracer.enabled:
            self.tracer.slice("engine_step", vt0, dt,
                              replica=self.trace_replica,
                              prefill_tokens=plan.prefill_tokens,
                              decode_batch=len(decode_reqs))
            for r in decode_reqs:
                self.tracer.slice("decode_step", vt0, dt,
                                  replica=self.trace_replica,
                                  slot=r._slot, rid=r.rid)
        # stamp times & retire
        seen, stampable = set(), []
        for r in self.running + [r for r, _ in plan.prefill]:
            if id(r) not in seen:
                seen.add(id(r))
                stampable.append(r)
        for r in stampable:
            if getattr(r, "_needs_ttft", False):
                r.first_token_time = self.clock
                r._needs_ttft = False
                if self.tracer.enabled:
                    self.tracer.instant("first_token", r.rid,
                                        replica=self.trace_replica,
                                        vt=self.clock)
            if r.state == State.DONE and r.finish_time is None:
                r.finish_time = self.clock
                self.finished.append(r)
                self._release_request(r)
                if self.tracer.enabled:
                    self.tracer.span_end("request", r.rid,
                                         replica=self.trace_replica,
                                         vt=self.clock,
                                         tokens=len(r.generated))
        self.running = [r for r in self.running if r.state != State.DONE]
        if self.sanitize:
            self._sanitize_check(f"Engine.step (iter {self.iters})")
        return True

    def run(self, max_iters: int = 100000) -> Dict:
        it = 0
        while self.step():
            it += 1
            if it >= max_iters:
                break
        out = summarize(self.finished)
        out["iterations"] = self.iters
        out["virtual_time_s"] = self.clock
        if self.ec.prefix_cache:
            out["prefix_hit_tokens"] = self.prefix_hit_tokens
            out["prefix_token_hit_rate"] = (
                self.prefix_hit_tokens / max(1, self.prefix_total_tokens))
        return out
