"""``Router``: one submit surface over N ``AsyncLVLMServer`` replicas.

The router keeps the server's contract -- ``async for tok in
router.submit(req)`` -- while dispatching each request to a replica via a
routing policy (round-robin / least-KV / prefix-affinity), so a fleet of
engines (possibly heterogeneous: different compression presets, decoder
defaults, draft models per replica) serves one open-loop request stream:

    router = lvlm.serve_cluster(replicas=2, routing="prefix_affinity")
    async with router:
        async for tok in router.submit(req):
            ...

Roles (disaggregated serving, DistServe-style):

  * ``unified``  -- prefills AND decodes (the default; a role-less fleet
                    behaves exactly as before).
  * ``prefill``  -- runs the vision encoder + chunked prefill, then hands
                    the post-compression KV to a decode replica over the
                    modeled KV link (``CostModel.transfer_time`` charged
                    on the importer's virtual clock before its first
                    decode step there).
  * ``decode``   -- takes no fresh submits; hosts migrated-in KV and
                    decodes it.

Lifecycle:

  * healthy   -- takes new work.
  * draining  -- ``router.drain(i)``: the policy never offers it new
                 requests AND its live KV migrates out to healthy
                 decode-capable siblings (streams stay token-identical;
                 with no sibling the in-flight streams simply finish
                 here). ``undrain`` reverses it while the pump is alive.
  * dead      -- the replica's pump raised. Its queued-but-UNSTARTED
                 requests (nothing generated yet) FAIL OVER to a healthy
                 sibling transparently. Requests that had already
                 streamed tokens re-raise to their consumer (the tokens
                 cannot be un-sent); the router never re-runs a request
                 that may have observable output.

When the fleet is only TRANSIENTLY without a healthy prefill-capable
replica (everything alive is draining), ``submit`` does not fail: the
stream PARKS router-side and dispatches on ``undrain``. Only a fleet
with every replica dead raises.

Failover and migration are consumer-driven: the pump surfaces a failure
(or a ``MigrateSignal``) on the stream's next ``__anext__``; the
``RouterStream`` catches it and re-dispatches / runs the migration
protocol (source ``export_kv`` -> sibling ``import_stream`` -> source
release) from the consumer task, with no await between the import commit
and the source release -- a request is never live on two engines outside
that atomic window, and never absent from both. Everything is
event-loop-confined, like the serving layer underneath.
"""
from __future__ import annotations

import asyncio
from typing import Dict, List, Optional, Sequence

from repro.core.serving.request import Request, State
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.policies import make_policy
from repro.cluster.prefix_tier import SharedPrefixTier
from repro.serving.server import AsyncLVLMServer, MigrateSignal, TokenStream

ROLES = ("unified", "prefill", "decode")


class Replica:
    """One ``AsyncLVLMServer`` plus its fleet-facing state and counters."""

    def __init__(self, index: int, server: AsyncLVLMServer,
                 role: str = "unified"):
        if role not in ROLES:
            raise ValueError(f"unknown replica role {role!r} "
                             f"(expected one of {ROLES})")
        self.index = index
        self.server = server
        self.role = role
        self.draining = False
        self.dispatched = 0           # requests routed here (incl. retries)
        self.completed = 0            # streams finished here (not aborted)
        self.inflight: Dict[int, Request] = {}   # rid -> assigned request

    # ------------------------------------------------------------ health --
    @property
    def dead(self) -> bool:
        return self.server._pump_error is not None

    @property
    def state(self) -> str:
        if self.dead:
            return "dead"
        return "draining" if self.draining else "ok"

    @property
    def error(self) -> Optional[BaseException]:
        return self.server._pump_error

    # -------------------------------------------------------------- role --
    @property
    def can_prefill(self) -> bool:
        return self.role in ("unified", "prefill")

    @property
    def can_decode(self) -> bool:
        return self.role in ("unified", "decode")

    @property
    def migrated_in(self) -> int:
        return self.server.engine.migrated_in

    @property
    def migrated_out(self) -> int:
        return self.server.engine.migrated_out

    # ------------------------------------------------- policy observables --
    def kv_load(self) -> float:
        """KV-reservation fraction of every live request ASSIGNED here --
        admitted or not (a dispatched request will commit its reservation
        the moment its consumer starts, so a join-the-shortest-queue
        policy must see it immediately, not after first ``__anext__``)."""
        eng = self.server.engine
        need = sum(eng.kv_request_tokens(r) for r in self.inflight.values()
                   if r.state is not State.DONE)
        return need / max(1, eng.kv_capacity_tokens)

    def queue_depth(self) -> int:
        return self.server.admission.queue_depth

    def prefix_block(self) -> int:
        return self.server.engine.ec.prefix_block

    def cached_prefix_len(self, tokens: Sequence[int],
                          compression: Optional[str] = None) -> int:
        """Longest block-aligned prefix of ``tokens`` this replica's
        engine caches UNDER the request's compression variant (None ->
        the replica's default strategy). Pure probe (``touch=False``): no
        LRU refresh -- only a real prefill hit should touch recency."""
        eng = self.server.engine
        if not eng.ec.prefix_cache:
            return 0
        k, _hit = eng._prefix_lookup([int(x) for x in tokens], touch=False,
                                     variant=compression)
        return k


class RouterStream:
    """One routed request's token channel: the ``TokenStream`` contract
    (async iteration, ``cancel()``, ``tokens``, ``aborted``) plus
    transparent failover while the request is still unstarted, parking
    while no healthy replica can take it, and consumer-side migration
    (prefill->decode handoff, drain) on ``MigrateSignal``."""

    def __init__(self, router: "Router", request: Request):
        self._router = router
        self.request = request
        self.replica: Optional[Replica] = None
        self._inner: Optional[TokenStream] = None
        self._done = False
        self.failovers = 0            # times THIS request was re-dispatched
        self.migrations = 0           # times its KV moved between replicas
        # parking (no healthy prefill-capable replica right now): the
        # stream waits here until undrain/recovery dispatches it
        self._park_evt: Optional[asyncio.Event] = None
        self._park_error: Optional[BaseException] = None

    @property
    def tokens(self) -> List[int]:
        return list(self.request.generated)

    @property
    def aborted(self) -> bool:
        return self._inner is not None and self._inner.aborted

    @property
    def parked(self) -> bool:
        return self._inner is None and not self._done

    def cancel(self) -> bool:
        self._router._streams.pop(self.request.rid, None)
        if self in self._router._parked:
            self._router._parked.remove(self)
        if self.replica is not None:
            self.replica.inflight.pop(self.request.rid, None)
        self._done = True
        if self._park_evt is not None:
            self._park_evt.set()
        return self._inner.cancel() if self._inner is not None else False

    def __aiter__(self) -> "RouterStream":
        return self

    async def __anext__(self) -> int:
        while True:
            if self._inner is None:
                if self._done:
                    raise StopAsyncIteration
                await self._wait_dispatch()
                continue
            try:
                return await self._inner.__anext__()
            except StopAsyncIteration:
                self._retire()
                raise
            except MigrateSignal:
                # the request parked in MIGRATING on its replica: run the
                # migration protocol from this consumer task, then keep
                # consuming (from the importing replica on success, from
                # the source on cancel)
                await self._router._migrate(self)
            except asyncio.CancelledError:
                # the consumer task was cancelled (client went away): free
                # the engine-side resources AND the router bookkeeping, or
                # the rid / Replica.inflight entry would leak forever and
                # least_kv would keep counting a request nobody runs
                if not self._done:
                    self.cancel()
                raise
            except Exception as exc:
                if not self._failover_eligible():
                    self._retire(failed=True)
                    raise
                self.failovers += 1
                self._router.failovers += 1
                try:
                    self._router._redispatch(self, exc)
                except BaseException:
                    self._retire(failed=True)   # no sibling: free the rid
                    raise
                # loop: continue consuming from the new replica's stream

    async def _wait_dispatch(self) -> None:
        """Parked: wait for ``undrain``/recovery to dispatch this stream
        (or for the router to give up on it)."""
        try:
            await self._park_evt.wait()
        except asyncio.CancelledError:
            if not self._done:
                self.cancel()
            raise
        if self._park_error is not None:
            err, self._park_error = self._park_error, None
            self._retire(failed=True)
            raise err

    def _failover_eligible(self) -> bool:
        """Retry only when the dead replica produced NOTHING observable:
        the pump died and this request never emitted a token."""
        return (self.replica is not None and self.replica.dead
                and not self.request.generated)

    def _retire(self, failed: bool = False) -> None:
        if self._done:
            return
        self._done = True
        self._router._streams.pop(self.request.rid, None)
        if self in self._router._parked:
            self._router._parked.remove(self)
        if self.replica is not None:
            self.replica.inflight.pop(self.request.rid, None)
            if not failed and self._inner is not None \
                    and not self._inner.aborted:
                self.replica.completed += 1


class Router:
    """Multi-engine front: routing policy + replica roles/lifecycle +
    fleet metrics over N ``AsyncLVLMServer`` replicas (see module
    docstring).

    Build via ``LVLM.serve_cluster``; construct directly to mix replicas
    of DIFFERENT models or hand-built servers. ``roles`` is a per-replica
    sequence over ``("unified", "prefill", "decode")``; a fleet with any
    ``prefill`` replica needs a decode-capable sibling to hand KV to.
    ``shared_prefix`` promotes the per-replica prefix caches to one
    cluster-shared radix tier (``SharedPrefixTier``): a prefix cached by
    ANY replica short-circuits prefill on every replica, at one modeled
    KV-link transfer per remote install. ``None`` (default) enables it
    exactly when the fleet is role-split -- there, the prefill replicas'
    caches are useless to the rest of the fleet without the shared tier.
    """

    def __init__(self, servers: Sequence[AsyncLVLMServer],
                 routing="round_robin",
                 roles: Optional[Sequence[str]] = None,
                 shared_prefix: Optional[bool] = None,
                 control=None):
        if not servers:
            raise ValueError("Router needs at least one replica")
        if roles is None:
            roles = ["unified"] * len(servers)
        if len(roles) != len(servers):
            raise ValueError(
                f"roles has {len(roles)} entries for {len(servers)} "
                "replicas")
        self.replicas = [Replica(i, s, role=r)
                         for i, (s, r) in enumerate(zip(servers, roles))]
        if not any(rep.can_prefill for rep in self.replicas):
            raise ValueError("fleet has no prefill-capable replica "
                             "(every role is 'decode')")
        if any(rep.role == "prefill" for rep in self.replicas) \
                and not any(rep.can_decode for rep in self.replicas):
            raise ValueError("'prefill' replicas need a decode-capable "
                             "('decode' or 'unified') sibling to hand "
                             "KV to")
        self.policy = make_policy(routing)
        # fleet-shared adaptive controller (repro.control.Controller or
        # None): biases video-heavy dispatch toward aggressive-pruning
        # replicas while any replica is under pressure. None = zero
        # policy calls, like the null tracer.
        self.control = control
        self.metrics = ClusterMetrics(self)
        self._streams: Dict[int, RouterStream] = {}
        self._parked: List[RouterStream] = []       # FIFO dispatch order
        self.failovers = 0
        self.migrations: List[Dict] = []            # completed KV handoffs
        self.prefix_tier = self._install_prefix_tier(shared_prefix)
        for rep in self.replicas:
            # server-initiated aborts (disconnect timeouts fire inside the
            # replica pump, no consumer will ever retire the stream) must
            # drop the router's bookkeeping too, or the rid leaks forever
            rep.server.on_abort = self._on_server_abort
            # each engine gets its own track in the (fleet-shared) trace:
            # spans carried across a migration land on distinct replica
            # pids in the Perfetto export
            rep.server.engine.trace_replica = rep.index

    def _install_prefix_tier(self,
                             shared_prefix: Optional[bool]
                             ) -> Optional[SharedPrefixTier]:
        if shared_prefix is None:
            shared_prefix = any(rep.role != "unified"
                                for rep in self.replicas)
        caching = [rep for rep in self.replicas
                   if rep.server.engine.ec.prefix_cache]
        if not shared_prefix or len(caching) < 2:
            return None
        blocks = {rep.server.engine.ec.prefix_block for rep in caching}
        if len(blocks) != 1:
            return None     # heterogeneous block sizes cannot share keys
        tier = SharedPrefixTier(
            block=blocks.pop(),
            cap=sum(rep.server.engine.ec.prefix_cap for rep in caching))
        for rep in caching:
            rep.server.engine.prefix_share = tier
        return tier

    # -------------------------------------------------------- lifecycle --
    async def start(self) -> "Router":
        for rep in self.replicas:
            await rep.server.start()
        return self

    async def stop(self, drain: bool = True) -> None:
        """Stop every replica. A replica whose pump already died does not
        re-raise here: its failure either failed over or surfaced on the
        affected streams, and is kept on ``Replica.error`` for reports."""
        for stream in list(self._parked):   # parked streams never started
            stream._park_error = RuntimeError(
                "router stopped before dispatch")
            stream._park_evt.set()
        for rep in self.replicas:
            try:
                await rep.server.stop(drain=drain)
            except BaseException:
                if not rep.dead:      # pragma: no cover - defensive
                    raise

    async def __aenter__(self) -> "Router":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=not any(exc))

    def drain(self, index: int, migrate: bool = True) -> None:
        """Take replica ``index`` out of rotation: new requests route
        elsewhere, and (``migrate=True``) its live KV moves to healthy
        decode-capable siblings -- each in-flight stream continues
        token-identically from the importer. With no eligible sibling
        (or ``migrate=False``) the in-flight streams finish here, as
        before."""
        rep = self.replicas[index]
        rep.draining = True
        if not migrate:
            return
        if not any(r.can_decode and r.state == "ok" and r is not rep
                   for r in self.replicas):
            return
        for rid in list(rep.inflight):
            # DECODE-phase requests park in MIGRATING now; waiting /
            # prefilling ones get handoff=True and park after their
            # prefill -- either way the consumer drives the move
            rep.server.request_migration(rid)

    def undrain(self, index: int) -> None:
        self.replicas[index].draining = False
        self._dispatch_parked()

    # ----------------------------------------------------------- intake --
    def _candidates(self, kind: str = "prefill") -> List[Replica]:
        """Healthy replicas able to take ``kind`` work (may be empty)."""
        want = "can_prefill" if kind == "prefill" else "can_decode"
        return [rep for rep in self.replicas
                if rep.state == "ok" and getattr(rep, want)]

    def submit(self, request: Request) -> RouterStream:
        """Route ``request`` to a prefill-capable replica and return its
        stream. Like the single-server ``submit``: never blocks (replica
        admission gates on the stream's first ``__anext__``); rids are
        fleet-unique. With every healthy replica draining the stream
        PARKS until ``undrain``; only an all-dead fleet raises."""
        if request.rid in self._streams:
            raise ValueError(f"request id {request.rid} already streaming")
        stream = RouterStream(self, request)
        if self._candidates("prefill"):
            self._dispatch(stream)
        else:
            if all(rep.dead for rep in self.replicas):
                raise RuntimeError("no live replica (every pump is dead)")
            self._park(stream)
        self._streams[request.rid] = stream
        return stream

    def _park(self, stream: RouterStream) -> None:
        stream._park_evt = asyncio.Event()
        self._parked.append(stream)

    def _dispatch_parked(self) -> None:
        """Dispatch parked streams (FIFO) while a healthy prefill-capable
        replica exists; called on ``undrain``."""
        while self._parked and self._candidates("prefill"):
            stream = self._parked.pop(0)
            if stream._done:
                continue
            self._dispatch(stream)
            stream._park_evt.set()

    def _dispatch(self, stream: RouterStream) -> None:
        candidates = self._candidates("prefill")
        if self.control is not None:
            # under pressure, video-heavy requests prefer replicas whose
            # default compression is aggressive (no-op at level 0; falls
            # back to the full list when no candidate qualifies)
            candidates = self.control.route_bias(stream.request,
                                                 candidates)
        rep = self.policy.pick(stream.request, candidates)
        rep.dispatched += 1
        rep.inflight[stream.request.rid] = stream.request
        stream.replica = rep
        # a prefill-ROLE replica never decodes what it prefills: the
        # request hands its KV off right after its first token
        stream.request.handoff = (rep.role == "prefill")
        stream._inner = rep.server.submit(stream.request)

    def _redispatch(self, stream: RouterStream, cause: BaseException) -> None:
        """Failover: the request never started on the dead replica, so its
        runtime state resets to a fresh submit and a sibling takes it --
        or, with every survivor draining, the stream parks until one
        rejoins."""
        if stream.replica is not None:
            stream.replica.inflight.pop(stream.request.rid, None)
        _reset_for_retry(stream.request)
        if self._candidates("prefill"):
            self._dispatch(stream)
            return
        if all(rep.dead for rep in self.replicas):
            raise RuntimeError(
                f"request {stream.request.rid}: replica "
                f"{stream.replica.index} died and no live sibling "
                "remains") from cause
        stream.replica = None
        stream._inner = None
        self._park(stream)

    # -------------------------------------------------------- migration --
    async def _migrate(self, stream: RouterStream) -> None:
        """Move ``stream``'s request (parked in MIGRATING on its replica)
        to a healthy decode-capable sibling: export the KV, commit the
        import through the target's admission gate, then release the
        source -- with NO await between commit and release, so the
        request is live on exactly one engine at every yield point. When
        no sibling can take it, the export cancels and the request
        resumes decoding where it is."""
        req = stream.request
        src = stream.replica
        rid = req.rid
        src_eng = src.server.engine
        try:
            ticket = src_eng.export_kv(rid)
        except (KeyError, RuntimeError):
            return      # finished/aborted in the signal gap: nothing to do
        transfer_s = src_eng.ec.cost.transfer_time(int(ticket["pos"]))
        ready_at = float(ticket["clock"]) + transfer_s
        # dedicated decode replicas first, then unified, least KV first
        targets = sorted(
            (rep for rep in self._candidates("decode") if rep is not src),
            key=lambda rep: (rep.role != "decode", rep.kv_load()))
        for dst in targets:
            try:
                inner = await dst.server.import_stream(req, ticket,
                                                       ready_at=ready_at)
            except Exception:
                continue     # this task still holds the export pin: retry
            # import committed on ``dst``: release the source and swap the
            # stream over, no awaits until done (exactly-once)
            src.server.complete_export(rid)
            src.server.release_migrated(rid)
            src.inflight.pop(rid, None)
            dst.inflight[rid] = req
            stream.replica = dst
            stream._inner = inner
            stream.migrations += 1
            self.migrations.append({
                "rid": rid, "src": src.index, "dst": dst.index,
                "kv_tokens": int(ticket["pos"]),
                "prefill_s": (req.first_token_time - req.arrival
                              if req.first_token_time is not None
                              else None),
                "transfer_s": transfer_s,
                "ready_at": ready_at,
            })
            return
        src.server.cancel_export(rid)   # nobody could take it: resume here

    def abort(self, rid: int) -> bool:
        stream = self._streams.get(rid)
        return stream.cancel() if stream is not None else False

    def _on_server_abort(self, rid: int) -> None:
        """A replica aborted ``rid`` on its own (disconnect timeout,
        direct ``server.abort``): retire the router stream so the rid
        frees up. A consumer that comes back can still drain the tokens
        already fanned out (the inner channel keeps them)."""
        stream = self._streams.get(rid)
        if stream is not None and stream._inner is not None \
                and stream._inner.aborted:
            stream._retire()

    # ---------------------------------------------------------- reports --
    def summary(self) -> Dict:
        """Fleet-wide merged metrics (see ``ClusterMetrics.summary``)."""
        return self.metrics.summary()

    def metrics_snapshot(self) -> str:
        """Fleet metrics in Prometheus text format: every replica's
        families labeled ``replica="i"`` plus router-level counters
        (failovers, migrations, shared-prefix-tier hits). The scrape
        surface ``launch.serve --metrics-out`` writes."""
        from repro.obs.prom import PromText
        parts = [rep.server.metrics_snapshot(replica=rep.index)
                 for rep in self.replicas]
        prom = PromText()
        prom.counter("failovers_total",
                     "Requests re-dispatched after a replica died.",
                     self.failovers)
        prom.counter("migrations_total", "Completed KV migrations.",
                     len(self.migrations))
        prom.counter(
            "migrated_kv_tokens_total", "KV tokens moved between replicas.",
            sum(m["kv_tokens"] for m in self.migrations))
        if self.prefix_tier is not None:
            stats = self.prefix_tier.stats()
            prom.counter("prefix_tier_hits_total",
                         "Shared-prefix-tier lookup hits.", stats["hits"])
            prom.counter("prefix_tier_misses_total",
                         "Shared-prefix-tier lookup misses.",
                         stats["misses"])
            prom.gauge("prefix_tier_entries",
                       "Entries resident in the shared prefix tier.",
                       stats["entries"])
        # the fleet shares ONE profiler (like the tracer), so its site
        # histograms render once at router level, not per replica
        profiler = self.replicas[0].server.profiler if self.replicas \
            else None
        if profiler is not None and profiler.enabled:
            from repro.obs.profile import profile_families
            profile_families(prom, profiler)
        # ... and ONE adaptive controller: its repro_control_* families
        # (per-replica ladder level, actuation counters) render here too
        if self.control is not None:
            self.control.prom_families(prom)
        return "".join(parts) + prom.render()


def _reset_for_retry(req: Request) -> None:
    """Return a never-started request to its pre-submit state so a sibling
    replica can run it from scratch (failover path; the caller guarantees
    ``req.generated`` is empty)."""
    from repro.core.serving.request import State

    assert not req.generated, "cannot retry a request with emitted tokens"
    req.state = State.WAITING
    req.prefill_done = 0
    req.aborted = False
    req.first_token_time = None
    req.finish_time = None
    req.served_tokens = 0
    req.handoff = False
    # the sibling re-resolves the compression strategy (its registry /
    # default may differ), so the stamped post-compression count resets
    req.nv_compressed = None
    for attr in ("_slot", "_ve", "_prefix_pin", "_needs_ttft",
                 "_gate_clock", "_comp_name", "_imported", "_ready_at",
                 "_export_pin"):
        if hasattr(req, attr):
            delattr(req, attr)
