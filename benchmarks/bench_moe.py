"""Benchmark: sparse-MoE routing balance (survey dim 3b + §V open problem).

The survey's §V: "the routing algorithm in MoE often routes visual context
to a small subset of 'popular' experts ... the model stops functioning like
a true mixture of experts." The Switch/GShard load-balance auxiliary loss
is the surveyed mitigation. This harness trains a small MoE with and
without the aux loss and reports expert-load entropy + drop rates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.models import build
from repro.models.moe import apply_moe
from repro.training import (OptimizerConfig, SyntheticDataConfig,
                            adamw_init, adamw_update)
from repro.training.data import make_batch


def run() -> None:
    # (a) mechanism check: the Switch lb_loss signal must separate a
    # collapsed routing from a balanced one by a wide margin
    e, t = 8, 512
    logits_bal = jnp.zeros((t, e))
    logits_col = jnp.zeros((t, e)).at[:, 0].set(8.0)
    for name, lg in (("balanced", logits_bal), ("collapsed", logits_col)):
        probs = jax.nn.softmax(lg, -1)
        _, idx = jax.lax.top_k(probs, 2)
        one_hot = jax.nn.one_hot(idx, e)
        load = one_hot.sum((0, 1)) / (t * 2)
        lb = float(e * jnp.sum(load * probs.mean(0)))
        emit(f"moe/lb_loss_signal/{name}", 0.0, f"lb_loss={lb:.3f}"
             ";(1.0=perfectly balanced)")

    # (b) training path: smoke-scale MoE stays balanced either way (real
    # collapse needs long training runs); rows prove the aux pathway runs
    base = get_config("arctic-480b", smoke=True).with_(vocab_size=256)
    for coef, tag in ((0.0, "no_aux"), (5e-2, "aux")):
        cfg = base.with_(router_aux_loss_coef=coef)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        oc = OptimizerConfig(lr=2e-3, warmup_steps=3, total_steps=40,
                             weight_decay=0.0)
        opt = adamw_init(params)

        @jax.jit
        def step(params, opt, batch):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch), has_aux=True)(params)
            params, opt, _ = adamw_update(oc, grads, opt, params)
            return params, opt, loss

        dc = SyntheticDataConfig(batch=4, seq_len=24)
        for s in range(40):
            batch = {k: jnp.asarray(v)
                     for k, v in make_batch(cfg, dc, s).items()}
            params, opt, loss = step(params, opt, batch)

        # measure routing balance on held-out data through layer-0 MoE
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, dc, 99).items()}
        emb = params["embed"]["tok"][batch["tokens"]]
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        _, aux = apply_moe(lp["moe"], emb, cfg)
        load = np.asarray(aux["load"])
        load = load / load.sum()
        ent = -(load * np.log(load + 1e-9)).sum() / np.log(len(load))
        emit(f"moe/balance/{tag}", 0.0,
             f"load_entropy={ent:.4f};max_load={load.max():.3f};"
             f"dropped={float(aux['dropped_frac']):.3f};"
             f"final_loss={float(loss):.3f}")


if __name__ == "__main__":
    run()
