"""Step functions + abstract inputs + shardings per (arch x input-shape).

``build_step(arch, shape, mesh)`` returns a LoweringSpec: a pure step
callable, the ShapeDtypeStruct stand-ins for every input (no allocation),
and matching in/out NamedShardings -- everything launch/dryrun.py needs to
``jax.jit(...).lower(...).compile()`` the pair on the production mesh.

Shape semantics (DESIGN.md §4):
  train_4k    -> train_step  (loss + AdamW update)
  prefill_32k -> prefill_step (populate KV cache / SSM state; last-token
                 logits only)
  decode_32k  -> serve_step  (ONE token against a seq_len cache)
  long_500k   -> serve_step; dense/vlm/moe archs run the sliding-window
                 variant (window 16384, ring-buffer cache); ssm/hybrid run
                 natively (O(1)/windowed state). whisper-tiny is skipped
                 (configs.SKIPS).

whisper-tiny's decoder is architecturally capped at 448 positions; its
train/prefill/decode shapes use min(seq_len, 448) for the decoder stream
with the full 1500-frame encoder context (noted in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import SKIPS, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.shapes import SHAPES
from repro.models.registry import build
from repro.sharding.specs import (ShardingRules, batch_shardings,
                                  cache_shardings, logits_sharding,
                                  opt_state_shardings, param_shardings,
                                  replicated)
from repro.training.optimizer import OptimizerConfig, adamw_update

LONG_CONTEXT_WINDOW = 16384


@dataclasses.dataclass
class LoweringSpec:
    name: str
    step: Callable
    args: Tuple[Any, ...]            # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    model_cfg: Optional[ModelConfig] = None
    shape_cfg: Optional[ShapeConfig] = None


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _abstract_tree(spec_tree, default_dtype):
    from repro.models.layers import abstract_params
    return abstract_params(spec_tree, default_dtype)


def effective_config(arch: str, shape_name: str) -> ModelConfig:
    """The config actually lowered for this pair (long-context variants)."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.family in ("dense", "vlm", "moe") \
            and cfg.sliding_window == 0:
        cfg = cfg.with_(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def batch_structs(cfg: ModelConfig, sc: ShapeConfig,
                  with_labels: bool) -> Dict[str, Any]:
    """ShapeDtypeStructs for a full-sequence batch (train / prefill)."""
    b = sc.global_batch
    s = sc.seq_len
    out: Dict[str, Any] = {}
    if cfg.family == "audio":
        s = min(s, cfg.decoder_max_seq or s)
        out["frames"] = _struct((b, cfg.encoder_seq, cfg.d_model), "float32")
        out["tokens"] = _struct((b, s), "int32")
    elif cfg.family == "vlm":
        nv = min(cfg.num_visual_tokens, s - 1)
        out["visual_embeds"] = _struct((b, nv, cfg.d_model), "float32")
        out["tokens"] = _struct((b, s - nv), "int32")
    else:
        out["tokens"] = _struct((b, s), "int32")
    if with_labels:
        st = out["tokens"].shape
        out["labels"] = _struct(st, "int32")
        out["loss_mask"] = _struct(st, "float32")
    return out


def _opt_structs(param_structs):
    return {
        "mu": jax.tree.map(lambda x: _struct(x.shape, "float32"),
                           param_structs),
        "nu": jax.tree.map(lambda x: _struct(x.shape, "float32"),
                           param_structs),
        "step": _struct((), "int32"),
    }


def build_step(arch: str, shape_name: str, mesh: Mesh, *,
               fsdp: bool = True, remat: bool = True,
               moe_cap: float = 1.25,
               decode_batch_replicated: bool = False,
               weight_quant: str = "none") -> Optional[LoweringSpec]:
    """None if the pair is skipped (configs.SKIPS)."""
    if (arch, shape_name) in SKIPS:
        return None
    sc = SHAPES[shape_name]
    cfg = effective_config(arch, shape_name)
    if weight_quant != "none":
        cfg = cfg.with_(weight_quant=weight_quant)
    model = build(cfg)
    # fsdp only pays off when model-sharded weights still exceed ~1 GB per
    # device: smaller archs replicate across "data" and skip the per-layer
    # weight all-gathers entirely (§Perf, qwen2-vl prefill iteration)
    model_size = mesh.shape.get("model", 1)
    param_bytes_per_dev = cfg.param_count() * 2 / model_size
    fsdp = fsdp and param_bytes_per_dev > 1e9
    rules = ShardingRules(mesh, fsdp=fsdp)

    pspec_tree = model.param_specs()
    params_sh = param_shardings(rules, pspec_tree)
    params_st = _abstract_tree(pspec_tree, cfg.dtype)

    if sc.kind == "train":
        oc = OptimizerConfig()
        bst = batch_structs(cfg, sc, with_labels=True)
        bsh = batch_shardings(rules, bst)
        opt_st = _opt_structs(params_st)
        opt_sh = opt_state_shardings(rules, pspec_tree)

        def train_step(params, opt_state, batch):
            (loss, _aux), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, remat=remat),
                has_aux=True)(params)
            params, opt_state, om = adamw_update(oc, grads, opt_state,
                                                 params)
            return params, opt_state, loss

        return LoweringSpec(
            name=f"{arch}/{shape_name}/train",
            step=train_step,
            args=(params_st, opt_st, bst),
            in_shardings=(params_sh, opt_sh, bsh),
            out_shardings=(params_sh, opt_sh, replicated(rules)),
            donate_argnums=(0, 1),
            model_cfg=cfg, shape_cfg=sc)

    if sc.kind == "prefill":
        bst = batch_structs(cfg, sc, with_labels=False)
        bsh = batch_shardings(rules, bst)
        windowed = bool(cfg.sliding_window) and cfg.family == "hybrid"
        cache_spec = model.cache_specs(sc.global_batch, _cache_len(cfg, sc),
                                       windowed=False)
        cache_sh = cache_shardings(rules, cache_spec)

        def prefill_step(params, batch):
            logits, cache = model.prefill(
                params, batch, cache_len=_cache_len(cfg, sc),
                moe_cap=moe_cap, last_only=True)
            return logits, cache

        lsh = logits_sharding(rules, (sc.global_batch, 1, cfg.vocab_size))
        return LoweringSpec(
            name=f"{arch}/{shape_name}/prefill",
            step=prefill_step,
            args=(params_st, bst),
            in_shardings=(params_sh, bsh),
            out_shardings=((lsh, cache_sh)),
            model_cfg=cfg, shape_cfg=sc)

    # decode kinds (decode_32k / long_500k)
    windowed = (shape_name == "long_500k"
                and cfg.family in ("dense", "vlm", "moe"))
    cache_len = _cache_len(cfg, sc)
    cache_spec = model.cache_specs(sc.global_batch, cache_len,
                                   windowed=windowed)
    cache_st = _abstract_tree(cache_spec, cfg.dtype)
    cache_sh = cache_shardings(rules, cache_spec)
    b = sc.global_batch
    tok_st = _struct((b, 1), "int32")
    pos_st = _struct((b,), "int32")
    if decode_batch_replicated:
        # weight-stationary decode: replicate the (tiny) token batch so
        # the partitioner psums activations rather than all-gathering the
        # fsdp weight shards every step (§Perf, nemotron decode_32k)
        from jax.sharding import PartitionSpec as P
        tok_sh = rules.named(P())
        pos_sh = rules.named(P())
    else:
        tok_sh = rules.named(rules.batch_pspec(2, batch_size=b))
        pos_sh = rules.named(rules.batch_pspec(1, batch_size=b))

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos,
                                 windowed=windowed, moe_cap=moe_cap,
                                 weight_stationary=decode_batch_replicated)

    lsh = logits_sharding(rules, (b, cfg.vocab_size))
    return LoweringSpec(
        name=f"{arch}/{shape_name}/decode",
        step=serve_step,
        args=(params_st, cache_st, tok_st, pos_st),
        in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
        out_shardings=((lsh, cache_sh)),
        donate_argnums=(1,),
        model_cfg=cfg, shape_cfg=sc)


def _cache_len(cfg: ModelConfig, sc: ShapeConfig) -> int:
    s = sc.seq_len
    if cfg.family == "audio":
        s = min(s, cfg.decoder_max_seq or s)
    return s
