"""``repro.control``: SLO-adaptive quality control + Pareto sweeps.

Two halves of one loop (ROADMAP's "EffiVLM-BENCH-style Pareto sweep
harness + SLO-adaptive quality control" item):

  * OFFLINE -- ``repro.control.sweep`` measures the quality-vs-latency
    frontier over (compression x decoder x replica mix x arrival rate)
    and commits it as ``BENCH_pareto.json`` (CI regress-gated);
  * ONLINE -- ``AdaptivePolicy`` (the table-driven degradation ladder,
    ``repro.control.policy``) + ``Controller`` (the actuator threaded
    through server admission and router dispatch,
    ``repro.control.controller``) walk that frontier live: under
    KV/SLO pressure requests degrade to aggressive presets instead of
    deferring, and recover when pressure drops.

Enable with ``control=True`` (defaults), a ``ControlConfig``, an
``AdaptivePolicy``, or a prebuilt ``Controller`` on ``LVLM.serve`` /
``serve_async`` / ``serve_cluster``. ``control=None`` (the default)
makes ZERO policy calls.
"""
from repro.control.controller import Controller
from repro.control.policy import (AdaptivePolicy, ControlConfig,
                                  ControlLevel, DEFAULT_LADDER,
                                  LevelState)
from repro.control.sweep import (FRONTIER_AXES, SweepConfig, dominates,
                                 pareto_frontier, point_key, run_sweep,
                                 write_pareto)

__all__ = [
    "AdaptivePolicy", "ControlConfig", "ControlLevel", "Controller",
    "DEFAULT_LADDER", "LevelState",
    "FRONTIER_AXES", "SweepConfig", "dominates", "pareto_frontier",
    "point_key", "run_sweep", "write_pareto",
]
