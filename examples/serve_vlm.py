"""Serve a VLM to concurrent STREAMING clients through the async serving
layer -- requests with visual tokens and mixed decoder strategies share one
engine, tokens stream per client as the engine emits them, one client
hangs up mid-stream (freeing its KV slot, speculative draft row, and
reserved lookahead), and the SLO telemetry reports tail latency:

    PYTHONPATH=src python examples/serve_vlm.py
"""
import asyncio

import numpy as np

from repro.api import (AdmissionConfig, EngineConfig, GenerationConfig,
                       LVLM, Request)


def make_requests(cfg, n=6, seed=0):
    rng = np.random.RandomState(seed)
    # structured "images": few textures + noise => redundancy to exploit
    centers = rng.randn(4, cfg.d_model) * 0.5
    strategies = ("speculative", "greedy", "speculative",
                  "sampling", "greedy", "speculative")
    reqs = []
    for i in range(n):
        nv = cfg.num_visual_tokens
        ve = (centers[rng.randint(4, size=nv)]
              + 0.05 * rng.randn(nv, cfg.d_model)).astype(np.float32)
        reqs.append(Request(
            rid=i, tokens=list(rng.randint(1, cfg.vocab_size, size=16)),
            visual_embeds=ve, max_new_tokens=12,
            decoder=strategies[i % len(strategies)]))
    return reqs


async def client(server, req, cancel_after=None):
    """One streaming consumer; ``cancel_after`` hangs up mid-stream."""
    stream = server.submit(req)
    toks = []
    async for tok in stream:
        toks.append(tok)
        if cancel_after is not None and len(toks) >= cancel_after:
            stream.cancel()                      # frees slot + draft row
            break
    tag = "cancelled" if stream.aborted else "done"
    print(f"client {req.rid} [{req.decoder:12s}] {tag:9s} "
          f"{len(toks):2d} tokens: {toks}")
    return toks


async def main_async():
    lvlm = LVLM.from_pretrained("qwen2-vl-2b", smoke=True)
    server = lvlm.serve_async(
        EngineConfig(max_batch=4, cache_len=160, temperature=0.0),
        gen=GenerationConfig(decoder="greedy", temperature=0.0,
                             max_new_tokens=12, gamma=3,
                             compression="divprune-0.5"),
        admission=AdmissionConfig(high_watermark=0.85, low_watermark=0.6))
    reqs = make_requests(lvlm.cfg)
    async with server:
        await asyncio.gather(
            *(client(server, r, cancel_after=3 if r.rid == 2 else None)
              for r in reqs))
    s = server.summary()
    print(f"\nserved {s['finished']} requests ({s['aborted']} cancelled) "
          f"in {s['virtual_time_s'] * 1e3:.2f} virtual ms; "
          f"admission deferred {s['deferred']}")
    print(f"TTFT p50/p95/p99: {s['ttft_p50']:.4f}/{s['ttft_p95']:.4f}/"
          f"{s['ttft_p99']:.4f} s   "
          f"TPOT p50/p95/p99: {s['tpot_p50']:.5f}/{s['tpot_p95']:.5f}/"
          f"{s['tpot_p99']:.5f} s")
    print(f"SLO attainment: ttft={s['slo_ttft_attainment']:.2f} "
          f"tpot={s['slo_tpot_attainment']:.2f} "
          f"goodput={s['slo_goodput']:.2f}")
    print(f"decode cost by strategy group: "
          f"{ {k: round(v, 6) for k, v in s['decode_cost_by_group'].items()} }")


def main():
    asyncio.run(main_async())


if __name__ == "__main__":
    main()
