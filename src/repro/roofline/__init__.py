from repro.roofline.hw import HW, TPU_V5E
from repro.roofline.analysis import (
    collective_bytes_from_hlo, roofline_from_compiled, RooflineReport)
