"""Continuous hot-path profiling: streaming per-site time histograms.

Where ``repro.obs.trace`` answers "what happened to request N" (lifecycle
spans), this module answers "where does an engine step actually spend its
time" -- continuously, in production, with the same zero-overhead-when-off
discipline:

  * ``NULL_PROFILER`` (a ``NullProfiler``) is the default everywhere; its
    ``enabled`` class attribute is ``False`` and every hot-path site guards
    on it (``if profiler.enabled:``), so the unprofiled path makes ZERO
    profiler calls (locked by a patch-the-null-profiler-to-raise test,
    mirroring the NullTracer test).
  * ``Profiler`` accumulates streaming log2-bucket histograms of wall time
    (``time.perf_counter`` around the site) and virtual time (the engine's
    modeled cost, passed by the site) per named *site* -- prefill forward,
    per-decoder-group decode launch, compression, KV-migration transfer,
    prefix-tier probe/install.
  * Sites nest (``compress`` runs inside ``prefill_forward``), and the
    profiler attributes wall time both ways: *total* (site entry to exit)
    and *self* (total minus enclosed child sites). Nesting paths feed the
    collapsed-stack (flamegraph-compatible) export.

Profiling only ever READS clocks -- it never touches the PRNG key, the
scheduler, or the virtual clock -- so profiled runs stay bit-identical at
temperature 0 (locked by test).

Exports: ``profile_families`` renders Prometheus histogram families into a
``PromText`` (picked up by ``metrics_snapshot()``), ``Profiler.write_json``
feeds ``scripts/profile_report.py`` (table + collapsed stacks), and
``Profiler.bench_record`` is the schema-v1 block embedded in
``--emit-bench`` records for ``repro.obs.regress`` to gate on.
"""
from __future__ import annotations

import json
import math
import time
from typing import Callable, Dict, List, Optional, Tuple

# log2 histogram upper bounds in seconds: 1us * 2**i -- 30 buckets cover
# 1us .. ~537s, far beyond any single hot-path site on any hardware
_BUCKET_BASE = 1e-6
_NUM_BUCKETS = 30


def bucket_bounds() -> List[float]:
    """The histogram's upper bounds in seconds (shared by all sites)."""
    return [_BUCKET_BASE * (1 << i) for i in range(_NUM_BUCKETS)]


def _bucket_index(x: float) -> int:
    if x <= _BUCKET_BASE:
        return 0
    i = int(math.ceil(math.log2(x / _BUCKET_BASE)))
    return min(max(i, 0), _NUM_BUCKETS - 1)


class NullProfiler:
    """Disabled profiler: every method is a no-op and ``enabled`` is a
    class attribute so the hot-path guard is one attribute load. Sites
    must NEVER call these when profiling is off -- guard with
    ``if profiler.enabled:`` (rule O003 checks site pairing; the
    patch-to-raise test checks the guards)."""

    enabled = False

    def site_begin(self, site: str) -> None:
        pass

    def site_end(self, site: str, vt: float = 0.0) -> None:
        pass

    # read-side surface (safe on the null profiler: empty results)
    def snapshot(self) -> Dict[str, Dict]:
        return {}

    def collapsed(self) -> List[str]:
        return []

    def bench_record(self) -> Dict:
        return {"schema_version": 1, "sites": {}}


NULL_PROFILER = NullProfiler()


class _Site:
    __slots__ = ("count", "wall_total", "wall_self", "virtual",
                 "wall_counts", "virtual_counts")

    def __init__(self) -> None:
        self.count = 0
        self.wall_total = 0.0
        self.wall_self = 0.0
        self.virtual = 0.0
        self.wall_counts = [0] * _NUM_BUCKETS
        self.virtual_counts = [0] * _NUM_BUCKETS

    def add(self, total: float, self_w: float, vt: float) -> None:
        self.count += 1
        self.wall_total += total
        self.wall_self += self_w
        self.virtual += vt
        self.wall_counts[_bucket_index(total)] += 1
        self.virtual_counts[_bucket_index(vt)] += 1


def _trim_buckets(counts: List[int]) -> List[List[float]]:
    """[(upper_bound_s, count), ...] up to the last non-empty bucket --
    cumulative rendering stays exact (all trimmed buckets are zero)."""
    last = -1
    for i, c in enumerate(counts):
        if c:
            last = i
    bounds = bucket_bounds()
    return [[bounds[i], counts[i]] for i in range(last + 1)]


class Profiler(NullProfiler):
    """Enabled profiler: streaming log-bucket histograms per site.

    One instance is shared by a whole fleet (like the Tracer): engine
    steps are synchronous, so begin/end pairs never interleave across
    replicas and a single site stack is sufficient for self/total
    attribution.
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._sites: Dict[str, _Site] = {}
        # open-site stack: [site, t0, child_wall_total] frames
        self._stack: List[List] = []
        # collapsed stacks: "outer;inner" -> self wall seconds
        self._paths: Dict[str, float] = {}

    # ------------------------------------------------------ recording --
    def site_begin(self, site: str) -> None:
        self._stack.append([site, self._clock(), 0.0])

    def site_end(self, site: str, vt: float = 0.0) -> None:
        # unwind to the matching frame (defensive: a site that leaked an
        # inner begin is discarded rather than corrupting attribution)
        frame = None
        while self._stack:
            top = self._stack.pop()
            if top[0] == site:
                frame = top
                break
        if frame is None:
            return
        total = self._clock() - frame[1]
        self_w = total - frame[2]
        if self_w < 0.0:
            self_w = 0.0
        if self._stack:
            self._stack[-1][2] += total
            path = ";".join(f[0] for f in self._stack) + ";" + site
        else:
            path = site
        rec = self._sites.get(site)
        if rec is None:
            rec = self._sites[site] = _Site()
        rec.add(total, self_w, vt)
        self._paths[path] = self._paths.get(path, 0.0) + self_w

    # ------------------------------------------------------- exports --
    def snapshot(self) -> Dict[str, Dict]:
        """Per-site accumulators: counts, wall self/total, virtual time,
        and trimmed (upper_bound_s, count) histogram buckets."""
        out: Dict[str, Dict] = {}
        for site, s in sorted(self._sites.items()):
            out[site] = {
                "count": s.count,
                "wall_total_s": s.wall_total,
                "wall_self_s": s.wall_self,
                "virtual_s": s.virtual,
                "wall_buckets": _trim_buckets(s.wall_counts),
                "virtual_buckets": _trim_buckets(s.virtual_counts),
            }
        return out

    def collapsed(self) -> List[str]:
        """Collapsed-stack lines (``outer;inner <self_usec>``) -- feed to
        any flamegraph renderer (e.g. flamegraph.pl, speedscope)."""
        return [f"{path} {max(1, int(round(us * 1e6)))}"
                for path, us in sorted(self._paths.items())]

    def bench_record(self) -> Dict:
        """The schema-v1 profile block for ``--emit-bench`` records:
        scalar per-site attribution only (histograms stay in
        ``write_json``; bench records are for regression gating)."""
        sites = {}
        for site, s in sorted(self._sites.items()):
            sites[site] = {
                "count": s.count,
                "wall_total_s": s.wall_total,
                "wall_self_s": s.wall_self,
                "virtual_s": s.virtual,
            }
        return {"schema_version": 1, "sites": sites}

    def write_json(self, path: str) -> None:
        """Full profile document for ``scripts/profile_report.py``."""
        doc = {
            "schema_version": 1,
            "kind": "profile",
            "sites": self.snapshot(),
            "collapsed": {p: v for p, v in sorted(self._paths.items())},
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")


def profile_families(prom, profiler, *,
                     labels: Optional[Dict[str, str]] = None) -> None:
    """Render a profiler's per-site families into a ``PromText``:
    ``repro_profile_wall_seconds`` / ``repro_profile_virtual_seconds``
    histograms plus self-time counters, labeled by ``site``."""
    snap = profiler.snapshot()
    for site, s in snap.items():
        lab = dict(labels or {})
        lab["site"] = site
        prom.histogram(
            "profile_wall_seconds",
            "Wall time per hot-path site call (log2 buckets).",
            s["wall_buckets"], s["wall_total_s"], s["count"], labels=lab)
        prom.histogram(
            "profile_virtual_seconds",
            "Modeled virtual time per hot-path site call (log2 buckets).",
            s["virtual_buckets"], s["virtual_s"], s["count"], labels=lab)
        prom.counter(
            "profile_wall_self_seconds_total",
            "Cumulative self wall time (enclosed child sites excluded).",
            s["wall_self_s"], labels=lab)
