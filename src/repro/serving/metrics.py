"""SLO telemetry for the serving layer.

Per-request latency records against the engine's deterministic virtual
clock, aggregated into the summary a serving operator actually pages on:
TTFT / TPOT percentiles (p50/p95/p99), queue wait, SLO attainment
fractions, abort counts, and the virtual-clock decode cost per decoder
group (``Engine.group_costs`` -- the price each strategy charged the
clock, which is how a mixed speculative/greedy deployment is costed).

``queue_wait`` here is the ADMISSION-gate wait (virtual clock at
``Engine.submit`` minus clock at the client's submit call); scheduler
queueing after admission is already inside TTFT.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.serving.request import Request
from repro.obs.stats import summarize_records


@dataclasses.dataclass
class RequestRecord:
    """One request's lifecycle metrics (virtual-clock seconds)."""
    rid: int
    decoder: str
    prompt_len: int
    tokens: int                       # generated (partial if aborted)
    queue_wait: float
    ttft: Optional[float]
    tpot: Optional[float]
    jct: Optional[float]
    aborted: bool
    ttft_ok: bool                     # against the request's OWN SLO
    tpot_ok: bool
    # END-TO-END first-token latency: admission-gate queue wait + TTFT.
    # ``Engine.submit`` re-anchors ``arrival`` at the commit clock, so
    # plain ``ttft`` is the ENGINE-phase latency only -- a deferred
    # request's gate wait is invisible to it. e2e_ok judges the TTFT SLO
    # a user actually experiences (what graceful degradation improves
    # over defer-only admission).
    e2e_ttft: Optional[float] = None
    e2e_ok: bool = False


class MetricsRegistry:
    """Collects ``RequestRecord``s and summarizes them.

    One registry per server by default; pass a shared instance to
    ``LVLM.serve_async(metrics=...)`` to aggregate across servers.
    """

    #: Default cold-start TTFT estimate (seconds). Before any request
    #: finishes there is no TTFT history, and returning 0.0 made EDF
    #: ``order="slack"`` maximally optimistic for the whole first wave --
    #: every waiter looked like it had a full SLO of slack, so the first
    #: drain order ignored imminent deadlines entirely. A small positive
    #: prior (half a typical TTFT SLO) keeps cold-start ordering sane and
    #: washes out as soon as real records arrive.
    DEFAULT_TTFT_PRIOR = 0.25

    def __init__(self, ttft_prior: float = DEFAULT_TTFT_PRIOR):
        self.records: List[RequestRecord] = []
        self.ttft_prior = float(ttft_prior)
        self._expected_ttft: Optional[float] = None   # cache, see below

    def observe(self, req: Request, *, queue_wait: float = 0.0,
                decoder: str = "", aborted: bool = False) -> RequestRecord:
        rec = RequestRecord(
            rid=req.rid, decoder=decoder or (req.decoder or "default"),
            prompt_len=req.prompt_len, tokens=len(req.generated),
            queue_wait=queue_wait, ttft=req.ttft(), tpot=req.tpot(),
            jct=req.jct(), aborted=aborted,
            ttft_ok=(not aborted and req.ttft() is not None
                     and req.ttft() <= req.slo.ttft_ms * 1e-3),
            tpot_ok=(not aborted
                     and (req.tpot() or 0.0) <= req.slo.tpot_ms * 1e-3),
            e2e_ttft=(None if req.ttft() is None
                      else queue_wait + req.ttft()),
            e2e_ok=(not aborted and req.ttft() is not None
                    and queue_wait + req.ttft()
                    <= req.slo.ttft_ms * 1e-3))
        self.records.append(rec)
        self._expected_ttft = None        # new record invalidates the cache
        return rec

    def expected_ttft(self) -> float:
        """Live TTFT estimate (median of finished requests; ``ttft_prior``
        before any finish). This is what SLO-slack dispatch subtracts from
        a waiter's deadline: slack = deadline - now - expected_ttft.
        Cached per new record: the slack key evaluates it per waiter per
        drain, which must not rescan the whole history each time."""
        if self._expected_ttft is None:
            ttfts = [r.ttft for r in self.records
                     if not r.aborted and r.ttft is not None]
            self._expected_ttft = (float(np.median(ttfts)) if ttfts
                                   else self.ttft_prior)
        return self._expected_ttft

    # ---------------------------------------------------------- summary --
    def summary(self, engine=None) -> Dict:
        # the aggregate body lives in repro.obs.stats -- shared with the
        # fleet-merged ClusterMetrics summary so the two can never drift
        out = summarize_records(self.records)
        if engine is not None:
            out["virtual_time_s"] = engine.clock
            out["decode_cost_by_group"] = dict(engine.group_costs)
        return out
