"""Visual token merging (survey dim 1a-b).

  * tome_merge        -- ToMe bipartite soft matching (r tokens per pass)
  * prune_then_merge  -- PuMer/ASAP/VisPruner hybrid: prune uninformative,
                         then consolidate survivors onto their nearest kept
                         neighbour (weighted average).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def tome_merge(embeds, r: int, *, sizes=None) -> Tuple[jax.Array, jax.Array, Dict]:
    """ToMe bipartite soft matching: merge ``r`` tokens into their best match.

    Tokens are split alternating (A = even, B = odd); each A token proposes
    its most similar B token; the ``r`` highest-similarity edges merge
    (size-weighted average), shrinking N by r. ``sizes`` tracks how many
    original tokens each current token represents (for correct averaging
    across repeated passes).

    Returns (merged [B, N-r, d], new_sizes [B, N-r], info).
    """
    b, n, d = embeds.shape
    na = (n + 1) // 2
    nb = n // 2
    assert 0 < r <= min(na, nb) - 0, (r, n)
    if sizes is None:
        sizes = jnp.ones((b, n), jnp.float32)

    x = embeds.astype(jnp.float32)
    xn = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-6)
    a, bt = xn[:, 0::2], xn[:, 1::2]
    ae, be = x[:, 0::2], x[:, 1::2]
    sa, sb = sizes[:, 0::2], sizes[:, 1::2]

    sim = jnp.einsum("bad,bcd->bac", a, bt)                 # [B,na,nb]
    best_sim = sim.max(-1)                                  # [B,na]
    best_dst = sim.argmax(-1)                               # [B,na]

    # pick r A-tokens with the highest best-similarity to merge away
    _, merge_src = jax.lax.top_k(best_sim, r)               # [B,r]
    merge_mask = jnp.zeros((b, na), bool)
    merge_mask = merge_mask.at[jnp.arange(b)[:, None], merge_src].set(True)

    # scatter-add merged A tokens into their B destinations (size-weighted)
    w_src = jnp.where(merge_mask, sa, 0.0)                  # [B,na]
    add_val = jnp.zeros_like(be)
    add_size = jnp.zeros_like(sb)
    bidx = jnp.arange(b)[:, None]
    add_val = add_val.at[bidx, best_dst].add(ae * w_src[..., None])
    add_size = add_size.at[bidx, best_dst].add(w_src)
    new_b = (be * sb[..., None] + add_val) / (sb + add_size + 1e-9)[..., None]
    new_sb = sb + add_size

    # keep the unmerged A tokens (fixed count na - r via top_k on neg mask)
    keep_score = jnp.where(merge_mask, -1.0, 1.0) * (
        1.0 + jnp.arange(na, dtype=jnp.float32)[None] * 1e-6)
    _, keep_idx = jax.lax.top_k(keep_score, na - r)
    keep_idx = jnp.sort(keep_idx, -1)
    kept_a = jnp.take_along_axis(ae, keep_idx[..., None], 1)
    kept_sa = jnp.take_along_axis(sa, keep_idx, 1)

    merged = jnp.concatenate([kept_a, new_b], 1).astype(embeds.dtype)
    new_sizes = jnp.concatenate([kept_sa, new_sb], 1)
    return merged, new_sizes, {"merged": r}


def tome_to_count(embeds, keep: int, *, max_r_ratio: float = 0.4):
    """Repeated ToMe passes until only ``keep`` tokens remain."""
    sizes = None
    x = embeds
    while x.shape[1] > keep:
        n = x.shape[1]
        r = min(n - keep, max(1, int((n // 2) * max_r_ratio)))
        x, sizes, _ = tome_merge(x, r, sizes=sizes)
    return x, sizes


def prune_then_merge(embeds, keep: int, *, scores=None
                     ) -> Tuple[jax.Array, jax.Array, Dict]:
    """PuMer/FrameFusion-style hybrid.

    1) rank tokens (by ``scores`` or L2 proxy), keep the top ``keep``;
    2) each dropped token is absorbed into its most similar kept token
       (weighted mean), so information is consolidated, not discarded.
    """
    b, n, d = embeds.shape
    if scores is None:
        scores = -jnp.linalg.norm(embeds.astype(jnp.float32), axis=-1)
    _, kidx = jax.lax.top_k(scores, keep)
    kidx = jnp.sort(kidx, -1)
    kept = jnp.take_along_axis(embeds, kidx[..., None], 1)

    keep_mask = jnp.zeros((b, n), bool).at[
        jnp.arange(b)[:, None], kidx].set(True)
    x = embeds.astype(jnp.float32)
    xn = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-6)
    kn = jnp.take_along_axis(xn, kidx[..., None], 1)
    sim = jnp.einsum("bnd,bkd->bnk", xn, kn)
    dst = sim.argmax(-1)                                    # [B,N]

    w = jnp.where(keep_mask, 0.0, 1.0)
    add = jnp.zeros((b, keep, d), jnp.float32)
    cnt = jnp.zeros((b, keep), jnp.float32)
    bidx = jnp.arange(b)[:, None]
    add = add.at[bidx, dst].add(x * w[..., None])
    cnt = cnt.at[bidx, dst].add(w)
    merged = ((kept.astype(jnp.float32) + add) / (1.0 + cnt)[..., None]
              ).astype(embeds.dtype)
    return merged, kidx.astype(jnp.int32), {"absorbed": int(n - keep)}
