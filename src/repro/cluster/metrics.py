"""``ClusterMetrics``: the fleet-wide view over per-replica registries.

Each ``AsyncLVLMServer`` keeps its own ``MetricsRegistry``; the cluster
view MERGES the raw per-request records (not the per-replica summaries --
percentiles do not average) and recomputes TTFT/TPOT/queue-wait
percentiles, SLO attainment, and goodput over the whole fleet. On top it
reports what only the router can see: dispatch and completion counts per
replica, failovers, replica health, fleet KV load, aggregate prefix-cache
hits, and fleet throughput against the SLOWEST replica's virtual clock
(replicas decode in parallel, so the fleet makespan is the max, and
fleet throughput is how the multi-replica trajectory in bench_serving
shows its scaling).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.obs.stats import summarize_records
from repro.serving.metrics import MetricsRegistry


class ClusterMetrics:
    """Aggregates a ``Router``'s replicas; built by the Router itself."""

    def __init__(self, router):
        self.router = router

    def merged_registry(self) -> MetricsRegistry:
        merged = MetricsRegistry()
        for rep in self.router.replicas:
            merged.records.extend(rep.server.metrics.records)
        return merged

    def per_replica(self) -> List[Dict]:
        out = []
        for rep in self.router.replicas:
            eng = rep.server.engine
            s = rep.server.metrics.summary(eng)
            s.update(state=rep.state, role=rep.role,
                     dispatched=rep.dispatched,
                     completed=rep.completed, kv_load=rep.kv_load(),
                     admitted=rep.server.admission.admitted,
                     deferred=rep.server.admission.deferrals,
                     disconnects=rep.server.disconnects,
                     migrated_in=rep.migrated_in,
                     migrated_out=rep.migrated_out,
                     error=repr(rep.error) if rep.error else None)
            if eng.ec.prefix_cache:
                s["prefix_hit_tokens"] = eng.prefix_hit_tokens
                s["remote_prefix_hits"] = eng.remote_prefix_hits
            out.append(s)
        return out

    def disaggregation(self) -> Dict:
        """Fleet TTFT split for migrated requests: time-to-first-token on
        the prefill side, the modeled KV-link transfer, and per-replica
        migration counts. Empty counters mean no KV ever moved."""
        moves = self.router.migrations
        prefills = [m["prefill_s"] for m in moves
                    if m.get("prefill_s") is not None]
        transfers = [m["transfer_s"] for m in moves]
        out: Dict = {
            "migrations": len(moves),
            "migrated_kv_tokens": sum(m["kv_tokens"] for m in moves),
            "prefill_s_mean": float(np.mean(prefills)) if prefills
            else None,
            "transfer_s_mean": float(np.mean(transfers)) if transfers
            else None,
            "migrated_in_by_replica": [rep.migrated_in
                                       for rep in self.router.replicas],
            "migrated_out_by_replica": [rep.migrated_out
                                        for rep in self.router.replicas],
        }
        tier = self.router.prefix_tier
        if tier is not None:
            out["prefix_tier"] = tier.stats()
        return out

    def summary(self) -> Dict:
        reps = self.router.replicas
        # same shared aggregate body as MetricsRegistry.summary
        # (repro.obs.stats), over the fleet-merged raw records
        out = summarize_records(self.merged_registry().records)
        out["replicas"] = len(reps)
        out["replica_states"] = [rep.state for rep in reps]
        out["replica_roles"] = [rep.role for rep in reps]
        out["dispatched_by_replica"] = [rep.dispatched for rep in reps]
        out["completed_by_replica"] = [rep.completed for rep in reps]
        out["failovers"] = self.router.failovers
        out["routing_policy"] = self.router.policy.name
        out["admitted"] = sum(rep.server.admission.admitted for rep in reps)
        out["deferred"] = sum(rep.server.admission.deferrals for rep in reps)
        out["disconnects"] = sum(rep.server.disconnects for rep in reps)
        out["kv_load_by_replica"] = [rep.kv_load() for rep in reps]
        # fleet makespan = slowest replica's virtual clock (they advance
        # in parallel); throughput is fleet tokens over that makespan
        clocks = [rep.server.engine.clock for rep in reps]
        out["virtual_time_s"] = max(clocks) if clocks else 0.0
        out["virtual_time_by_replica"] = clocks
        if out["virtual_time_s"] > 0:
            out["fleet_throughput_tok_per_s"] = (
                out["tokens"] / out["virtual_time_s"])
        out["prefix_hit_tokens"] = sum(
            rep.server.engine.prefix_hit_tokens for rep in reps)
        if self.router.migrations or any(rep.role != "unified"
                                         for rep in reps):
            out["disaggregation"] = self.disaggregation()
        if self.router.control is not None:
            out.update(self.router.control.summary())
        return out
