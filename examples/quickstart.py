"""Quickstart: the unified ``repro.api`` facade in ~10 lines --
build an LVLM, generate with a compression preset, stream tokens,
then serve a batch through the taxonomy engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import EngineConfig, GenerationConfig, LVLM, Request


def main():
    # 1. one call wraps config -> build -> param init (smoke = CPU-sized)
    lvlm = LVLM.from_pretrained("qwen2-vl-2b", smoke=True, vocab_size=512)
    print(f"arch={lvlm.cfg.name} family={lvlm.cfg.family} "
          f"params={lvlm.cfg.param_count() / 1e6:.1f}M")

    # 2. (optional) train a few steps -- the internal layer stays available
    from repro.training import (OptimizerConfig, SyntheticDataConfig,
                                train_loop)
    out = train_loop(
        lvlm.model,
        oc=OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=30),
        dc=SyntheticDataConfig(batch=4, seq_len=32),
        num_steps=30, log_every=10)
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}")
    lvlm = lvlm.with_params(out["params"])

    # 3. generate: FastV-style visual pruning via a named preset
    rng = np.random.RandomState(0)
    prompt = list(rng.randint(1, lvlm.cfg.vocab_size, size=12))
    ve = rng.randn(lvlm.cfg.num_visual_tokens,
                   lvlm.cfg.d_model).astype(np.float32) * 0.02
    result = lvlm.generate(
        prompt,
        GenerationConfig(max_new_tokens=8, compression="divprune-0.5"),
        visual_embeds=ve)
    print("generated:", result.tokens)

    # 4. stream tokens one by one (same signature, any decoder strategy)
    print("streamed :", list(lvlm.generate_stream(
        prompt, GenerationConfig(max_new_tokens=8), visual_embeds=ve)))

    # 5. serve a batch: continuous batching + virtual-clock metrics.
    # Compression is configured via the FACADE (GenerationConfig default,
    # Request.compression per-request override), never by mutating
    # EngineConfig.compression -- here every other request opts into a
    # harsher prune-then-merge strategy in the same engine run.
    reqs = [Request(rid=i,
                    tokens=list(rng.randint(1, lvlm.cfg.vocab_size,
                                            size=12)),
                    visual_embeds=rng.randn(
                        lvlm.cfg.num_visual_tokens,
                        lvlm.cfg.d_model).astype(np.float32) * 0.02,
                    max_new_tokens=8,
                    compression="framefusion-0.25" if i % 2 else None)
            for i in range(6)]
    report = lvlm.serve(
        reqs,
        EngineConfig(max_batch=4, cache_len=128, scheduler="continuous"),
        gen=GenerationConfig(max_new_tokens=8, compression="divprune-0.5"))
    stats = report.stats
    print(f"served {stats['finished']} requests, {stats['tokens']} tokens, "
          f"throughput {stats['throughput_tok_per_s']:.0f} tok/s (virtual)")
    for name, cs in report.engine.compression_stats().items():
        print(f"  {name}: prefill token reduction "
              f"{cs['prefill_token_reduction']:.2f}")


if __name__ == "__main__":
    main()
