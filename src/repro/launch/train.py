"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --smoke --steps 100 --batch 8 --seq 64

``--smoke`` runs the reduced config on the local device (the container's
CPU); without it the full config is lowered under the production mesh,
which on this CPU container only makes sense via ``--dry-run`` (alias of
launch/dryrun.py for the train_4k shape).
"""
from __future__ import annotations

import argparse

from repro.configs import ARCHS, get_config
from repro.models.registry import build
from repro.training import (OptimizerConfig, SyntheticDataConfig,
                            train_loop)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the local device")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vocab", type=int, default=0,
                    help="override vocab (smaller = faster smoke)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower/compile train_4k under the production mesh")
    args = ap.parse_args()

    if args.dry_run:
        # delegated: dryrun.py must own the process (XLA_FLAGS ordering)
        import os
        import subprocess
        import sys
        return subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", args.arch, "--shape", "train_4k"],
            env=dict(os.environ, PYTHONPATH="src"))

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.vocab:
        cfg = cfg.with_(vocab_size=args.vocab)
    model = build(cfg)
    out = train_loop(
        model,
        oc=OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps),
        dc=SyntheticDataConfig(batch=args.batch, seq_len=args.seq),
        num_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, resume=args.resume)
    print(f"done: loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
          f"in {out['steps']} steps ({out['wall_s']:.1f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
