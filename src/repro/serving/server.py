"""``AsyncLVLMServer``: the asyncio pump over the grouped Engine.

One background task drives ``Engine.step()`` -- each step one fixed-shape
jitted iteration over the whole slot pool, decode slots grouped per
request strategy -- and fans newly emitted tokens out to per-request
``TokenStream`` queues. Clients are plain coroutines:

    server = lvlm.serve_async(EngineConfig(max_batch=8, cache_len=256))
    async with server:
        stream = server.submit(Request(rid=0, tokens=prompt,
                                       decoder="speculative"))
        async for tok in stream:          # tokens as the engine emits them
            ...
            if bored:
                stream.cancel()           # frees slot + draft row + pins
                break

Design points:

  * Everything is event-loop-confined: submits, aborts, and the pump
    interleave only at awaits, so there are no locks and the engine is
    never re-entered. The jitted step blocks the loop while computing --
    by design: the accelerator is the serial resource; asyncio buys
    request multiplexing, streaming delivery, and backpressure.
  * Admission runs lazily on the stream's FIRST ``__anext__`` (i.e. when
    the client starts consuming), so ``submit`` itself never blocks;
    under KV pressure the client awaits inside the admission gate instead
    of the engine crashing.
  * Determinism: the engine's virtual clock and temperature-0 decoding
    make the async path bit-identical to the sync facade
    (``tests/test_async_serving.py`` locks this down).
  * Pacing: ``pacing="virtual"`` (default) runs steps back-to-back and
    time exists only on the engine's virtual clock -- deterministic, the
    mode every test uses. ``pacing="wall"`` sleeps each step's virtual
    duration (scaled by ``pacing_scale``) in REAL time, so open-loop
    arrivals, client think-time, and disconnect timeouts play out on the
    wall clock the way they would against hardware.
  * ``disconnect_timeout_s``: a consumer whose unread token backlog
    stays untouched for that many WALL seconds (measured across post-step
    checks, so loop-blocking jit time never counts against it) is treated
    as hung up -- the request is aborted and every held resource
    (KV slot, draft row, gamma lookahead, prefix pin) is released.
  * ``stop()`` drains by default (finishes in-flight work); pass
    ``drain=False`` to abort all live streams first.
"""
from __future__ import annotations

import asyncio
from typing import Dict, List, Optional

from repro.core.serving.request import Request, State
from repro.serving.admission import AdmissionConfig, AdmissionController
from repro.serving.metrics import MetricsRegistry

_DONE = object()                      # stream sentinel


class MigrateSignal(Exception):
    """Pushed into a stream's queue when its request parks in MIGRATING
    (disaggregated handoff after prefill, or a drain's live migration).
    The consumer-side Router catches it and runs the migration protocol
    -- export, import on a sibling, source release -- from the consumer
    task, so the pump never blocks on a sibling server. Seeing it raised
    from a bare ``TokenStream`` means a migration was requested on a
    server with no fronting ``repro.cluster.Router``."""

    def __init__(self, rid: int):
        super().__init__(f"request {rid} awaiting KV migration")
        self.rid = rid


class TokenStream:
    """One request's async token channel (single consumer).

    ``async for tok in stream`` yields token ids as the engine emits them
    (speculative rounds surface several per step). ``cancel()`` aborts
    the request mid-stream; tokens already emitted remain readable, then
    the iterator ends.
    """

    def __init__(self, server: "AsyncLVLMServer", request: Request):
        self._server = server
        self.request = request
        self._q: asyncio.Queue = asyncio.Queue()
        self._pushed = 0              # tokens fanned out so far
        self._submitted = False
        self._finished = False
        self.aborted = False
        self.disconnected = False     # aborted by the disconnect timeout
        self.submit_clock: Optional[float] = None
        self.admit_clock: Optional[float] = None
        self._migrate_signaled = False   # MigrateSignal already queued
        # wall-clock consumer liveness (disconnect-timeout bookkeeping)
        self._reading = False         # consumer currently inside __anext__
        self._pending_since = None    # first post-step sighting of an
        #                               unread backlog (None = no backlog)

    @property
    def queue_wait(self) -> float:
        """Virtual-clock admission-gate wait (0 until admitted)."""
        if self.submit_clock is None or self.admit_clock is None:
            return 0.0
        return self.admit_clock - self.submit_clock

    @property
    def tokens(self) -> List[int]:
        """Tokens generated so far (complete once the stream ends)."""
        return list(self.request.generated)

    def cancel(self) -> bool:
        """Abort mid-stream; see ``AsyncLVLMServer.abort``."""
        return self._server.abort(self.request.rid)

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        self._reading = True            # an awaiting consumer is NOT hung up
        try:
            if not self._submitted and not self._finished:
                await self._server._admit(self)
            if self._finished and self._q.empty():
                raise StopAsyncIteration
            item = await self._q.get()
        finally:
            self._reading = False
            self._pending_since = None  # the consumer is keeping up
        if item is _DONE:
            raise StopAsyncIteration
        if isinstance(item, BaseException):
            raise item                  # pump failure propagates, no hang
        return item


class AsyncLVLMServer:
    """Async streaming server over one Engine (see module docstring).

    Build via ``LVLM.serve_async(engine_cfg, gen=..., draft=...,
    admission=...)``; the engine wiring (decoder registry, compression,
    temperature plumbing) is exactly ``LVLM.serve``'s.
    """

    def __init__(self, lvlm, *, engine_cfg=None, gen=None, draft=None,
                 admission: Optional[AdmissionConfig] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 compressors: Optional[Dict] = None,
                 pacing: str = "virtual", pacing_scale: float = 1.0,
                 disconnect_timeout_s: Optional[float] = None,
                 tracer=None, profiler=None, control=None):
        if pacing not in ("virtual", "wall"):
            raise ValueError("pacing must be 'virtual' or 'wall'")
        self.engine = lvlm._serve_engine(engine_cfg, gen, draft,
                                         compressors=compressors,
                                         tracer=tracer, profiler=profiler)
        # the server shares the engine's tracer (NULL_TRACER when off);
        # admission-gate spans and pump counter tracks are emitted here
        self.tracer = self.engine.tracer
        # ... and its profiler (NULL_PROFILER when off): hot-path site
        # histograms surface through metrics_snapshot()
        self.profiler = self.engine.profiler
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.admission = AdmissionController(
            admission if admission is not None else AdmissionConfig(),
            self.engine)
        if self.admission.cfg.order == "slack":
            self.admission.order_key = self._slack
        self.pacing = pacing
        self.pacing_scale = pacing_scale
        self.disconnect_timeout_s = disconnect_timeout_s
        self.disconnects = 0
        # callback(rid) fired after ANY successful abort -- lets a fronting
        # layer (the cluster Router) drop its own bookkeeping for aborts it
        # did not initiate (disconnect timeouts fire inside the pump)
        self.on_abort = None
        self._streams: Dict[int, TokenStream] = {}
        self._wake: Optional[asyncio.Event] = None
        self._pump_task: Optional[asyncio.Task] = None
        self._stopping = False
        self._pump_error: Optional[BaseException] = None
        # SLO-adaptive controller (repro.control), possibly shared
        # fleet-wide like the tracer/profiler. None = zero policy calls:
        # every call site below guards on `is not None`, same discipline
        # as tracer.enabled (locked by a patch-to-raise test).
        self.control = control
        if control is not None:
            control.attach(self)
        # runtime sanitizer (repro.analysis.sanitizer): follows the
        # engine's resolved flag (EngineConfig.sanitize / REPRO_SANITIZE)
        self.sanitize = bool(getattr(self.engine, "sanitize", False))

    def _sanitize_check(self) -> None:
        from repro.analysis.sanitizer import (assert_conserved,
                                              check_server_conservation)
        assert_conserved(self, check_server_conservation,
                         "AsyncLVLMServer pump step")

    def _slack(self, req: Request) -> float:
        """SLO slack of a deferred request: its TTFT deadline (anchored at
        the later of arrival and the clock when it was parked) minus now
        and minus the fleet's live expected TTFT. The clock and
        expected-TTFT terms are uniform across the waiters of one drain,
        so the resulting ORDER is earliest-deadline-first; they are kept
        so the value is a true (sign-meaningful) slack for telemetry and
        future deadline-shedding policies. Deadlines are FIXED per request
        while new arrivals' deadlines recede -- EDF drain order is
        therefore starvation-free under saturation."""
        anchor = max(req.arrival, getattr(req, "_gate_clock", 0.0))
        deadline = anchor + req.slo.ttft_ms * 1e-3
        return deadline - self.engine.clock - self.metrics.expected_ttft()

    # -------------------------------------------------------- lifecycle --
    async def start(self) -> "AsyncLVLMServer":
        if self._pump_task is None:
            self._stopping = False
            self._wake = asyncio.Event()
            self._pump_task = asyncio.get_running_loop().create_task(
                self._pump())
        return self

    async def stop(self, drain: bool = True) -> None:
        """Stop the pump. ``drain=True`` finishes in-flight requests
        first; ``drain=False`` aborts every live stream immediately."""
        if self._pump_task is None:
            return
        if not drain:
            self.admission.cancel_waiters()
            for rid in list(self._streams):
                self.abort(rid)
        self._stopping = True
        self._wake.set()
        try:
            await self._pump_task      # re-raises a pump failure here
        finally:
            self._pump_task = None

    async def __aenter__(self) -> "AsyncLVLMServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=not any(exc))

    # ----------------------------------------------------------- intake --
    def submit(self, request: Request) -> TokenStream:
        """Register a request and return its token stream. Admission (and
        hence any backpressure await) happens on the stream's first
        ``__anext__`` -- ``submit`` itself never blocks. The rid is
        reserved immediately, so a duplicate submit fails fast and a
        ``cancel()`` BEFORE the first ``__anext__`` already aborts."""
        if request.rid in self._streams:
            raise ValueError(f"request id {request.rid} already streaming")
        stream = TokenStream(self, request)
        self._streams[request.rid] = stream
        return stream

    async def _admit(self, stream: TokenStream) -> None:
        if self._pump_error is not None:
            raise RuntimeError("server pump failed") from self._pump_error
        if self._pump_task is None:
            await self.start()          # lazy start outside `async with`
        stream._submitted = True
        stream.submit_clock = self.engine.clock
        rid = stream.request.rid
        rep = self.engine.trace_replica
        if self.tracer.enabled:
            self.tracer.span_begin("admission_wait", rid, replica=rep,
                                   vt=self.engine.clock)
        if self.control is not None:
            # under pressure: degrade the incoming request's shape BEFORE
            # the watermark check (aggressive preset = smaller KV need)
            self.control.shape(self, stream.request)
        try:
            admitted = await self.admission.admit(stream.request)
        except asyncio.CancelledError:
            self._streams.pop(stream.request.rid, None)
            if self.control is not None:
                self.control.revert(stream.request)
            stream.aborted = True
            stream._finished = True
            if self.tracer.enabled:
                self.tracer.span_abort(rid, replica=rep,
                                       vt=self.engine.clock,
                                       reason="cancelled at admission")
            raise
        if not admitted:
            if self.control is not None:
                self.control.revert(stream.request)
            if self.tracer.enabled:
                self.tracer.span_end("admission_wait", rid, replica=rep,
                                     vt=self.engine.clock, cancelled=True)
            return                      # cancelled at the admission gate
        if self.control is not None:
            # the request entered the engine under its (possibly
            # degraded) fields: consume the override record
            self.control.commit(stream.request)
        stream.admit_clock = self.engine.clock
        if self.tracer.enabled:
            self.tracer.span_end("admission_wait", rid, replica=rep,
                                 vt=self.engine.clock)
        self._wake.set()

    def abort(self, rid: int) -> bool:
        """Cancel a live request: ``Engine.abort`` frees its KV slot, any
        speculative draft-pool slot, the reserved lookahead, and its
        prefix pin; already-emitted tokens stay readable on the stream.
        Works at every lifecycle stage: not-yet-iterated, waiting at the
        admission gate, or mid-decode."""
        stream = self._streams.pop(rid, None)
        ok = self.engine.abort(rid)
        if stream is not None:
            if not ok and stream._submitted:
                # parked at the admission gate: retract the waiter so the
                # cancelled request never enters the engine
                self.admission.cancel(stream.request)
            stream.aborted = True
            stream.request.aborted = True
            self._fan_out(stream)
            self._finish_stream(stream, aborted=True)
        self.admission.maybe_admit()     # freed capacity -> drain waiters
        aborted = ok or stream is not None
        if aborted and self.on_abort is not None:
            self.on_abort(rid)
        return aborted

    # -------------------------------------------------------- migration --
    def request_migration(self, rid: int) -> bool:
        """Ask for ``rid`` to be migrated off this server. An exportable
        DECODE-phase request parks in MIGRATING now (the pump then pushes
        a ``MigrateSignal`` to its consumer); a request still waiting,
        prefilling, or parked at the admission gate is flagged ``handoff``
        so it parks right after its prefill. Returns False when the
        request is unknown, finished, or not exportable -- it then simply
        finishes here."""
        eng = self.engine
        for r in eng.running:
            if r.rid == rid and r.state is State.DECODE:
                if not eng.can_export(r):
                    return False
                r.state = State.MIGRATING
                if self._wake is not None:
                    self._wake.set()
                return True
        for r in list(eng.waiting) + [x for x in eng.running
                                      if x.state is State.PREFILL]:
            if r.rid == rid and r.state is not State.DONE:
                if not eng.can_export(r):
                    return False
                r.handoff = True
                if self._wake is not None:
                    self._wake.set()
                return True
        stream = self._streams.get(rid)
        if stream is not None and not stream.aborted \
                and stream.request.state is State.WAITING:
            # parked at the admission gate: prefill will park it for
            # export once admitted
            if not eng.can_export(stream.request):
                return False
            stream.request.handoff = True
            return True
        return False

    async def import_stream(self, request: Request, ticket: Dict, *,
                            ready_at: float = 0.0) -> TokenStream:
        """Adopt a request migrated FROM a sibling server: register its
        stream (tokens the source already delivered are not replayed) and
        commit the KV import through the admission gate, so migrated KV
        respects the same watermarks as fresh admissions. On any failure
        (no free slot, cancelled, pump dead) nothing stays registered and
        the caller still holds the source's export pin."""
        if self._pump_error is not None:
            raise RuntimeError("server pump failed") from self._pump_error
        rid = request.rid
        if self._pump_task is None:
            await self.start()
        if rid in self._streams:
            raise ValueError(f"request id {rid} already streaming")
        stream = TokenStream(self, request)
        stream._submitted = True
        stream._pushed = len(request.generated)  # source already delivered
        stream.submit_clock = self.engine.clock
        # full-decode KV accounting from the first watermark check on: the
        # request decodes HERE even though its prefill ran elsewhere
        request._imported = True
        # the stream registers BEFORE the admission await so the
        # sanitizer's live-rid/stream invariant holds the moment the
        # import commits inside the gate
        # analysis: atomic-step (the duplicate-rid check runs AFTER the
        # lazy start() suspension, with no await between it and this
        # registration)
        self._streams[rid] = stream
        rep = self.engine.trace_replica
        if self.tracer.enabled:
            # the import waits out the same watermarks as a fresh
            # admission; on failure ONLY this span closes -- the request
            # (and its open kv_migration span) stays live on the source,
            # which resumes it via cancel_export or tries a sibling
            self.tracer.span_begin("admission_wait", rid, replica=rep,
                                   vt=self.engine.clock, imported=True)
        try:
            admitted = await self.admission.admit(
                request,
                submit=lambda r: self.engine.import_kv(r, ticket,
                                                       ready_at=ready_at))
        except BaseException:
            # analysis: atomic-step (retracts only this coroutine's own
            # registration; no other stream state is assumed unchanged
            # across the await)
            self._streams.pop(rid, None)
            stream._finished = True
            if self.tracer.enabled:
                self.tracer.span_end("admission_wait", rid, replica=rep,
                                     vt=self.engine.clock, failed=True)
            raise
        if not admitted:
            # analysis: atomic-step (same single-entry retraction as the
            # failure path above)
            self._streams.pop(rid, None)
            stream._finished = True
            if self.tracer.enabled:
                self.tracer.span_end("admission_wait", rid, replica=rep,
                                     vt=self.engine.clock, failed=True)
            raise RuntimeError(
                f"import of rid {rid} retracted at the admission gate")
        stream.admit_clock = self.engine.clock
        if self.tracer.enabled:
            self.tracer.span_end("admission_wait", rid, replica=rep,
                                 vt=self.engine.clock)
        self._wake.set()
        return stream

    def complete_export(self, rid: int) -> None:
        """Source-side release after a sibling committed the import (see
        ``Engine.complete_export``); wakes the pump so a now-unblocked
        drain can finish."""
        self.engine.complete_export(rid)
        self.admission.maybe_admit()     # freed KV -> drain waiters
        if self._wake is not None:
            self._wake.set()

    def cancel_export(self, rid: int) -> None:
        """Back out a migration: the request resumes decoding here."""
        self.engine.cancel_export(rid)
        stream = self._streams.get(rid)
        if stream is not None:
            stream._migrate_signaled = False   # a later drain may retry
        if self._wake is not None:
            self._wake.set()

    def release_migrated(self, rid: int) -> None:
        """Deregister the stream of a request migrated AWAY. No metrics
        record here -- the importing server observes the completed
        request, so fleet-merged registries count it exactly once."""
        stream = self._streams.pop(rid, None)
        if stream is not None:
            stream._finished = True

    # ------------------------------------------------------------- pump --
    async def _pump(self) -> None:
        eng = self.engine
        try:
            while True:
                before = eng.clock
                progressed = False
                if eng.waiting or eng.running:
                    progressed = eng.step()  # one jitted grouped iteration
                self._drain()
                self._check_disconnects()
                self.admission.maybe_admit()
                if self.control is not None:
                    # observe pressure, walk the degradation ladder,
                    # reshape deferred waiters on a level change
                    self.control.on_step(self)
                if progressed and self.tracer.enabled:
                    self._emit_counters()
                if self.sanitize:
                    self._sanitize_check()   # conservation at the boundary
                if not progressed:
                    # idle, or every live request is frozen (MIGRATING /
                    # awaiting its KV transfer): park until a submit,
                    # migration completion, or stop wakes the pump --
                    # never busy-spin
                    if self._stopping:
                        return
                    self._wake.clear()
                    await self._wake.wait()
                    continue
                if self.pacing == "wall":
                    # sleep the step's virtual duration in real time (the
                    # analytic per-step latency estimate), scaled; clients
                    # consume during the sleep just as they would while a
                    # real accelerator computes
                    await asyncio.sleep(
                        max(0.0, (eng.clock - before) * self.pacing_scale))
                else:
                    await asyncio.sleep(0)   # let clients consume this step
        except BaseException as exc:     # fail streams: never hang clients
            self._fail(exc)
            raise

    def _emit_counters(self) -> None:
        """Post-step counter tracks: KV watermark, admission queue depth,
        prefix hits (local + cluster tier), migration bytes in flight --
        the live time-series the SLO-adaptive controller (ROADMAP) will
        consume and the Perfetto export renders as counter lanes."""
        eng = self.engine
        rep = eng.trace_replica
        vt = eng.clock
        t = self.tracer
        t.counter("kv_committed_tokens", eng.kv_committed_tokens(),
                  replica=rep, vt=vt)
        t.counter("admission_queue_depth", len(self.admission._waiters),
                  replica=rep, vt=vt)
        t.counter("prefix_hit_tokens", eng.prefix_hit_tokens,
                  replica=rep, vt=vt)
        if eng.prefix_share is not None:
            stats = eng.prefix_share.stats()
            t.counter("prefix_tier_hits", stats.get("hits", 0),
                      replica=rep, vt=vt)
        t.counter("migration_bytes_inflight",
                  eng._export_bytes_inflight(), replica=rep, vt=vt)

    def _check_disconnects(self) -> None:
        """Abort streams whose consumer hung up: tokens stayed queued
        unread with no ``__anext__`` awaiting for more than
        ``disconnect_timeout_s`` WALL seconds. Backlog age is anchored at
        the first POST-step sighting (this method runs right after each
        step) and every read clears it, so time the event loop spent
        blocked inside a jitted step -- when the consumer could not
        possibly run -- never counts against the consumer. The abort
        releases the slot / draft row / gamma lookahead / prefix pin
        exactly like an explicit ``cancel()``."""
        if self.disconnect_timeout_s is None or not self._streams:
            return
        now = asyncio.get_running_loop().time()
        for rid, stream in list(self._streams.items()):
            if stream._reading or stream._q.empty():
                stream._pending_since = None   # consuming / nothing unread
                continue
            if stream._pending_since is None:
                stream._pending_since = now    # backlog first seen NOW
                continue
            if now - stream._pending_since > self.disconnect_timeout_s:
                stream.disconnected = True
                self.disconnects += 1
                self.abort(rid)

    def _fail(self, exc: BaseException) -> None:
        """Pump died: every live stream and admission waiter must learn,
        or their consumers would await a sentinel that never comes."""
        self._pump_error = exc
        self.admission.cancel_waiters()
        for rid, stream in list(self._streams.items()):
            del self._streams[rid]
            self._fan_out(stream)
            stream._finished = True
            stream._q.put_nowait(exc)
            if self.tracer.enabled:
                # close every span the dead replica still holds open; a
                # fronting Router's failover re-begins the request span
                # on the replica it redispatches to
                self.tracer.span_abort(rid,
                                       replica=self.engine.trace_replica,
                                       vt=self.engine.clock,
                                       reason="pump failure")

    def _fan_out(self, stream: TokenStream) -> None:
        gen = stream.request.generated
        while stream._pushed < len(gen):
            stream._q.put_nowait(gen[stream._pushed])
            stream._pushed += 1

    def _finish_stream(self, stream: TokenStream, aborted: bool) -> None:
        stream._finished = True
        stream._q.put_nowait(_DONE)
        req = stream.request
        name = req.decoder or self.engine._default_name
        self.metrics.observe(req, queue_wait=stream.queue_wait,
                             decoder=name, aborted=aborted)

    def _drain(self) -> None:
        for rid, stream in list(self._streams.items()):
            self._fan_out(stream)
            if stream.request.state is State.DONE:
                del self._streams[rid]
                self._finish_stream(stream, aborted=False)
            elif (stream.request.state is State.MIGRATING
                  and not stream._migrate_signaled):
                # tell the consumer -- after any tokens already fanned out
                # -- to run the migration protocol from its own task
                stream._migrate_signaled = True
                stream._q.put_nowait(MigrateSignal(rid))

    # ---------------------------------------------------------- reports --
    def metrics_snapshot(self, *, replica: Optional[int] = None) -> str:
        """Pull-based metrics snapshot in Prometheus text exposition
        format: request-latency summaries (exact quantiles over the
        registry's records), live engine gauges (KV watermark, pool
        occupancy, virtual clock), and admission counters. ``replica``
        adds a ``replica="i"`` label to every family (the Router passes
        each replica's index)."""
        from repro.obs.prom import (PromText, engine_families,
                                    registry_families)
        prom = PromText()
        labels = ({"replica": str(replica)}
                  if replica is not None else None)
        registry_families(prom, self.metrics.records, labels=labels)
        engine_families(prom, self.engine, labels=labels)
        prom.counter("admitted_total", "Requests admitted.",
                     self.admission.admitted, labels=labels)
        prom.counter("deferred_total",
                     "Requests deferred at the admission gate.",
                     self.admission.deferrals, labels=labels)
        prom.gauge("admission_queue_depth",
                   "Requests parked at the admission gate.",
                   len(self.admission._waiters), labels=labels)
        prom.gauge("admission_draining",
                   "1 while the admission gate holds admits until "
                   "committed KV falls to the low watermark.",
                   int(self.admission.draining), labels=labels)
        prom.counter("disconnects_total",
                     "Streams aborted by the disconnect timeout.",
                     self.disconnects, labels=labels)
        # standalone server: render the profiler's hot-path site
        # histograms here; in a fleet the profiler is shared, so the
        # Router renders them ONCE at fleet level (replica label absent)
        if replica is None and self.profiler.enabled:
            from repro.obs.profile import profile_families
            profile_families(prom, self.profiler)
        # same sharing rule for the adaptive controller's families
        if replica is None and self.control is not None:
            self.control.prom_families(prom)
        return prom.render()

    def summary(self) -> Dict:
        """Metrics summary + admission counters (see MetricsRegistry)."""
        out = self.metrics.summary(self.engine)
        out["admitted"] = self.admission.admitted
        out["deferred"] = self.admission.deferrals
        out["disconnects"] = self.disconnects
        out.update({f"decoder_stats/{k}": v
                    for k, v in self.engine.decoder_stats().items()
                    if not isinstance(v, (list, dict))})
        # per-compression-strategy prefill token reduction (dim 1): what
        # the mixed-workload benchmarks chart per preset
        for name, cs in self.engine.compression_stats().items():
            for k, v in cs.items():
                out[f"compression/{name}/{k}"] = v
        if self.control is not None:
            out.update(self.control.summary())
        return out
