"""Multimodal speculative decoding demo (survey dim 4a), via the
``repro.api`` facade.

A language-only draft speculates for a multimodal target (Gagrani et al.):
the draft never sees the image; the target verifies with full context.
A distilled draft shows real acceptance; LANTERN relaxation on top.

    PYTHONPATH=src python examples/spec_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import GenerationConfig, LVLM
from repro.training import OptimizerConfig, adamw_init, adamw_update


def distill_draft(target, t_params, draft, d_params, vocab, steps=60):
    """Train the draft to mimic the target's next-token logits (tiny KD)."""
    oc = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=steps,
                         weight_decay=0.0)
    opt = adamw_init(d_params)
    rng = np.random.RandomState(0)

    @jax.jit
    def step(d_params, opt, tokens):
        t_logits, _ = target.forward(t_params, {"tokens": tokens})
        t_probs = jax.nn.softmax(t_logits, -1)

        def loss_fn(p):
            d_logits, _ = draft.forward(p, {"tokens": tokens})
            lsm = jax.nn.log_softmax(d_logits, -1)
            return -(t_probs * lsm).sum(-1).mean()
        loss, grads = jax.value_and_grad(loss_fn)(d_params)
        d_params, opt, _ = adamw_update(oc, grads, opt, d_params)
        return d_params, opt, loss

    for s in range(steps):
        tokens = jnp.asarray(rng.randint(1, vocab, (8, 24)), jnp.int32)
        d_params, opt, loss = step(d_params, opt, tokens)
        if s % 20 == 0:
            print(f"  distill step {s:3d} KD-loss {float(loss):.4f}")
    return d_params


def main():
    target = LVLM.from_pretrained("qwen2-vl-2b", smoke=True, vocab_size=512)
    # train the target briefly so its outputs have learnable structure
    # (an untrained target's greedy stream is noise no draft can match)
    from repro.training import SyntheticDataConfig, train_loop
    print("== training target on the synthetic stream")
    t_out = train_loop(target.model,
                       oc=OptimizerConfig(lr=2e-3, warmup_steps=5,
                                          total_steps=80),
                       dc=SyntheticDataConfig(batch=8, seq_len=32),
                       num_steps=80, log_every=40)
    target = target.with_params(t_out["params"])
    # language-only draft: NO visual pathway (dense family, tiny)
    draft = LVLM.from_pretrained(
        "phi4-mini-3.8b", smoke=True, seed=1,
        num_layers=1, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
        head_dim=32, vocab_size=target.cfg.vocab_size)

    rng = np.random.RandomState(2)
    prompt = list(rng.randint(1, target.cfg.vocab_size, size=20))
    ve = (rng.randn(target.cfg.num_visual_tokens, target.cfg.d_model)
          * 0.02).astype(np.float32)
    n_new, gamma = 24, 4
    spec = GenerationConfig(decoder="speculative", temperature=0.0,
                            max_new_tokens=n_new, gamma=gamma)

    print("== random draft (no training)")
    r0 = target.generate(prompt, spec, visual_embeds=ve, draft=draft)
    print(f"  acceptance={r0.stats['acceptance']:.2f} "
          f"target_calls={r0.stats['target_calls']} "
          f"(vs {n_new} sequential)")

    print("== distilled language-only draft")
    draft = draft.with_params(distill_draft(
        target.model, target.params, draft.model, draft.params,
        target.cfg.vocab_size, steps=150))
    r1 = target.generate(prompt, spec, visual_embeds=ve, draft=draft)
    print(f"  acceptance={r1.stats['acceptance']:.2f} "
          f"target_calls={r1.stats['target_calls']} "
          f"call_reduction={n_new / r1.stats['target_calls']:.2f}x")

    print("== + LANTERN relaxed acceptance (temperature 0.8)")
    r2 = target.generate(
        prompt, spec.with_(temperature=0.8, lantern_k=16,
                           lantern_delta=0.3),
        visual_embeds=ve, draft=draft)
    print(f"  acceptance={r2.stats['acceptance']:.2f} "
          f"target_calls={r2.stats['target_calls']}")

    # fidelity: greedy speculative == greedy target, draft quality aside
    assert r1.tokens == r0.tokens, "greedy outputs must agree"
    ref = target.generate(prompt, GenerationConfig(
        decoder="greedy", max_new_tokens=n_new), visual_embeds=ve)
    assert r1.tokens == ref.tokens, "speculative must match target greedy"
    print("greedy fidelity check passed")


if __name__ == "__main__":
    main()
