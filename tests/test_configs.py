"""Assigned-architecture configs: exact numbers + smoke-variant limits."""
import pytest

from repro.configs import ARCHS, SHAPES, get_config

# (arch, layers, d_model, heads, kv_heads, d_ff, vocab) from the assignment
ASSIGNED = {
    "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
    "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
    "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
    "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
    "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    "granite-34b": (88, 6144, 48, 1, 24576, 49152),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
}

FAMILY = {
    "mistral-large-123b": "dense", "deepseek-v3-671b": "moe",
    "qwen2-vl-2b": "vlm", "arctic-480b": "moe", "phi4-mini-3.8b": "dense",
    "rwkv6-3b": "ssm", "nemotron-4-340b": "dense", "whisper-tiny": "audio",
    "granite-34b": "dense", "zamba2-1.2b": "hybrid",
}


@pytest.mark.parametrize("arch", ARCHS)
def test_assigned_numbers(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    # MoE archs: the assigned d_ff is the per-expert hidden width
    assert ff in (cfg.d_ff, cfg.moe_d_ff)
    assert cfg.vocab_size == v
    assert cfg.family == FAMILY[arch]
    if cfg.family != "ssm":
        assert cfg.num_heads == h
        assert cfg.num_kv_heads == kv
    assert cfg.source, "every config must cite its source"


def test_moe_details():
    ds = get_config("deepseek-v3-671b")
    assert (ds.num_experts, ds.experts_per_token,
            ds.num_shared_experts) == (256, 8, 1)
    assert ds.use_mla
    ar = get_config("arctic-480b")
    assert (ar.num_experts, ar.experts_per_token) == (128, 2)
    assert ar.dense_residual


def test_param_counts_in_band():
    """Param counts should match the names within tolerance."""
    expect = {"mistral-large-123b": 123e9, "deepseek-v3-671b": 671e9,
              "qwen2-vl-2b": 2e9, "arctic-480b": 480e9,
              "phi4-mini-3.8b": 3.8e9, "rwkv6-3b": 3e9,
              "nemotron-4-340b": 340e9, "granite-34b": 34e9,
              "zamba2-1.2b": 1.2e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.7 * n < got < 1.4 * n, f"{arch}: {got/1e9:.1f}B vs {n/1e9}B"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_variant_is_reduced(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    assert (cfg.num_experts or 0) <= 4
    assert cfg.family == FAMILY[arch]


def test_shapes():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1
