"""Visual token compression (dim 1): invariants + hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import CompressionConfig
from repro.core.token_compression.merging import (prune_then_merge,
                                                  tome_merge, tome_to_count)
from repro.core.token_compression.policy import (
    compress_visual_tokens, fastv_scores_from_attention)
from repro.core.token_compression.pruning import (PRUNERS,
                                                  pyramiddrop_schedule)
from repro.core.token_compression import video


def _embeds(b, n, d, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, n, d),
                             jnp.float32)


@pytest.mark.parametrize("name", sorted(PRUNERS))
def test_pruner_invariants(name):
    b, n, d, keep = 2, 32, 16, 8
    embeds = _embeds(b, n, d)
    kwargs = {}
    if name == "fastv":
        kwargs["scores"] = jax.random.uniform(jax.random.PRNGKey(1), (b, n))
    if name in ("sparsevlm", "cdpruner"):
        kwargs["query"] = _embeds(b, 4, d, seed=2)
    kept, idx, info = PRUNERS[name](embeds, keep, **kwargs)
    assert kept.shape == (b, keep, d)
    assert idx.shape == (b, keep)
    idx_np = np.asarray(idx)
    # ascending order (RoPE monotonicity requirement) and uniqueness
    assert (np.diff(idx_np, axis=1) > 0).all(), f"{name}: idx not unique-sorted"
    assert (idx_np >= 0).all() and (idx_np < n).all()
    # kept embeds really are the selected rows
    np.testing.assert_allclose(
        np.asarray(kept), np.take_along_axis(np.asarray(embeds),
                                             idx_np[..., None], axis=1))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(6, 48), keep_frac=st.floats(0.2, 0.9), seed=st.integers(0, 99))
def test_l2_pruner_property(n, keep_frac, seed):
    keep = max(1, int(n * keep_frac))
    embeds = _embeds(1, n, 8, seed=seed)
    kept, idx, _ = PRUNERS["l2"](embeds, keep)
    assert kept.shape == (1, keep, 8)
    idx_np = np.asarray(idx[0])
    assert len(set(idx_np.tolist())) == keep
    # l2 keeps the LOWEST-norm tokens
    norms = np.linalg.norm(np.asarray(embeds[0]), axis=-1)
    chosen = set(idx_np.tolist())
    worst_kept = max(norms[i] for i in chosen)
    best_dropped = min((norms[i] for i in range(n) if i not in chosen),
                       default=np.inf)
    assert worst_kept <= best_dropped + 1e-5


def test_divprune_beats_random_diversity():
    """DivPrune's min pairwise distance >= random subset's (its objective)."""
    rng = np.random.RandomState(0)
    # clustered data: many near-duplicates (sky/wall patches)
    centers = rng.randn(4, 16)
    pts = np.concatenate([c + 0.05 * rng.randn(16, 16) for c in centers])
    embeds = jnp.asarray(pts[None], jnp.float32)
    keep = 8

    def min_dist(idx):
        x = pts[idx]
        x = x / np.linalg.norm(x, axis=1, keepdims=True)
        s = 1 - x @ x.T
        return (s + np.eye(len(idx)) * 9).min()

    _, idx, _ = PRUNERS["divprune"](embeds, keep)
    div_score = min_dist(np.asarray(idx[0]))
    rand_scores = [min_dist(rng.choice(64, keep, replace=False))
                   for _ in range(50)]
    assert div_score >= np.mean(rand_scores)


def test_fastv_scores_and_policy():
    b, hq, sq, n_total = 2, 4, 24, 24
    attn = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(0), (b, hq, sq, n_total)), -1)
    scores = fastv_scores_from_attention(attn, (0, 16))
    assert scores.shape == (b, 16)
    cc = CompressionConfig(token_pruner="fastv", keep_ratio=0.5)
    embeds = _embeds(b, 16, 8)
    kept, idx, info = compress_visual_tokens(cc, embeds, scores=scores)
    assert kept.shape == (b, 8, 8)


def test_fastv_scores_uniform_attention_is_uniform():
    """Uniform attention spreads 1/Sk to every key: each visual token's
    received-attention score must be exactly 1/Sk."""
    b, h, sq, sk = 2, 3, 5, 20
    attn = jnp.full((b, h, sq, sk), 1.0 / sk)
    scores = fastv_scores_from_attention(attn, (4, 12))
    assert scores.shape == (b, 8)
    np.testing.assert_allclose(np.asarray(scores), 1.0 / sk, rtol=1e-6)


def test_fastv_scores_mean_over_heads_and_queries_with_offset_slice():
    """Score = mean over heads AND queries of the attention each visual
    KEY receives, honoring a non-zero slice start: concentrating every
    query on key ``start + j`` must make j the argmax, and hand-computed
    means must match exactly."""
    b, h, sq, sk, start, stop = 1, 2, 4, 16, 5, 13
    rng = np.random.RandomState(0)
    attn = rng.rand(b, h, sq, sk).astype(np.float32)
    attn /= attn.sum(-1, keepdims=True)
    scores = fastv_scores_from_attention(jnp.asarray(attn), (start, stop))
    expect = attn[..., start:stop].mean(axis=(1, 2))
    np.testing.assert_allclose(np.asarray(scores), expect, rtol=1e-6)
    # concentrated attention: all mass on visual key j (absolute index
    # start + j) -> that key dominates the in-slice scores
    j = 2
    conc = np.full((b, h, sq, sk), 1e-4, np.float32)
    conc[..., start + j] = 1.0
    conc /= conc.sum(-1, keepdims=True)
    s = np.asarray(fastv_scores_from_attention(jnp.asarray(conc),
                                               (start, stop)))
    assert int(s[0].argmax()) == j
    # keys OUTSIDE the visual slice never leak into the scores
    assert s.shape == (b, stop - start)


def test_fastv_scores_drive_pruner_to_attended_tokens():
    """End-to-end: the tokens FastV keeps are exactly the most-attended
    visual keys under the scores this helper computes."""
    b, h, sq, n = 1, 2, 6, 12
    hot = [1, 4, 7, 10]
    attn = np.full((b, h, sq, n), 1e-3, np.float32)
    for k in hot:
        attn[..., k] = 1.0
    attn /= attn.sum(-1, keepdims=True)
    scores = fastv_scores_from_attention(jnp.asarray(attn), (0, n))
    cc = CompressionConfig(token_pruner="fastv", keep_ratio=len(hot) / n)
    _, idx, _ = compress_visual_tokens(cc, _embeds(b, n, 8), scores=scores)
    assert sorted(np.asarray(idx[0]).tolist()) == hot


def test_tome_merge_reduces_and_conserves():
    b, n, d = 1, 32, 8
    embeds = _embeds(b, n, d)
    merged, sizes, info = tome_merge(embeds, r=8)
    assert merged.shape == (b, n - 8, d)
    assert sizes.shape == (b, n - 8)
    # token "mass" conserved: sizes sum to the original count
    assert int(np.asarray(sizes).sum()) == b * n
    merged2 = tome_to_count(embeds, keep=12)
    assert merged2[0].shape[1] <= 16  # reaches <= keep via capped rounds


def test_prune_then_merge():
    embeds = _embeds(2, 40, 8)
    out, kidx, info = prune_then_merge(embeds, keep=10)
    assert out.shape[1] == 10
    assert kidx.shape == (2, 10)
    assert info["absorbed"] == 30


def test_video_compression_paths():
    b, t, p, d = 1, 12, 8, 16
    vid = jax.random.normal(jax.random.PRNGKey(0), (b, t, p, d), jnp.float32)
    merged, info = video.temporal_merge(vid, num_segments=4)
    assert merged.shape[1] == 4
    two_tok, info = video.llama_vid_compress(vid)
    assert two_tok.shape == (b, t * 2, d)
    ratio = video.dycoke_ratio(vid)
    assert ratio.shape == (b, t)          # per-frame complexity ratio
    assert float(ratio.min()) >= 0.1 and float(ratio.max()) <= 1.0
    comp, info = video.dynamic_compress(vid, token_budget=32)
    assert comp.shape == (b, 32, d)
    ff, info = video.framefusion(vid, keep=24)
    assert ff.shape == (b, 24, d)


def test_dycoke_discriminates_static_from_action():
    """Absolute (not per-video-normalized) complexity: a static video must
    compress hard EVERYWHERE (regression test for the max-normalization
    bug caught by examples/stream_video.py)."""
    rng = np.random.RandomState(0)
    bg = rng.randn(16, 64) * 0.3
    static = jnp.asarray((np.tile(bg, (8, 1, 1))
                          + rng.randn(8, 16, 64) * 0.02)[None], jnp.float32)
    action = jnp.asarray((np.tile(bg, (8, 1, 1))
                          + rng.randn(8, 16, 64) * 1.5)[None], jnp.float32)
    r_static = float(video.dycoke_ratio(static).mean())
    r_action = float(video.dycoke_ratio(action).mean())
    assert r_static < 0.2, r_static
    assert r_action > 0.7, r_action


def test_pyramiddrop_schedule():
    sched = pyramiddrop_schedule(1024, num_layers=32, stages=4,
                                 final_keep_ratio=0.125)
    assert len(sched) == 4
    layers = [l for l, _ in sched]
    keeps = [k for _, k in sched]
    assert layers == sorted(layers)
    assert keeps == sorted(keeps, reverse=True)
    assert keeps[-1] >= int(1024 * 0.125 * 0.9)
