"""``Tracer``: per-request lifecycle spans with dual clocks.

The tracing contract the whole serving stack instruments against:

  * **Spans** mark stages of one request's lifecycle -- ``request``
    (submit -> finish/abort), ``admission_wait``, ``prefill``,
    ``compress``, ``kv_migration`` -- opened with ``span_begin`` and
    closed with ``span_end`` or ``span_abort``. Spans are keyed
    ``(rid, name)`` fleet-wide: a span opened on the prefill replica and
    closed on the decode replica (KV migration) is ONE span, so a
    disaggregated fleet still yields one contiguous trace per request.
  * **Instants** (``instant``) mark points: first token, prefill chunks,
    KV export/import, admission deferral.
  * **Slices** (``slice``) are duration events on a replica's engine /
    slot lanes -- one engine step, one decode-group launch.
  * **Counters** (``counter``) are sampled time series: KV watermark,
    admission queue depth, prefix-tier hits, migration bytes in flight.

Every event carries BOTH clocks: ``vt`` -- the engine's deterministic
virtual clock (what the cost model charges) -- and ``wt`` -- wall time
from ``time.perf_counter()`` (what the hardware actually took). Events
are plain dicts; ``None`` fields are omitted.

Zero overhead when off: the stack holds ``NULL_TRACER`` (class attr
``enabled = False``) by default and every instrumentation site is
guarded by ``if tracer.enabled:`` -- the disabled hot path performs no
calls, no allocation, no formatting. Tests enforce this by patching the
``NullTracer`` methods to raise.

The tracer doubles as the live span accounting the runtime sanitizer
checks (``open_requests(replica) == live rids`` at every pump
iteration) and the static O-rules lint (every ``span_begin`` must reach
a ``span_end``/``span_abort``; see ``repro.analysis.rules_obs``).

This module is import-light (stdlib only) so ``repro.core`` can import
it without layering cycles; sinks (Perfetto export, JSONL streaming)
subscribe via ``add_sink``.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional, Set, Tuple


class NullTracer:
    """The no-op tracer: every emit is a pass, ``enabled`` is False so
    guarded call sites skip even the call. A single shared instance
    (``NULL_TRACER``) serves every untraced engine/server."""

    enabled = False

    def span_begin(self, name, rid, **kw):
        pass

    def span_end(self, name, rid, **kw):
        pass

    def span_abort(self, rid, **kw):
        pass

    def instant(self, name, rid=None, **kw):
        pass

    def slice(self, name, vt0, dur, **kw):
        pass

    def counter(self, name, value, **kw):
        pass

    def open_requests(self, replica=None) -> Set[int]:
        return set()


NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Collects lifecycle events (see module docstring).

    One instance is shared by every replica of a fleet (the Router wires
    it through ``LVLM.serve_cluster(obs=...)``), so span pairing and
    request ownership survive the prefill->decode migration boundary.
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.events: List[Dict] = []
        self._clock = clock
        self._sinks: List[Callable[[Dict], None]] = []
        # open spans keyed (rid, name) -> begin event (span pairing);
        # request ownership rid -> replica (the sanitizer invariant and
        # the migration-boundary track assignment both read it)
        self._open: Dict[Tuple[int, str], Dict] = {}
        self._owner: Dict[int, int] = {}
        # per-rid virtual-time high-water mark over span boundary events.
        # Replica virtual clocks are NOT synchronized: an import on a
        # quiet decode replica can carry a lower clock than the source's
        # export. The request's OWN timeline must still be monotone
        # (validate checks it), so boundary events clamp to the furthest
        # virtual time the request has reached on any replica.
        self._vt_hwm: Dict[int, float] = {}

    # ------------------------------------------------------------ sinks --
    def add_sink(self, sink: Callable[[Dict], None]) -> None:
        """Subscribe a sink: called once per event dict as it is
        emitted (the streaming-JSONL path)."""
        self._sinks.append(sink)

    def _emit(self, ev: Dict) -> Dict:
        self.events.append(ev)
        for sink in self._sinks:
            sink(ev)
        return ev

    def _event(self, kind: str, name: str, rid=None, replica=None,
               slot=None, vt=None, dur=None, value=None,
               attrs=None) -> Dict:
        ev: Dict = {"k": kind, "name": name, "wt": self._clock()}
        if rid is not None:
            ev["rid"] = rid
        if replica is not None:
            ev["rep"] = replica
        if slot is not None:
            ev["slot"] = slot
        if vt is not None:
            ev["vt"] = vt
        if dur is not None:
            ev["dur"] = dur
        if value is not None:
            ev["value"] = value
        if attrs:
            ev["attrs"] = attrs
        return ev

    def _clamp_vt(self, rid: int, vt: float) -> float:
        vt = max(vt, self._vt_hwm.get(rid, vt))
        self._vt_hwm[rid] = vt
        return vt

    # ------------------------------------------------------------ spans --
    def span_begin(self, name: str, rid: int, *, replica: int = 0,
                   slot: Optional[int] = None, vt: float = 0.0,
                   **attrs) -> None:
        vt = self._clamp_vt(rid, vt)
        key = (rid, name)
        if key in self._open:
            # double-begin (e.g. re-submit of a rid whose span leaked):
            # close the stale one as aborted so the trace stays paired
            self.span_abort(rid, replica=replica, vt=vt,
                            reason=f"re-begin of open span {name!r}")
        ev = self._emit(self._event("B", name, rid=rid, replica=replica,
                                    slot=slot, vt=vt,
                                    attrs=attrs or None))
        self._open[key] = ev
        if name == "request":
            self._owner[rid] = replica

    def span_end(self, name: str, rid: int, *, replica: int = 0,
                 slot: Optional[int] = None, vt: float = 0.0,
                 **attrs) -> None:
        vt = self._clamp_vt(rid, vt)
        self._open.pop((rid, name), None)
        if name == "request":
            self._owner.pop(rid, None)
        elif name == "kv_migration" and rid in self._owner:
            # the import side closes the migration span: ownership of the
            # request track moves to the importing replica
            self._owner[rid] = replica
        self._emit(self._event("E", name, rid=rid, replica=replica,
                               slot=slot, vt=vt, attrs=attrs or None))

    def span_abort(self, rid: int, *, replica: int = 0, vt: float = 0.0,
                   reason: str = "abort", **attrs) -> None:
        """Close EVERY open span of ``rid`` (innermost first) with an
        abort marker -- the single call the abort/failure paths make so
        no span is ever orphaned by a cancellation, disconnect timeout,
        or pump death."""
        vt = self._clamp_vt(rid, vt)
        keys = [k for k in reversed(list(self._open)) if k[0] == rid]
        for key in keys:
            del self._open[key]
            self._emit(self._event(
                "E", key[1], rid=rid, replica=replica, vt=vt,
                attrs=dict(attrs, aborted=True, reason=reason)))
        self._owner.pop(rid, None)

    # --------------------------------------------------- points & series --
    def instant(self, name: str, rid: Optional[int] = None, *,
                replica: int = 0, slot: Optional[int] = None,
                vt: float = 0.0, **attrs) -> None:
        self._emit(self._event("i", name, rid=rid, replica=replica,
                               slot=slot, vt=vt, attrs=attrs or None))

    def slice(self, name: str, vt0: float, dur: float, *,
              replica: int = 0, slot: Optional[int] = None,
              rid: Optional[int] = None, **attrs) -> None:
        """A duration event on a replica lane (engine lane when ``slot``
        is None, else that slot's lane): virtual start ``vt0``, virtual
        duration ``dur``."""
        self._emit(self._event("X", name, rid=rid, replica=replica,
                               slot=slot, vt=vt0, dur=dur,
                               attrs=attrs or None))

    def counter(self, name: str, value: float, *, replica: int = 0,
                vt: float = 0.0) -> None:
        self._emit(self._event("C", name, replica=replica, vt=vt,
                               value=value))

    # ------------------------------------------------------- accounting --
    def open_requests(self, replica: Optional[int] = None) -> Set[int]:
        """rids with an open ``request`` span (optionally only those
        owned by ``replica``) -- the sanitizer invariant's left side."""
        if replica is None:
            return set(self._owner)
        return {rid for rid, rep in self._owner.items() if rep == replica}

    def open_spans(self) -> List[Tuple[int, str]]:
        return list(self._open)

    # ----------------------------------------------------------- export --
    def write_jsonl(self, path: str) -> int:
        """Dump the in-memory event log as one JSON object per line
        (the ``scripts/trace_report.py`` input). Returns event count."""
        with open(path, "w", encoding="utf-8") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
        return len(self.events)


class JsonlSink:
    """Streaming sink: every event appends one JSON line as it happens
    (crash-durable, unlike the post-hoc ``write_jsonl``)."""

    def __init__(self, path: str):
        self._f = open(path, "w", encoding="utf-8")

    def __call__(self, ev: Dict) -> None:
        self._f.write(json.dumps(ev) + "\n")

    def close(self) -> None:
        self._f.close()
