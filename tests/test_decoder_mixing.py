"""Batched speculative decoding + per-request decoder mixing (PR tentpole).

Golden-equivalence contract: promoting speculative / early-exit from
batch-1 adapters to batched slot strategies must NOT change a single
emitted token --

  * batched speculative (many slots per jitted draft/verify call) is
    bit-identical to the standalone ``speculative_generate`` driver and to
    greedy decoding at temperature 0, per compression preset,
  * per-request decoder mixing in ONE engine reproduces each strategy's
    dedicated single-strategy run,
  * edge cases: prefix-cache + speculative interaction, eos emitted
    mid-accepted-block (the engine truncates the block at eos),
  * the prefix cache is true LRU (hits move-to-end; regression test).
"""
import numpy as np
import pytest

from repro.api import (EngineConfig, GenerationConfig, LVLM, Request)
from repro.core.decoding.speculative import speculative_generate
from repro.core.serving import Engine
from repro.core.token_compression.policy import compress_visual_tokens

MAX_NEW = 8
GAMMA = 3


@pytest.fixture(scope="module")
def lvlm():
    return LVLM.from_pretrained("phi4-mini-3.8b", smoke=True)


@pytest.fixture(scope="module")
def vlm():
    return LVLM.from_pretrained("qwen2-vl-2b", smoke=True)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.RandomState(7)
    return [list(rng.randint(1, 512, size=n)) for n in (12, 9, 15)]


# ------------------------------------------------- golden equivalence --


@pytest.mark.slow
def test_batched_spec_matches_standalone_and_greedy(lvlm, prompts):
    """>= 2 speculative slots share each jitted draft/verify round, and
    every request's tokens are bit-identical to BOTH the standalone driver
    and the greedy stream."""
    gen = GenerationConfig(decoder="speculative", temperature=0.0,
                           max_new_tokens=MAX_NEW, gamma=GAMMA)
    outs = lvlm.generate(prompts, gen)
    assert outs[0].stats["max_slots_per_round"] >= 2
    refs = lvlm.generate(prompts, GenerationConfig(
        decoder="greedy", max_new_tokens=MAX_NEW))
    for o, ref, p in zip(outs, refs, prompts):
        assert o.tokens == ref.tokens
        toks, _ = speculative_generate(
            lvlm.model, lvlm.model, lvlm.params, lvlm.params, p,
            max_new_tokens=MAX_NEW, gamma=GAMMA, temperature=0.0)
        assert o.tokens == toks


@pytest.mark.slow
@pytest.mark.parametrize("preset", ["none", "fastv-0.5", "divprune-0.5",
                                    "tome-0.5"])
def test_batched_spec_matches_greedy_per_preset(vlm, preset):
    """Per compression preset: batched speculative over a 2-slot VLM batch
    == greedy under the same preset (acceptance path, temperature 0)."""
    rng = np.random.RandomState(11)
    prompts = [list(rng.randint(1, vlm.cfg.vocab_size, size=n))
               for n in (10, 7)]
    ves = [rng.randn(vlm.cfg.num_visual_tokens, vlm.cfg.d_model
                     ).astype(np.float32) * 0.02 for _ in prompts]
    spec = vlm.generate(prompts, GenerationConfig(
        decoder="speculative", temperature=0.0, max_new_tokens=6,
        gamma=GAMMA, compression=preset), visual_embeds=ves)
    ref = vlm.generate(prompts, GenerationConfig(
        decoder="greedy", max_new_tokens=6, compression=preset),
        visual_embeds=ves)
    assert spec[0].stats["max_slots_per_round"] >= 2
    for s, r in zip(spec, ref):
        assert s.tokens == r.tokens, preset


@pytest.mark.slow
def test_batched_spec_matches_standalone_driver_compressed_vlm(vlm):
    """Engine-batched speculative under a pruning preset == the standalone
    driver fed the same (pre-compressed) visual tokens."""
    rng = np.random.RandomState(13)
    prompt = list(rng.randint(1, vlm.cfg.vocab_size, size=9))
    ve = rng.randn(vlm.cfg.num_visual_tokens, vlm.cfg.d_model
                   ).astype(np.float32) * 0.02
    gen = GenerationConfig(decoder="speculative", temperature=0.0,
                           max_new_tokens=6, gamma=GAMMA,
                           compression="fastv-0.5")
    out = vlm.generate(prompt, gen, visual_embeds=ve)
    cc = gen.resolved_compression()
    ve_c, _, _ = compress_visual_tokens(cc, np.asarray(ve)[None], query=None)
    toks, _ = speculative_generate(
        vlm.model, vlm.model, vlm.params, vlm.params, prompt,
        max_new_tokens=6, gamma=GAMMA, temperature=0.0,
        visual_embeds=np.asarray(ve_c[0]))
    assert out.tokens == toks


def test_kv_presets_reject_speculative(lvlm, prompts):
    """Live KV compaction and speculative verify are not composable; the
    incompatibility must surface as a clean error, not corruption."""
    with pytest.raises(ValueError):
        lvlm.generate(prompts[0], GenerationConfig(
            decoder="speculative", max_new_tokens=4,
            compression="streaming-kv"))


# ------------------------------------------------- per-request mixing --


@pytest.mark.slow
def test_mixed_strategies_single_engine(lvlm, prompts):
    """ONE engine serves greedy + sampling + speculative + early-exit
    requests concurrently; each request's tokens equal its dedicated
    single-strategy run; mixed stats are strategy-prefixed."""
    decs = ["greedy", "speculative", "early_exit", "speculative",
            "sampling", None]
    reqs = [Request(rid=i, tokens=list(prompts[i % 3]),
                    max_new_tokens=MAX_NEW, decoder=d)
            for i, d in enumerate(decs)]
    gen = GenerationConfig(decoder="greedy", temperature=0.0,
                           max_new_tokens=MAX_NEW, gamma=GAMMA)
    rep = lvlm.serve(reqs, EngineConfig(max_batch=6, cache_len=64,
                                        temperature=0.0), gen=gen)
    assert rep.stats["finished"] == len(reqs)
    # both speculative requests decoded in the SAME jitted rounds
    assert rep.stats["speculative/max_slots_per_round"] >= 2
    assert "early_exit/exit_rate" in rep.stats
    by_rid = {r.rid: r.generated for r in rep.requests}
    for i, d in enumerate(decs):
        ref = lvlm.generate(prompts[i % 3], gen.with_(
            decoder=d if d is not None else "greedy"))
        assert by_rid[i] == ref.tokens, (i, d)


def test_per_request_spec_capacity_margin(lvlm):
    """Speculative slots reserve gamma lookahead: a request that fits
    greedily but whose verify block would collide with the scratch
    position must be rejected at submit."""
    eng = Engine(lvlm.model, lvlm.params,
                 EngineConfig(max_batch=1, cache_len=32, decoder="greedy"))
    fits = Request(rid=0, tokens=list(range(1, 24)), max_new_tokens=8)
    eng.submit(fits)                                # 23 + 8 == cache_len-1
    tight = Request(rid=1, tokens=list(range(1, 24)), max_new_tokens=8,
                    decoder="speculative")
    with pytest.raises(ValueError):
        eng.submit(tight)                           # + gamma lookahead > cap
    assert tight.lookahead > 0                      # resolved before reject


def test_greedy_default_routes_and_keeps_sampling_temperature(lvlm, prompts):
    """Regression: a greedy DEFAULT must register under 'greedy' (not the
    class-level 'sampling' name) and must not zero the engine temperature
    -- per-request sampling overrides keep the caller's temperature."""
    reqs = [Request(rid=0, tokens=list(prompts[0]), max_new_tokens=4),
            Request(rid=1, tokens=list(prompts[0]), max_new_tokens=4,
                    decoder="sampling")]
    rep = lvlm.serve(reqs, EngineConfig(max_batch=2, cache_len=64),
                     gen=GenerationConfig(decoder="greedy", temperature=0.9,
                                          max_new_tokens=4))
    eng = rep.engine
    assert eng._default_name == "greedy"
    assert getattr(eng._decoders["greedy"], "greedy", False)
    assert not getattr(eng._decoders["sampling"], "greedy", True)
    assert eng._decoders["greedy"] is not eng._decoders["sampling"]
    assert eng.ec.temperature == 0.9          # raw temp reaches the engine
    # greedy request still argmax-exact despite the non-zero temperature
    ref = lvlm.generate(prompts[0], GenerationConfig(decoder="greedy",
                                                     max_new_tokens=4))
    assert {r.rid: r.generated for r in rep.requests}[0] == ref.tokens


# -------------------------------------------------------- edge cases --


def test_spec_with_prefix_cache_matches_and_hits(lvlm):
    """Prefix reuse composes with batched speculative: identical tokens
    with the cache on, and real block hits."""
    rng = np.random.RandomState(17)
    shared = list(rng.randint(1, 512, size=16))
    prompts = [shared + list(rng.randint(1, 512, size=4)) for _ in range(3)]
    gen = GenerationConfig(decoder="speculative", temperature=0.0,
                           max_new_tokens=6, gamma=GAMMA)
    base = lvlm.generate(prompts, gen)
    cached = lvlm.generate(prompts, gen, engine_cfg=EngineConfig(
        max_batch=3, cache_len=64, prefix_cache=True, prefix_block=8))
    for b, c in zip(base, cached):
        assert b.tokens == c.tokens
    assert cached[0].stats["prefix_hit_tokens"] > 0


def test_eos_mid_accepted_block_truncates(lvlm, prompts):
    """eos inside an accepted speculative block: the engine must cut the
    block at eos -- nothing is appended past DONE."""
    ref = lvlm.generate(prompts[0], GenerationConfig(
        decoder="greedy", max_new_tokens=MAX_NEW))
    # pick an eos whose FIRST occurrence lands strictly inside the first
    # accepted block (tokens 1..gamma emitted by round 1's verify)
    k = next(i for i in range(1, GAMMA)
             if ref.tokens.index(ref.tokens[i]) == i)
    eos = ref.tokens[k]
    out = lvlm.generate(prompts[0], GenerationConfig(
        decoder="speculative", temperature=0.0, max_new_tokens=MAX_NEW,
        gamma=GAMMA, eos_id=eos))
    assert out.tokens == ref.tokens[:k + 1]
    assert out.tokens[-1] == eos
    assert eos not in out.tokens[:-1]
    assert len(out.tokens) < MAX_NEW


# ----------------------------------------------------- prefix LRU fix --


def test_prefix_cache_true_lru_eviction(lvlm):
    """Regression: eviction must be LRU (hits move-to-end), not insertion
    order -- a recently-hit old entry survives, the stale one is evicted."""
    eng = Engine(lvlm.model, lvlm.params,
                 EngineConfig(max_batch=1, cache_len=64, prefix_cache=True,
                              prefix_block=4, prefix_cap=2))
    a = list(range(1, 9))                     # 8 tokens -> one 8-key
    b = list(range(101, 109))
    c = list(range(201, 209))
    eng._prefix_insert(a, 0, 8)
    eng._prefix_insert(b, 0, 8)
    hit_k, hit = eng._prefix_lookup(a + [99])   # LRU touch on A
    assert hit_k == 8 and hit is not None
    eng._prefix_insert(c, 0, 8)                 # cap 2: evicts B, not A
    assert eng._prefix_lookup(a + [99])[0] == 8
    assert eng._prefix_lookup(b + [99])[0] == 0
    assert eng._prefix_lookup(c + [99])[0] == 8
    assert len(eng._prefix) == 2
