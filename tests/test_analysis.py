"""repro.analysis: rule fixtures, waivers, baseline, mutation tests.

Each rule family gets small inline fixture snippets (linted via
``analyze_source`` at a synthetic repo-relative path, so the path-based
scoping is exercised too), plus MUTATION tests over the real tree: the
acceptance bar is that deleting any single release call in
``Engine._release_request`` (or adding a ``repro.core`` import to an
example) flips the analyzer from clean to failing.
"""
import ast
import textwrap
from types import SimpleNamespace

import pytest

from repro.analysis import (Baseline, Finding, analyze_source,
                            check_engine_conservation,
                            check_server_conservation, parse_waivers,
                            run_analysis, select_rules)
from repro.analysis.cfg import ENTRY, EXIT, build_cfg, function_defs
from repro.analysis.findings import fence_lines

ENGINE_PATH = "src/repro/core/serving/engine.py"


def lint(src, path, rules=None):
    return analyze_source(textwrap.dedent(src), path, rules=rules)


def rules_of(findings):
    return [f.rule for f in findings]


# ----------------------------------------------------------- registry --
def test_select_rules_all_families_present():
    rules = select_rules("all")
    fams = {r.family for r in rules.values()}
    assert {"L", "R", "A", "K"} <= fams


def test_select_rules_by_family_and_id():
    assert set(select_rules(["L"])) == {"L001", "L002", "L003"}
    assert set(select_rules(["R002", "A"])) == {
        "R002", "A001", "A002", "A003"}
    with pytest.raises(ValueError):
        select_rules(["Z999"])


# ----------------------------------------------------------- L-rules --
CORE_IMPORT = """
    from repro.core.serving import Engine
    """


def test_l001_core_import_outside_src_flagged():
    fs = lint(CORE_IMPORT, "examples/demo.py", rules=["L001"])
    assert rules_of(fs) == ["L001"]


def test_l001_core_import_inside_src_and_tests_ok():
    for path in ("src/repro/api/lvlm.py", "tests/test_x.py"):
        assert lint(CORE_IMPORT, path, rules=["L001"]) == []


def test_l001_waiver_on_line_above_suppresses():
    src = """
    # analysis: allow L001 (micro-bench)
    from repro.core.serving import Engine
    """
    assert lint(src, "benchmarks/bench_x.py", rules=["L001"]) == []


def test_waiver_spans_comment_block_to_next_code_line():
    src = """
    # analysis: allow L001 (micro-bench: long justification that
    # continues on a second comment line before the import)
    from repro.core.kv_cache.budget import uniform_budgets
    """
    assert lint(src, "benchmarks/bench_x.py", rules=["L001"]) == []


def test_l002_engineconfig_compression_mutation_flagged():
    src = """
    from repro.api import EngineConfig
    cfg = EngineConfig(max_batch=2)
    cfg.compression = "framefusion-0.25"
    """
    fs = lint(src, "examples/demo.py", rules=["L002"])
    assert rules_of(fs) == ["L002"]


def test_l002_per_request_compression_not_flagged():
    # Request.compression is the sanctioned per-request knob (PR 5)
    src = """
    for r in reqs:
        r.compression = presets[i % len(presets)]
    """
    assert lint(src, "examples/demo.py", rules=["L002"]) == []


def test_l003_engine_construction_outside_src_flagged():
    src = """
    eng = Engine(model, params, cfg)
    """
    fs = lint(src, "scripts/run.py", rules=["L003"])
    assert rules_of(fs) == ["L003"]
    assert lint(src, "src/repro/api/lvlm.py", rules=["L003"]) == []


# ----------------------------------------------------------- R-rules --
def test_r002_acquire_with_unconditional_handoff_ok():
    src = """
    class E:
        def bind(self, req):
            slot = self._free_slot()
            req._slot = slot
            self.slot_req[slot] = req
    """
    assert lint(src, ENGINE_PATH, rules=["R002"]) == []


def test_r002_early_return_leaks_slot():
    src = """
    class E:
        def bind(self, req):
            slot = self._free_slot()
            if req.cancelled:
                return
            self.slot_req[slot] = req
    """
    fs = lint(src, ENGINE_PATH, rules=["R002"])
    assert rules_of(fs) == ["R002"]
    assert "slot" in fs[0].message


def test_r002_release_on_every_branch_ok():
    src = """
    class E:
        def bind(self, req):
            slot = self._free_slot()
            if req.cancelled:
                self._release_request(req)
                return
            self.slot_req[slot] = req
    """
    assert lint(src, ENGINE_PATH, rules=["R002"]) == []


def test_r002_exception_path_through_handler():
    # handler releases; fall-through handoff: both paths covered
    src = """
    class E:
        def bind(self, req):
            slot = self._free_slot()
            try:
                self.prefill(req)
            except RuntimeError:
                self._release_request(req)
                raise
            self.slot_req[slot] = req
    """
    assert lint(src, ENGINE_PATH, rules=["R002"]) == []


def test_r003_module_level_pairing():
    acquire_only = """
    class S:
        def register(self, rid, stream):
            self._streams[rid] = stream
    """
    fs = lint(acquire_only, "src/repro/serving/server.py", rules=["R003"])
    assert rules_of(fs) == ["R003"]
    paired = acquire_only + """
        def drop(self, rid):
            self._streams.pop(rid, None)
    """
    assert lint(paired, "src/repro/serving/server.py",
                rules=["R003"]) == []


# ------------------------------------------- R mutation (real tree) --
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(path):
    with open(os.path.join(ROOT, path), encoding="utf-8") as f:
        return f.read()


def _neutralize(src, needle):
    """Replace the first line containing ``needle`` with ``pass`` at the
    same indentation (keeps the mutant syntactically valid)."""
    lines = src.splitlines(keepends=True)
    for i, line in enumerate(lines):
        if needle in line:
            indent = line[:len(line) - len(line.lstrip())]
            lines[i] = indent + "pass\n"
            return "".join(lines)
    raise AssertionError(f"needle not found: {needle!r}")


def test_real_engine_is_clean_under_r_rules():
    src = _read("src/repro/core/serving/engine.py")
    assert lint(src, ENGINE_PATH, rules=["R"]) == []


@pytest.mark.parametrize("needle,action", [
    ("self.slot_req[slot] = None", "slot-unbind"),
    ("release(slot)", "draft-row release"),
    ("r._prefix_pin = None", "prefix-pin clear"),
])
def test_deleting_release_call_trips_r001(needle, action):
    src = _read("src/repro/core/serving/engine.py")
    mutant = _neutralize(src, needle)
    fs = lint(mutant, ENGINE_PATH, rules=["R001"])
    assert any(f.rule == "R001" and action in f.message for f in fs), fs


def test_deleting_pin_decrement_trips_r001():
    # the decrement action matches either the re-store or the pop;
    # both must go for the finding to fire
    src = _read("src/repro/core/serving/engine.py")
    mutant = _neutralize(src, "self._prefix_pins[key] = n")
    mutant = _neutralize(mutant, "self._prefix_pins.pop(key, None)")
    fs = lint(mutant, ENGINE_PATH, rules=["R001"])
    assert any("decrement" in f.message for f in fs), fs


@pytest.mark.parametrize("needle,action", [
    ("ticket = self._exports.pop(rid)", "export-ticket pop"),
    ("self.running.remove(req)", "running-list removal"),
    ('self.slot_req[ticket["slot"]] = None', "source-slot unbind"),
])
def test_deleting_migration_source_release_trips_r001(needle, action):
    """The KV-migration source release (complete_export) is R001-pinned:
    deleting any one of its release actions -- ticket pop, running-list
    removal, source-slot unbind -- must flip the analyzer."""
    src = _read("src/repro/core/serving/engine.py")
    mutant = _neutralize(src, needle)
    fs = lint(mutant, ENGINE_PATH, rules=["R001"])
    assert any(f.rule == "R001" and "complete_export" in f.message
               and action in f.message for f in fs), fs


CONTROLLER_PATH = "src/repro/control/controller.py"


def test_real_controller_is_clean_under_r_rules():
    src = _read(CONTROLLER_PATH)
    assert lint(src, CONTROLLER_PATH, rules=["R"]) == []


@pytest.mark.parametrize("needle,action", [
    ("req.compression = orig_comp", "preferred-compression restore"),
    ("req.decoder = orig_dec", "preferred-decoder restore"),
])
def test_deleting_controller_revert_restore_trips_r001(needle, action):
    """The controller's revert() is R001-pinned like _release_request:
    deleting any single field restore leaves a request permanently
    degraded after pressure clears, and must flip the analyzer."""
    src = _read(CONTROLLER_PATH)
    mutant = _neutralize(src, needle)
    fs = lint(mutant, CONTROLLER_PATH, rules=["R001"])
    assert any(f.rule == "R001" and "revert" in f.message
               and action in f.message for f in fs), fs


def test_deleting_controller_nv_invalidation_trips_r001():
    # "req.nv_compressed = None" appears in _apply_fields AND revert;
    # only revert's copy is R001-pinned, so neutralize both (first call
    # hits _apply_fields, second hits revert)
    src = _read(CONTROLLER_PATH)
    mutant = _neutralize(src, "req.nv_compressed = None")
    mutant = _neutralize(mutant, "req.nv_compressed = None")
    fs = lint(mutant, CONTROLLER_PATH, rules=["R001"])
    assert any(f.rule == "R001" and "revert" in f.message
               and "stamped-count invalidation" in f.message
               for f in fs), fs


def test_deleting_controller_override_pops_trips_r001_and_r003():
    # the pop line is identical in commit() and revert(); removing both
    # must trip R001 for each release function AND R003 (the module no
    # longer releases the control_override resource at all)
    src = _read(CONTROLLER_PATH)
    mutant = _neutralize(src, "self._overrides.pop(req.rid, None)")
    mutant = _neutralize(mutant, "self._overrides.pop(req.rid, None)")
    fs = lint(mutant, CONTROLLER_PATH, rules=["R001", "R003"])
    r001_funcs = {f.message for f in fs if f.rule == "R001"}
    assert any("commit" in m for m in r001_funcs), fs
    assert any("revert" in m for m in r001_funcs), fs
    assert any(f.rule == "R003" and "control_override" in f.message
               for f in fs), fs


def test_deleting_slot_handoff_trips_r002():
    src = _read("src/repro/core/serving/engine.py")
    mutant = _neutralize(src, "self.slot_req[slot] = req")
    fs = lint(mutant, ENGINE_PATH, rules=["R002"])
    assert any(f.rule == "R002" and "`slot`" in f.message for f in fs), fs


def test_adding_core_import_to_example_trips_l001():
    src = _read("examples/stream_video.py")
    assert lint(src, "examples/stream_video.py", rules=["L001"]) == []
    mutant = src + "\nfrom repro.core.serving import Engine\n"
    fs = lint(mutant, "examples/stream_video.py", rules=["L001"])
    assert rules_of(fs) == ["L001"]


# ----------------------------------------------------------- A-rules --
def test_a001_blocking_sleep_in_async():
    src = """
    import time
    async def pump(self):
        time.sleep(0.1)
    """
    fs = lint(src, "src/repro/serving/server.py", rules=["A001"])
    assert rules_of(fs) == ["A001"]


def test_a001_from_import_alias_and_sync_ok():
    flagged = """
    from time import sleep as zzz
    async def pump(self):
        zzz(0.1)
    """
    assert rules_of(lint(flagged, "src/x.py", rules=["A001"])) == ["A001"]
    ok = """
    import time, asyncio
    def sync_fn():
        time.sleep(0.1)
    async def pump(self):
        await asyncio.sleep(0.1)
    """
    assert lint(ok, "src/x.py", rules=["A001"]) == []


A002_HAZARD = """
    class S:
        async def pump(self):
            if self._streams:
                await self.tick()
                self._streams.pop(1, None)
    """


def test_a002_await_spanning_mutation_flagged():
    fs = lint(A002_HAZARD, "src/repro/serving/server.py", rules=["A002"])
    assert rules_of(fs) == ["A002"]
    assert "_streams" in fs[0].message


def test_a002_fence_comment_suppresses():
    fenced = A002_HAZARD.replace(
        "self._streams.pop(1, None)",
        "# analysis: atomic-step (pop of own key is idempotent)\n"
        "            self._streams.pop(1, None)")
    assert lint(fenced, "src/repro/serving/server.py",
                rules=["A002"]) == []


def test_a002_mutation_before_await_ok():
    src = """
    class S:
        async def pump(self):
            self._streams.pop(1, None)
            await self.tick()
    """
    assert lint(src, "src/repro/serving/server.py", rules=["A002"]) == []


def test_a003_fire_and_forget_task():
    src = """
    import asyncio
    def kick(loop):
        asyncio.create_task(work())
    """
    fs = lint(src, "src/x.py", rules=["A003"])
    assert rules_of(fs) == ["A003"]
    kept = """
    import asyncio
    def kick(loop):
        t = asyncio.create_task(work())
        return t
    """
    assert lint(kept, "src/x.py", rules=["A003"]) == []


# ----------------------------------------------------------- K-rules --
KERNEL_OK = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...].astype(o_ref.dtype)

    def run(x):
        return pl.pallas_call(
            kern,
            grid=(4,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((32, 128), jnp.float32),
        )(x)
    """

KPATH = "src/repro/kernels/demo.py"


def test_kernel_fixture_clean():
    assert lint(KERNEL_OK, KPATH, rules=["K"]) == []


def test_k_rules_only_apply_to_kernel_paths():
    bad = KERNEL_OK.replace("lambda i:", "lambda i, j:")
    assert lint(bad, "src/repro/serving/server.py", rules=["K"]) == []
    assert rules_of(lint(bad, "src/attn_kernel.py", rules=["K001"])) \
        == ["K001", "K001"]


def test_k001_index_map_arity():
    bad = KERNEL_OK.replace(
        "in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))]",
        "in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, 0))]")
    fs = lint(bad, KPATH, rules=["K001"])
    assert rules_of(fs) == ["K001"]


def test_k001_defaulted_closure_params_ignored():
    ok = KERNEL_OK.replace(
        "in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))]",
        "in_specs=[pl.BlockSpec((8, 128), lambda i, g=2: (i, g))]")
    assert lint(ok, KPATH, rules=["K001"]) == []


def test_k002_kernel_signature_mismatch():
    bad = KERNEL_OK.replace("def kern(x_ref, o_ref):",
                            "def kern(x_ref, y_ref, o_ref):")
    bad = bad.replace("o_ref[...] = x_ref[...]",
                      "o_ref[...] = x_ref[...]")
    fs = lint(bad, KPATH, rules=["K002"])
    assert rules_of(fs) == ["K002"]


def test_k003_partial_tile_divisibility():
    bad = KERNEL_OK.replace("(32, 128)", "(33, 128)")
    fs = lint(bad, KPATH, rules=["K003"])
    assert rules_of(fs) == ["K003"]
    assert "33" in fs[0].message


def test_k004_store_without_astype():
    bad = KERNEL_OK.replace(
        "o_ref[...] = x_ref[...].astype(o_ref.dtype)",
        "o_ref[...] = x_ref[...] * 2.0")
    fs = lint(bad, KPATH, rules=["K004"])
    assert rules_of(fs) == ["K004"]


# ----------------------------------------------------------- O-rules --
SERVER_PATH = "src/repro/serving/server.py"

SPAN_OK = """
    class S:
        async def _admit(self, stream):
            if self.tracer.enabled:
                self.tracer.span_begin("admission_wait", 1)
            try:
                ok = await self.admission.admit(stream.request)
            except BaseException:
                if self.tracer.enabled:
                    self.tracer.span_abort(1)
                raise
            if not ok:
                if self.tracer.enabled:
                    self.tracer.span_end("admission_wait", 1)
                return
            if self.tracer.enabled:
                self.tracer.span_end("admission_wait", 1)
            self._wake.set()
    """


def test_o001_guarded_span_pairing_clean():
    """The `if tracer.enabled:` guard idiom pairs on every path,
    including the exception and retraction paths."""
    assert lint(SPAN_OK, SERVER_PATH, rules=["O001"]) == []


def test_o001_leaky_return_path_flagged():
    # drop the close on the not-admitted early return: that path now
    # exits with the span open
    bad = SPAN_OK.replace(
        """            if not ok:
                if self.tracer.enabled:
                    self.tracer.span_end("admission_wait", 1)
                return""",
        """            if not ok:
                return""")
    fs = lint(bad, SERVER_PATH, rules=["O001"])
    assert rules_of(fs) == ["O001", "O001"]     # guard header + call site
    assert "orphan span" in fs[0].message


def test_o001_module_pairing_for_engine_spans():
    src = """
    class Engine:
        def submit(self, req):
            self.tracer.span_begin("request", req.rid)

        def step(self):
            self.tracer.span_end("request", 1)
    """
    assert lint(src, ENGINE_PATH, rules=["O001"]) == []
    bad = src.replace('self.tracer.span_end("request", 1)', "pass")
    fs = lint(bad, ENGINE_PATH, rules=["O001"])
    assert rules_of(fs) == ["O001"]
    assert "no span_end/span_abort site" in fs[0].message


def test_renaming_server_span_closes_trips_o001():
    """Real-tree mutation: neutering every close in the server leaves
    _admit/import_stream opening spans no path ever closes."""
    src = _read("src/repro/serving/server.py")
    mutant = (src.replace("span_end(", "span_noop(")
              .replace("span_abort(", "span_noop("))
    fs = lint(mutant, SERVER_PATH, rules=["O001"])
    assert fs and all(f.rule == "O001" for f in fs), fs


def test_renaming_engine_span_closes_trips_o001():
    src = _read("src/repro/core/serving/engine.py")
    mutant = (src.replace("span_end(", "span_noop(")
              .replace("span_abort(", "span_noop("))
    fs = lint(mutant, ENGINE_PATH, rules=["O001"])
    assert fs and all(f.rule == "O001" for f in fs), fs


@pytest.mark.parametrize("call,action", [
    ("span_abort(", "trace span close on abort"),
    ("span_end(", "request-span close at retire"),
])
def test_deleting_engine_span_close_trips_r001(call, action):
    """The R-table pins the specific closes: Engine.abort must
    span_abort, Engine.step must span_end at retire."""
    src = _read("src/repro/core/serving/engine.py")
    mutant = src.replace(call, "span_noop(")
    fs = lint(mutant, ENGINE_PATH, rules=["R001"])
    assert any(f.rule == "R001" and action in f.message for f in fs), fs


O002_KERNEL = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref):
        tracer.instant("inner", 0)
        o_ref[...] = x_ref[...].astype(o_ref.dtype)

    def run(x, tracer):
        tracer.span_begin("run", 0)
        out = pl.pallas_call(
            kern,
            grid=(4,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((32, 128), jnp.float32),
        )(x)
        tracer.span_end("run", 0)
        return out
    """


def test_o002_kernel_emission_flagged():
    fs = lint(O002_KERNEL, KPATH, rules=["O002"])
    assert rules_of(fs) == ["O002"]
    assert "trace time" in fs[0].message


PROFILE_OK = """
    class Engine:
        def _decode_iteration(self):
            if self.profiler.enabled:
                self.profiler.site_begin("decode:greedy")
            cost = self._launch()
            if cost is None:
                if self.profiler.enabled:
                    self.profiler.site_end("decode:greedy")
                return 0.0
            if self.profiler.enabled:
                self.profiler.site_end("decode:greedy", vt=cost)
            return cost
    """


def test_o003_guarded_site_pairing_clean():
    """Profiler sites close on every CFG path (per-function pairing:
    unlike trace spans, a site never crosses function boundaries)."""
    assert lint(PROFILE_OK, ENGINE_PATH, rules=["O003"]) == []


def test_o003_leaky_site_flagged():
    bad = PROFILE_OK.replace(
        """            if cost is None:
                if self.profiler.enabled:
                    self.profiler.site_end("decode:greedy")
                return 0.0""",
        """            if cost is None:
                return 0.0""")
    fs = lint(bad, ENGINE_PATH, rules=["O003"])
    assert rules_of(fs) == ["O003", "O003"]     # guard header + call site
    assert "self/total attribution" in fs[0].message


def test_renaming_engine_site_closes_trips_o003():
    """Real-tree mutation: neutering every site_end in the engine leaves
    the prefill/decode/compress sites open on every path."""
    src = _read("src/repro/core/serving/engine.py")
    mutant = src.replace("site_end(", "site_noop(")
    fs = lint(mutant, ENGINE_PATH, rules=["O003"])
    assert fs and all(f.rule == "O003" for f in fs), fs


def test_o002_host_wrapper_emission_clean():
    ok = O002_KERNEL.replace('    tracer.instant("inner", 0)\n', '')
    assert lint(ok, KPATH, rules=["O002"]) == []


def test_o002_generic_names_need_a_tracer_object():
    # jax.lax.slice inside a kernel shares a name with Tracer.slice;
    # only calls on a tracer object count
    ok = O002_KERNEL.replace(
        'tracer.instant("inner", 0)',
        'y = jax.lax.slice(x_ref[...], (0, 0), (4, 4))')
    assert lint(ok, KPATH, rules=["O002"]) == []


# ------------------------------------------------- waivers / baseline --
def test_syntax_error_reports_e000():
    fs = analyze_source("def broken(:\n", "src/x.py")
    assert rules_of(fs) == ["E000"]


def test_parse_waivers_multiple_rules():
    waived = parse_waivers(
        "x = 1  # analysis: allow L001, A002 (legacy)\n")
    assert waived[1] == {"L001", "A002"}


def test_fence_lines_cover_next_code_line():
    src = ("# analysis: atomic-step (safe:\n"
           "# own entry only)\n"
           "self._waiters.remove(e)\n")
    assert fence_lines(src) >= {1, 2, 3}


def test_baseline_roundtrip_and_line_slack(tmp_path):
    f = Finding(path="a.py", line=10, rule="L001", severity="error",
                message="m")
    bl = Baseline([f])
    p = tmp_path / "baseline.json"
    bl.save(str(p))
    loaded = Baseline.load(str(p))
    near = Finding(path="a.py", line=15, rule="L001", severity="error",
                   message="moved")
    far = Finding(path="a.py", line=40, rule="L001", severity="error",
                  message="new")
    assert loaded.is_baselined(near)
    assert loaded.filter([near, far]) == [far]


# ------------------------------------------------------------- CFG --
def test_cfg_loop_break_and_finally_paths():
    src = textwrap.dedent("""
    def f(xs):
        acc = 0
        for x in xs:
            if x < 0:
                break
            acc += x
        try:
            return acc
        finally:
            log(acc)
    """)
    fn = next(iter(function_defs(ast.parse(src))))
    g = build_cfg(fn)
    stmts = {s.lineno: s for s in g.succ if not isinstance(s, str)}
    # the finally body (`log(acc)`, line 11) is on every path to EXIT:
    # avoiding it disconnects the function from its exit
    assert not g.path_avoiding(ENTRY, EXIT, {stmts[11]})
    assert g.path_avoiding(ENTRY, EXIT, set())


# ------------------------------------------------ whole-tree contract --
def test_repo_tree_is_clean():
    """The committed tree has zero non-baselined findings (what CI runs
    as `python -m repro.analysis --fail-on-regression`)."""
    report = run_analysis()
    assert report.ok, report.render()
    assert report.files_checked > 50


# ----------------------------------------------------------- sanitizer --
def _fake_engine(n_slots=2, cache_len=32):
    from repro.core.serving.request import State
    req = SimpleNamespace(rid=7, state=State.DECODE, _slot=0,
                          _prefix_pin=None)
    eng = SimpleNamespace(
        running=[req], waiting=[], slot_req=[req] + [None] * (n_slots - 1),
        slot_pos=[4] + [0] * (n_slots - 1),
        ec=SimpleNamespace(cache_len=cache_len),
        _decoders={}, _prefix_pins={}, _prefix={},
        kv_committed_tokens=lambda include_waiting=True: 4,
        kv_request_tokens=lambda r: 4)
    return eng, req


def test_sanitizer_clean_fake_engine():
    eng, _ = _fake_engine()
    assert check_engine_conservation(eng) == []


def test_sanitizer_detects_kv_drift():
    eng, _ = _fake_engine()
    eng.kv_committed_tokens = lambda include_waiting=True: 9
    assert any("kv_committed" in p
               for p in check_engine_conservation(eng))


def test_sanitizer_detects_slot_bound_to_done_request():
    from repro.core.serving.request import State
    eng, req = _fake_engine()
    req.state = State.DONE
    eng.running = []
    eng.kv_committed_tokens = lambda include_waiting=True: 0
    assert any("slot leak" in p for p in check_engine_conservation(eng))


def test_sanitizer_detects_draft_row_leak():
    eng, _ = _fake_engine()
    eng._decoders = {"speculative": SimpleNamespace(
        bound_slots=lambda: {0, 1})}      # slot 1 is free in slot_req
    assert any("draft-row leak" in p
               for p in check_engine_conservation(eng))


def test_sanitizer_detects_pin_leak_both_directions():
    eng, req = _fake_engine()
    key = ("none", (1, 2, 3))
    # counted pin with no live holder
    eng._prefix_pins = {key: 1}
    eng._prefix = {key: ()}
    assert any("pin leak" in p for p in check_engine_conservation(eng))
    # live holder the engine no longer counts
    eng._prefix_pins = {}
    req._prefix_pin = key
    assert any("no longer counts" in p
               for p in check_engine_conservation(eng))


def test_sanitizer_server_orphan_stream():
    eng, _ = _fake_engine()
    server = SimpleNamespace(engine=eng, _streams={})
    assert any("no registered stream" in p
               for p in check_server_conservation(server))
    server._streams = {7: SimpleNamespace(aborted=False)}
    assert check_server_conservation(server) == []
