"""Nemotron-4 340B (dense, GQA, squared-ReLU MLP). [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    source="arXiv:2402.16819",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,               # d_model / num_heads
    d_ff=73728,
    vocab_size=256000,
    activation="relu2",
    norm="layernorm",
    rope_theta=1.0e4,
    sliding_window=16384,       # long_500k variant
)

SMOKE_CONFIG = CONFIG.with_(
    name="nemotron-smoke",
    num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512, sliding_window=64, dtype="float32",
)
