"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
experiments/dryrun_*.json.

    PYTHONPATH=src python scripts/make_experiments_tables.py
"""
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def fmt_bytes(b):
    if b is None:
        return "n/a"
    return f"{b / 1e9:.2f}"


def roofline_table(results):
    lines = [
        "| arch | shape | step | peak GB/dev | compute s | memory s | "
        "collective s | dominant | useful FLOPs frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        r = results[key]
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| SKIPPED ({r['reason'][:40]}…) | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | FAILED: "
                         f"{r['error'][:60]} | | | | | |")
            continue
        rf = r["roofline"]
        peak = r["memory"].get("peak_bytes")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | "
            f"{fmt_bytes(peak)} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"**{rf['dominant']}** | {rf['useful_frac']:.3f} |")
    return "\n".join(lines)


def dryrun_summary(results, chips):
    ok = sum(1 for r in results.values() if r["status"] == "ok")
    sk = sum(1 for r in results.values() if r["status"] == "skipped")
    fail = sum(1 for r in results.values() if r["status"] == "fail")
    coll = {}
    for r in results.values():
        if r["status"] != "ok":
            continue
        for op, n in (r["roofline"]["collective_counts"] or {}).items():
            coll[op] = coll.get(op, 0) + n
    return (f"{ok} ok / {sk} skipped / {fail} failed on {chips} chips; "
            f"collective ops across grid: "
            + ", ".join(f"{k}={v}" for k, v in sorted(coll.items())))


def main():
    sections = []
    for tag, chips in (("singlepod", 256), ("multipod", 512)):
        path = os.path.join(ROOT, f"dryrun_{tag}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            results = json.load(f)
        block = (f"### {tag} ({chips} chips)\n\n"
                 + dryrun_summary(results, chips) + "\n\n"
                 + roofline_table(results) + "\n")
        print(block)
        sections.append(block)

    # splice the single-pod table into EXPERIMENTS.md at the marker
    exp = os.path.join(ROOT, "..", "EXPERIMENTS.md")
    if sections and os.path.exists(exp):
        with open(exp) as f:
            text = f.read()
        marker = "<!-- ROOFLINE_TABLE -->"
        if marker in text:
            pre = text.split(marker)[0]
            post = text.split(marker)[-1]
            # drop any previously spliced table (up to the next heading)
            idx = post.find("\nObservations:")
            post = post[idx:] if idx >= 0 else post
            with open(exp, "w") as f:
                f.write(pre + marker + "\n\n" + sections[0] + post)
            print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
