"""Routing policies: which replica serves a new request.

Every policy sees only healthy (non-draining, non-dead) candidates and
picks exactly one. Policies are tiny stateful objects so the Router can
hold per-policy state (the round-robin cursor) without globals:

  round_robin      cycle through replicas -- the baseline spreader.
  least_kv         the replica with the lowest committed-KV fraction
                   (``Engine.kv_committed_tokens / kv_capacity_tokens``),
                   i.e. join-the-shortest-queue on the resource that
                   actually gates admission.
  prefix_affinity  the replica whose prefix cache already holds the
                   longest block-aligned prefix of the prompt (so the
                   prefill reuses it); a COLD prefix consistent-hashes
                   its first block, so one prefix family converges on one
                   replica and affinity builds instead of spraying.

Custom policies: any object with ``name`` and
``pick(request, candidates) -> Replica`` works; register it in
``ROUTING_POLICIES`` or pass the instance to ``Router(routing=...)``.
"""
from __future__ import annotations

import zlib
from typing import List, Sequence


class RoundRobinPolicy:
    name = "round_robin"

    def __init__(self):
        self._cursor = 0

    def pick(self, request, candidates: List):
        rep = candidates[self._cursor % len(candidates)]
        self._cursor += 1
        return rep


class LeastKVPolicy:
    """Join-the-shortest-queue on KV reservations (the PR 3
    ``kv_request_tokens`` accounting) of every request assigned to the
    replica -- admitted, deferred, or dispatched-but-not-yet-iterated --
    so a replica stops attracting work the moment it is loaded up, not
    once its engine commits."""
    name = "least_kv"

    def pick(self, request, candidates: List):
        return min(candidates,
                   key=lambda rep: (rep.kv_load(), rep.queue_depth(),
                                    rep.index))


def _hash_block(tokens: Sequence[int], block: int) -> int:
    """Deterministic hash of the prompt's first prefix block (crc32 over
    the token bytes -- stable across processes, unlike ``hash``)."""
    head = ",".join(str(int(t)) for t in tokens[:block])
    return zlib.crc32(head.encode())


class PrefixAffinityPolicy:
    """Route to the replica that already caches the longest prefix of the
    prompt; consistent-hash cold prefixes so repeats land together."""
    name = "prefix_affinity"

    def pick(self, request, candidates: List):
        best, best_len = None, 0
        for rep in candidates:
            # prefix entries are keyed by compression variant too: a
            # replica only counts as warm if it cached the prefix under
            # THIS request's strategy
            n = rep.cached_prefix_len(request.tokens,
                                      getattr(request, "compression", None))
            if n > best_len:
                best, best_len = rep, n
        if best is not None:
            return best
        block = max((rep.prefix_block() for rep in candidates), default=16)
        h = _hash_block(request.tokens, block)
        return candidates[h % len(candidates)]


def replica_keep_fraction(rep, probe: int = 256) -> float:
    """Fraction of visual tokens the replica's DEFAULT compression
    strategy keeps, probed exactly via
    ``CompressionStrategy.compressed_token_count`` (the same accounting
    admission uses -- no heuristics)."""
    comp = rep.server.engine.compressor
    if comp is None or probe <= 0:
        return 1.0
    return comp.compressed_token_count(probe) / float(probe)


def prefer_aggressive(candidates: Sequence, max_keep: float = 0.5) -> List:
    """Candidates whose default strategy keeps at most ``max_keep`` of
    visual tokens -- the SLO-adaptive controller's routing bias for
    video-heavy traffic under pressure. Empty when none qualify (the
    caller falls back to the full list)."""
    return [rep for rep in candidates
            if replica_keep_fraction(rep) <= max_keep]


ROUTING_POLICIES = {
    "round_robin": RoundRobinPolicy,
    "least_kv": LeastKVPolicy,
    "prefix_affinity": PrefixAffinityPolicy,
}


def make_policy(routing):
    """Name -> fresh policy instance; a policy object passes through."""
    if isinstance(routing, str):
        if routing not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {routing!r}; known: "
                             f"{sorted(ROUTING_POLICIES)}")
        return ROUTING_POLICIES[routing]()
    if not hasattr(routing, "pick"):
        raise TypeError("routing must be a policy name or an object with "
                        "a pick(request, candidates) method")
    return routing
