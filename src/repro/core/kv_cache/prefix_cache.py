"""Prefix-aware caching (survey dim 2b-ii): RadixAttention-style radix tree.

A radix tree over token-id sequences maps shared prefixes (system prompts,
repeated images -- visual tokens hash to ids too) to physical KV blocks.
LRU eviction respects reference counts so actively-used entries survive
continuous batching (SGLang's design); ``match_prefix`` returns the longest
cached prefix and pins its blocks.

Entries are NAMESPACED by compression ``variant`` (one radix tree per
variant): KV blocks written under one visual-token-compression strategy
are not interchangeable with another's, so a ``fastv-0.5`` prefill must
never serve a ``none`` lookup -- same rule the serving engine's host
prefix map applies.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.kv_cache.paged import BlockAllocator

_clock = itertools.count()


@dataclasses.dataclass
class RadixNode:
    key: Tuple[int, ...]                       # edge label (token ids)
    block_ids: List[int]                       # blocks covering this edge
    children: Dict[int, "RadixNode"]
    parent: Optional["RadixNode"]
    ref: int = 0                               # active readers
    last_access: int = 0

    def tokens_len(self) -> int:
        return len(self.key)


class RadixPrefixCache:
    #: variant key used when callers do not namespace (back-compat)
    DEFAULT_VARIANT = "none"

    def __init__(self, allocator: BlockAllocator,
                 block_size: Optional[int] = None):
        self.alloc = allocator
        self.block_size = block_size or allocator.block_size
        # one radix tree per compression variant; ``root`` stays the
        # default-variant tree for existing callers
        self.roots: Dict[str, RadixNode] = {}
        self.root = self._variant_root(self.DEFAULT_VARIANT)
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.total_tokens = 0

    def _variant_root(self, variant: Optional[str]) -> RadixNode:
        v = variant if variant is not None else self.DEFAULT_VARIANT
        node = self.roots.get(v)
        if node is None:
            node = RadixNode((), [], {}, None)
            self.roots[v] = node
        return node

    def _split_edge(self, parent: RadixNode, child: RadixNode,
                    split: int) -> RadixNode:
        """Split ``child``'s edge after ``split`` tokens (block multiple)."""
        bs = self.block_size
        assert split % bs == 0 and 0 < split < len(child.key)
        nsb = split // bs
        upper = RadixNode(child.key[:split], child.block_ids[:nsb], {},
                          parent, last_access=next(_clock))
        old_first = child.key[0]
        child.key = child.key[split:]
        child.block_ids = child.block_ids[nsb:]
        child.parent = upper
        upper.children[child.key[0]] = child
        parent.children[old_first] = upper
        return upper

    # ------------------------------------------------------------- match --
    def match_prefix(self, tokens: Sequence[int],
                     variant: Optional[str] = None
                     ) -> Tuple[List[int], int, List[RadixNode]]:
        """Longest cached prefix of ``tokens`` under compression
        ``variant`` (None -> the default namespace).

        Returns (block_ids, matched_token_count, pinned_nodes). Caller must
        ``unpin`` the nodes when the request finishes. Only whole-block
        multiples are reusable (partial blocks would need copy-on-write).
        """
        node = self._variant_root(variant)
        matched: List[int] = []
        pinned: List[RadixNode] = []
        i = 0
        tokens = tuple(tokens)
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                break
            common = 0
            while (common < len(child.key) and i + common < len(tokens)
                   and child.key[common] == tokens[i + common]):
                common += 1
            if common < len(child.key):
                # partial edge: split at a block boundary and reuse the top
                split = (common // self.block_size) * self.block_size
                if split == 0:
                    break
                upper = self._split_edge(node, child, split)
                matched.extend(upper.block_ids)
                upper.ref += 1
                upper.last_access = next(_clock)
                pinned.append(upper)
                i += split
                break
            matched.extend(child.block_ids)
            child.ref += 1
            child.last_access = next(_clock)
            pinned.append(child)
            i += common
            node = child
        self.total_tokens += len(tokens)
        if i:
            self.hits += 1
            self.hit_tokens += i
        else:
            self.misses += 1
        return matched, i, pinned

    def unpin(self, pinned: List[RadixNode]) -> None:
        for n in pinned:
            n.ref -= 1
            assert n.ref >= 0

    # ------------------------------------------------------------ insert --
    def insert(self, tokens: Sequence[int], block_ids: Sequence[int],
               block_size: int, variant: Optional[str] = None) -> None:
        """Register a computed prefix under compression ``variant``;
        takes shared ownership of blocks."""
        tokens = tuple(tokens)
        usable = (len(tokens) // block_size) * block_size
        tokens = tokens[:usable]
        block_ids = list(block_ids[:usable // block_size])
        if not tokens:
            return
        node = self._variant_root(variant)
        i = 0
        bi = 0
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                key = tokens[i:]
                blocks = block_ids[bi:]
                for blk in blocks:
                    self.alloc.share(blk)
                new = RadixNode(key, blocks, {}, node,
                                last_access=next(_clock))
                node.children[tokens[i]] = new
                return
            common = 0
            max_c = min(len(child.key), len(tokens) - i)
            while common < max_c and child.key[common] == tokens[i + common]:
                common += 1
            if common == len(child.key):
                node = child
                i += common
                bi += len(child.key) // block_size
                continue
            # split the edge at a block boundary
            split = (common // block_size) * block_size
            if split == 0:
                return                      # divergence inside first block
            node = self._split_edge(node, child, split)
            i += split
            bi += split // block_size

    # ------------------------------------------------------------- evict --
    def evict(self, num_blocks: int) -> int:
        """LRU-evict leaf nodes (ref==0, any variant) until ``num_blocks``
        are released."""
        released = 0
        while released < num_blocks:
            leaves = [n for n in self._iter_nodes()
                      if not n.children and n.ref == 0
                      and n.parent is not None]
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_access)
            for blk in victim.block_ids:
                self.alloc.free(blk)
                released += 1
            first = victim.key[0]
            del victim.parent.children[first]
        return released

    def _iter_nodes(self):
        stack = list(self.roots.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def stats(self) -> Dict:
        nodes = list(self._iter_nodes())
        return {
            "nodes": len(nodes) - len(self.roots),
            "cached_blocks": sum(len(n.block_ids) for n in nodes),
            "hit_rate": self.hits / max(1, self.hits + self.misses),
            "token_hit_rate": self.hit_tokens / max(1, self.total_tokens),
        }
