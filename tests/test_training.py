"""Training substrate: optimizer math, data determinism, loop, checkpoint."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import build
from repro.training import (OptimizerConfig, SyntheticDataConfig,
                            adamw_init, adamw_update, cosine_lr,
                            global_norm, load_checkpoint, save_checkpoint,
                            train_loop)
from repro.training.data import make_batch


def test_cosine_lr_schedule():
    oc = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=110,
                         min_lr_frac=0.1)
    assert float(cosine_lr(oc, 0)) == 0.0
    np.testing.assert_allclose(float(cosine_lr(oc, 10)), 1e-3, rtol=1e-5)
    assert float(cosine_lr(oc, 5)) == pytest.approx(5e-4)
    np.testing.assert_allclose(float(cosine_lr(oc, 110)), 1e-4, rtol=1e-5)
    # monotone decay after warmup
    vals = [float(cosine_lr(oc, s)) for s in range(10, 111, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_adamw_first_step_is_signed_lr():
    """With bias correction, |update| == lr / (1 + eps') on step 1."""
    oc = OptimizerConfig(lr=0.1, weight_decay=0.0, clip_norm=0.0,
                         warmup_steps=0, total_steps=1, min_lr_frac=1.0)
    params = {"w": jnp.asarray([[1.0, -2.0]])}
    grads = {"w": jnp.asarray([[0.5, -0.25]])}
    opt = adamw_init(params)
    new_params, opt, m = adamw_update(oc, grads, opt, params)
    delta = np.asarray(params["w"] - new_params["w"])
    np.testing.assert_allclose(np.abs(delta), 0.1, rtol=1e-4)
    np.testing.assert_allclose(np.sign(delta),
                               np.sign(np.asarray(grads["w"])))


def test_grad_clipping():
    oc = OptimizerConfig(lr=0.1, clip_norm=1.0, weight_decay=0.0,
                         warmup_steps=0, total_steps=1, min_lr_frac=1.0)
    params = {"w": jnp.zeros((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    opt = adamw_init(params)
    _, opt2, m = adamw_update(oc, grads, opt, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    # clipped first moment: beta-weighted clipped grad
    expected_mu = 0.1 * 100.0 * (1.0 / 200.0)
    np.testing.assert_allclose(np.asarray(opt2["mu"]["w"]), expected_mu,
                               rtol=1e-4)


def test_weight_decay_only_on_matrices():
    oc = OptimizerConfig(lr=0.1, weight_decay=0.5, clip_norm=0.0,
                         warmup_steps=0, total_steps=1, min_lr_frac=1.0)
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    grads = {"mat": jnp.zeros((2, 2)), "vec": jnp.zeros((2,))}
    opt = adamw_init(params)
    new_params, _, _ = adamw_update(oc, grads, opt, params)
    assert float(new_params["mat"][0, 0]) < 1.0    # decayed
    assert float(new_params["vec"][0]) == 1.0      # not decayed


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 500))
def test_data_pipeline_deterministic_and_seekable(step):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    dc = SyntheticDataConfig(batch=2, seq_len=16, seed=7)
    a = make_batch(cfg, dc, step)
    b = make_batch(cfg, dc, step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0
    assert a["tokens"].max() < cfg.vocab_size
    assert a["loss_mask"][:, -1].sum() == 0


def test_vlm_batch_has_visual_embeds():
    cfg = get_config("qwen2-vl-2b", smoke=True)
    b = make_batch(cfg, SyntheticDataConfig(batch=2, seq_len=8), 0)
    assert b["visual_embeds"].shape == (2, cfg.num_visual_tokens,
                                        cfg.d_model)


def test_loss_decreases_and_resume_matches():
    cfg = get_config("phi4-mini-3.8b", smoke=True).with_(vocab_size=128)
    model = build(cfg)
    dc = SyntheticDataConfig(batch=4, seq_len=24)
    oc = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=14)
    with tempfile.TemporaryDirectory() as d:
        out = train_loop(model, oc=oc, dc=dc, num_steps=14, ckpt_dir=d,
                         ckpt_every=7, log_every=0)
        assert out["final_loss"] < out["first_loss"]
        # resume from step 7 and retrace the identical loss curve
        tree, step = load_checkpoint(d)
        assert step == 14
        # drop to the mid checkpoint: re-save it then resume
        out2 = train_loop(model, oc=oc, dc=dc, num_steps=14, ckpt_dir=d,
                          resume=True, log_every=0)
        assert out2["steps"] == 0 or out2["final_loss"] == pytest.approx(
            out["final_loss"], rel=1e-3)


def test_checkpoint_shard_roundtrip():
    tree = {"a": {"b": jnp.arange(100, dtype=jnp.float32).reshape(10, 10),
                  "c": jnp.ones((3,), jnp.int32)},
            "d": jnp.asarray(2.5)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=5, shard_bytes=128)
        got, step = load_checkpoint(d)
        assert step == 5
        shards = [f for f in os.listdir(d) if f.endswith(".npz")]
        assert len(shards) > 1, "shard_bytes cap must split files"
        for k1, k2 in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)
