"""Paged KV cache (survey dim 2b-i): vLLM's PagedAttention adapted to TPU.

Host-side ``BlockAllocator`` manages a fixed pool of physical blocks with
reference counting (copy-on-write sharing for prefix reuse). Device-side
``PagedKVPool`` holds the preallocated physical pages; sequences address
them through per-request block tables, exactly like vLLM's logical->physical
mapping. The TPU adaptation (DESIGN.md §2): attention gathers whole PAGES
(block_size a multiple of the lane width), not scattered tokens, so the
lookup is DMA-friendly -- kernels/paged_attention.py makes the page the
Pallas grid dimension.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


class OutOfBlocksError(RuntimeError):
    pass


class BlockAllocator:
    """Reference-counted physical block pool (host-side control plane)."""

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.free_list: List[int] = list(range(num_blocks))
        self.ref: np.ndarray = np.zeros(num_blocks, np.int32)

    # -- stats ------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free_list)

    def utilization(self) -> float:
        return 1.0 - self.num_free / self.num_blocks

    # -- ops ---------------------------------------------------------------
    def alloc(self) -> int:
        if not self.free_list:
            raise OutOfBlocksError("paged KV pool exhausted")
        blk = self.free_list.pop()
        self.ref[blk] = 1
        return blk

    def share(self, blk: int) -> int:
        assert self.ref[blk] > 0
        self.ref[blk] += 1
        return blk

    def free(self, blk: int) -> None:
        assert self.ref[blk] > 0, f"double free of block {blk}"
        self.ref[blk] -= 1
        if self.ref[blk] == 0:
            self.free_list.append(blk)

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return (n_tokens + self.block_size - 1) // self.block_size


@dataclasses.dataclass
class SeqBlocks:
    """Per-sequence logical->physical mapping."""
    block_ids: List[int]
    length: int = 0                    # tokens written

    def capacity(self, block_size: int) -> int:
        return len(self.block_ids) * block_size


class PagedKVPool:
    """Device-side paged pool for an L-layer attention model.

    Layout: k/v [L, num_blocks, block_size, H_kv, D]. Page-major so one
    (layer, block) pair is a contiguous DMA.
    """

    def __init__(self, num_layers: int, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int, dtype=jnp.float32):
        shape = (num_layers, num_blocks, block_size, num_kv_heads, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        self.shape = shape
        self.block_size = block_size

    def write_prefill(self, seq: SeqBlocks, layer_k, layer_v):
        """layer_k/v [L, S, H, D]: scatter a prompt's KV into its blocks."""
        l, s, h, d = layer_k.shape
        bs = self.block_size
        nb = (s + bs - 1) // bs
        assert nb <= len(seq.block_ids), (nb, len(seq.block_ids))
        pad = nb * bs - s
        if pad:
            layer_k = jnp.pad(layer_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            layer_v = jnp.pad(layer_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kb = layer_k.reshape(l, nb, bs, h, d)
        vb = layer_v.reshape(l, nb, bs, h, d)
        ids = jnp.asarray(seq.block_ids[:nb], jnp.int32)
        self.k = self.k.at[:, ids].set(kb)
        self.v = self.v.at[:, ids].set(vb)
        seq.length = s

    def append_token(self, seq: SeqBlocks, k_t, v_t):
        """k_t/v_t [L, H, D]: append one token's KV."""
        pos = seq.length
        blk = seq.block_ids[pos // self.block_size]
        off = pos % self.block_size
        self.k = self.k.at[:, blk, off].set(k_t)
        self.v = self.v.at[:, blk, off].set(v_t)
        seq.length += 1

    def gather(self, seq: SeqBlocks, layer: int):
        """Reference gather of one sequence's KV: ([S,H,D], [S,H,D])."""
        ids = jnp.asarray(seq.block_ids, jnp.int32)
        k = self.k[layer, ids].reshape(-1, *self.shape[3:])[:seq.length]
        v = self.v[layer, ids].reshape(-1, *self.shape[3:])[:seq.length]
        return k, v


def fragmentation_waste(seqs: List[SeqBlocks], block_size: int) -> Dict:
    """Internal fragmentation stats: bytes reserved but unused.

    The survey's motivation for PagedAttention: contiguous preallocation
    wastes (max_len - len) per sequence; paging wastes < block_size.
    """
    internal = sum(len(s.block_ids) * block_size - s.length for s in seqs)
    used = sum(s.length for s in seqs)
    return {"internal_slots_wasted": internal,
            "used_slots": used,
            "waste_frac": internal / max(1, internal + used)}
