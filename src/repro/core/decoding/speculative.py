"""Multimodal speculative decoding (survey dim 4a): draft-then-verify.

Reproduces the surveyed pipeline:

  * Gagrani et al. [CVPR'24w]: a small LANGUAGE-ONLY draft model speculates
    for a multimodal target -- the draft never sees the visual embeddings
    (its prompt is the text tokens only), the target verifies with full
    multimodal context. We implement exactly that asymmetry: the target's
    cache is built over [visual | text], the draft's over text only, and the
    two position streams are reconciled by the visual offset.
  * standard Leviathan/Chen rejection sampling: accept draft token x with
    prob min(1, p_target(x)/p_draft(x)); on rejection resample from
    norm(max(0, p_t - p_d)); if the whole block survives, sample one bonus
    token from the target's last logits.
  * LANTERN [ICLR'25] relaxed acceptance: visual AR models spread mass over
    many semantically-equivalent tokens ("token selection ambiguity"), so
    LANTERN aggregates target probability over the draft token's latent
    neighbourhood B_k(x) before the acceptance test:
        accept with prob min(1, sum_{y in B_k(x)} p_t(y) / p_d(x))
    bounded by a total-variation budget delta. ``lantern_k`` > 0 enables it;
    the neighbourhood is cosine-kNN in the target's unembedding space.

Verification is ONE ``model.extend`` call (gamma+1 logits in a single pass)
against the target cache -- the memory-bound decode loop is replaced by a
compute-dense block scoring, which is the entire point of the technique.
Cache rollback is implicit: the next extend overwrites the rejected slots,
and causal masking hides stale positions (q_pos < k_pos) meanwhile.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decoding.sampling import sample_probs


@dataclasses.dataclass
class SpecStats:
    proposed: int = 0
    accepted: int = 0
    bonus: int = 0
    target_calls: int = 0
    draft_calls: int = 0

    @property
    def tokens_emitted(self) -> int:
        return self.accepted + self.bonus + self.rejected_resamples

    @property
    def rejected_resamples(self) -> int:
        # every target call emits at least one token (resample or bonus)
        return self.target_calls - self.bonus

    def mean_accepted_per_call(self) -> float:
        return (self.accepted + self.target_calls) / max(self.target_calls, 1)


def acceptance_rate(stats: SpecStats) -> float:
    return stats.accepted / max(stats.proposed, 1)


def _lantern_neighbourhood(embed_w: np.ndarray, k: int):
    """Precompute cosine-kNN token neighbourhoods in unembedding space."""
    w = np.asarray(embed_w, np.float32)
    w = w / (np.linalg.norm(w, axis=1, keepdims=True) + 1e-6)
    sims = w @ w.T
    return np.argsort(-sims, axis=1)[:, :k]        # [V, k], col 0 == self


def speculative_generate(target, draft, t_params, d_params, prompt,
                         *, max_new_tokens: int, gamma: int = 4,
                         temperature: float = 0.0,
                         lantern_k: int = 0, lantern_delta: float = 0.2,
                         visual_embeds: Optional[jax.Array] = None,
                         key: Optional[jax.Array] = None,
                         cache_margin: int = 8):
    """Generate with draft-then-verify. Returns (tokens [T], SpecStats).

    target/draft: Model instances (same vocab). ``prompt`` [S] int32.
    ``visual_embeds`` [Nv, d_target] goes ONLY to the target (language-only
    drafting per Gagrani et al.).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    stats = SpecStats()
    prompt = jnp.asarray(prompt, jnp.int32)[None]          # [1, S]
    s = int(prompt.shape[1])
    nv = 0 if visual_embeds is None else int(visual_embeds.shape[0])
    budget = s + nv + max_new_tokens + gamma + cache_margin

    # --- prefill both models -------------------------------------------
    t_batch = {"tokens": prompt}
    if visual_embeds is not None:
        t_batch["visual_embeds"] = visual_embeds[None]
    t_logits, t_cache = jax.jit(
        lambda p, b: target.prefill(p, b, cache_len=budget))(t_params, t_batch)
    d_logits, d_cache = jax.jit(
        lambda p, b: draft.prefill(p, b, cache_len=budget))(d_params,
                                                            {"tokens": prompt})
    stats.target_calls += 1
    stats.draft_calls += 1

    t_extend = jax.jit(target.extend, static_argnames=())
    d_extend = jax.jit(draft.extend)
    d_decode = jax.jit(draft.decode_step)

    nbhd = None
    if lantern_k > 1:
        ew = t_params["embed"]
        w = ew["unembed"].T if "unembed" in ew else ew["tok"]
        nbhd = _lantern_neighbourhood(np.asarray(w, np.float32), lantern_k)

    def probs(logits):
        return sample_probs(logits, temperature=temperature)

    out = []
    # sample the first token from the prefill logits
    p0 = probs(t_logits[:, -1])
    key, k0 = jax.random.split(key)
    tok = (jnp.argmax(p0, -1) if temperature <= 0
           else jax.random.categorical(k0, jnp.log(p0 + 1e-30))).astype(
               jnp.int32)
    out.append(int(tok[0]))

    t_len = s          # text tokens scored so far (target pos = nv + t_len)
    d_len = s
    while len(out) < max_new_tokens:
        # --- draft gamma tokens autoregressively -----------------------
        draft_toks, draft_ps = [], []
        cur = tok[:, None]
        for g in range(gamma):
            if g == 0:
                lg, d_cache = d_extend(d_params, d_cache, cur,
                                       jnp.int32(d_len))
                lg = lg[:, -1]
            else:
                lg, d_cache = d_decode(d_params, d_cache, cur,
                                       jnp.int32(d_len))
            stats.draft_calls += 1
            d_len += 1
            pd = probs(lg)
            key, kk = jax.random.split(key)
            nxt = (jnp.argmax(pd, -1) if temperature <= 0
                   else jax.random.categorical(kk, jnp.log(pd + 1e-30))
                   ).astype(jnp.int32)
            draft_toks.append(int(nxt[0]))
            draft_ps.append(pd[0])
            cur = nxt[:, None]

        # --- verify: ONE target pass over [tok, draft block] -----------
        block = jnp.asarray([int(tok[0])] + draft_toks, jnp.int32)[None]
        t_logits, t_cache = t_extend(t_params, t_cache, block,
                                     jnp.int32(nv + t_len))
        stats.target_calls += 1
        stats.proposed += gamma

        n_acc = 0
        emitted_reject = False
        for g in range(gamma):
            pt = probs(t_logits[:, g])[0]
            pd = draft_ps[g]
            x = draft_toks[g]
            p_acc_num = float(pt[x])
            if nbhd is not None:
                # LANTERN: aggregate target mass over the latent
                # neighbourhood of x, capped by the TV budget delta
                extra = float(jnp.sum(pt[nbhd[x]])) - float(pt[x])
                p_acc_num = min(p_acc_num + max(extra, 0.0),
                                p_acc_num + lantern_delta)
            ratio = p_acc_num / max(float(pd[x]), 1e-30)
            key, ku = jax.random.split(key)
            u = float(jax.random.uniform(ku)) if temperature > 0 else 0.5
            if ratio >= 1.0 or u < ratio:
                n_acc += 1
                out.append(x)
                if len(out) >= max_new_tokens:
                    break
            else:
                # rejection: resample from norm(max(0, p_t - p_d))
                resid = jnp.clip(pt - pd, 0.0)
                tot = float(jnp.sum(resid))
                if tot <= 1e-9:
                    resid = pt
                    tot = float(jnp.sum(resid))
                key, kr = jax.random.split(key)
                y = int(jax.random.categorical(
                    kr, jnp.log(resid / tot + 1e-30)))
                out.append(y)
                emitted_reject = True
                break
        stats.accepted += n_acc

        if not emitted_reject and len(out) < max_new_tokens and n_acc == gamma:
            # whole block accepted: bonus token from the last target logits
            pt = probs(t_logits[:, gamma])[0]
            key, kb = jax.random.split(key)
            y = (int(jnp.argmax(pt)) if temperature <= 0
                 else int(jax.random.categorical(kb, jnp.log(pt + 1e-30))))
            out.append(y)
            stats.bonus += 1

        t_len += 1 + n_acc          # target consumed tok + accepted drafts
        # draft cache rollback: rewind logical length to the target's
        d_len = t_len
        tok = jnp.asarray([out[-1]], jnp.int32)
        if len(out) >= max_new_tokens:
            break

    return out[:max_new_tokens], stats
