"""Qwen2-VL-2B (VLM backbone; M-RoPE, dynamic resolution). [arXiv:2409.12191]

The ViT frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (num_visual_tokens x d_model after projector).
This arch is the primary target of the survey's dimension-1 (visual token
compression) pipeline.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    activation="swiglu",
    rope_theta=1.0e6,
    use_mrope=True,
    mrope_sections=(16, 24, 24),
    num_visual_tokens=1024,       # default dynamic-resolution budget
    tie_embeddings=True,
    sliding_window=16384,         # long_500k variant
)

SMOKE_CONFIG = CONFIG.with_(
    name="qwen2-vl-smoke",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, num_visual_tokens=16,
    mrope_sections=(8, 12, 12), sliding_window=64, dtype="float32",
)
