"""Benchmark: KV cache management (survey dim 2a/2b).

  * selector fidelity: decode-logit KL divergence of each eviction policy
    vs the full cache at matched budgets (the eviction-quality claim),
  * budget policies: pyramid/adaptive vs uniform at the same total budget,
  * paging: fragmentation waste of paged vs reserve-max allocation
    (PagedAttention's core claim), plus paged-kernel gather overhead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jit
from repro.configs import get_config
# analysis: allow L001 (micro-bench: times internal kv-cache kernels
# directly; the facade would add dispatch overhead to the measurement)
from repro.core.kv_cache.budget import (adaptive_budgets, cake_layer_scores,
                                        pyramid_budgets, uniform_budgets)
# analysis: allow L001 (micro-bench)
from repro.core.kv_cache.paged import SeqBlocks, fragmentation_waste
# analysis: allow L001 (micro-bench)
from repro.core.kv_cache.selection import SELECTORS
from repro.models import build
from repro.models.attention import simple_sdpa


def _kl(p_logits, q_logits):
    p = jax.nn.log_softmax(p_logits, -1)
    q = jax.nn.log_softmax(q_logits, -1)
    return float(jnp.sum(jnp.exp(p) * (p - q), -1).mean())


def selector_fidelity() -> None:
    """One attention layer, long synthetic history, decode one step."""
    rng = np.random.RandomState(0)
    b, s, h, d, hq = 2, 256, 2, 16, 4
    k = jnp.asarray(rng.randn(b, s, h, d) * 0.4, jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    q = jnp.asarray(rng.randn(b, 1, h, hq // h, d), jnp.float32)
    pos = jnp.arange(s)
    full = simple_sdpa(q, k, v, q_pos=jnp.asarray([[s]] * b), k_pos=pos,
                       causal=True)
    attn_hist = jax.nn.softmax(
        jnp.einsum("bqkgd,bckd->bkgqc", q, k).reshape(b, -1, 1, s) * 4.0, -1)
    for name in sorted(SELECTORS):
        for budget in (64, 32):
            k2, v2, kept = SELECTORS[name](k, v, budget=budget,
                                           attn=attn_hist)
            out = simple_sdpa(q, k2, v2, q_pos=jnp.asarray([[s]] * b),
                              k_pos=kept, causal=True)
            err = float(jnp.abs(out - full).mean() /
                        (jnp.abs(full).mean() + 1e-9))
            us = time_jit(jax.jit(
                lambda kk, vv, n=name, bu=budget: SELECTORS[n](
                    kk, vv, budget=bu, attn=attn_hist)[0]), k, v)
            emit(f"kvsel/{name}/b{budget}", us, f"rel_err={err:.4f}")


def budget_policies() -> None:
    """Same total budget, different per-layer split: attention mass kept.

    Two synthetic regimes decide the verdict on PyramidKV's premise:
      * funneled  -- deep layers concentrate mass on a few hot tokens (the
        "pyramidal information funneling" the paper observed): pyramid and
        adaptive beat uniform;
      * flat      -- mild sharpening only, no funnel: uniform is NOT beaten
        (DynamicKV's critique of static architectural heuristics).
    """
    rng = np.random.RandomState(1)
    layers, s = 8, 128

    def synth(funneled: bool):
        attns = []
        for li in range(layers):
            base = jax.nn.softmax(jnp.asarray(rng.randn(1, 2, 16, s)), -1)
            if funneled:
                # fraction of mass on 2 hot tokens (attention sinks) grows
                # to 95% with depth -- PyramidKV's measured funnel
                hot = jnp.zeros((s,)).at[
                    jnp.asarray(rng.choice(s, 2, replace=False))].set(0.5)
                w = li / (layers - 1) * 0.95
                a = (1 - w) * base + w * hot[None, None, None, :]
            else:
                sharp = 0.3 + 2.5 * li / layers
                a = jax.nn.softmax(
                    jnp.asarray(rng.randn(1, 2, 16, s)) * sharp, -1)
            attns.append(a)
        return attns

    total = layers * 24
    for regime in ("funneled", "flat"):
        attns = synth(regime == "funneled")
        schemes = {
            "uniform": uniform_budgets(total, layers, min_per_layer=1),
            "pyramid": pyramid_budgets(total, layers, min_per_layer=1),
            "adaptive": adaptive_budgets(total, cake_layer_scores(attns),
                                         min_per_layer=1),
        }
        for name, budgets in schemes.items():
            mass = 0.0
            for li, a in enumerate(attns):
                scores = np.asarray(a.sum((0, 1, 2)))
                top = np.sort(scores)[::-1][:budgets[li]]
                mass += float(top.sum() / scores.sum())
            emit(f"kvbudget/{regime}/{name}", 0.0,
                 f"attn_mass_kept={mass / layers:.4f};total={total}")


def paging() -> None:
    rng = np.random.RandomState(2)
    lengths = rng.randint(16, 900, size=64)
    max_len = 1024
    bs = 16
    seqs = [SeqBlocks(block_ids=list(range((l + bs - 1) // bs)), length=l)
            for l in lengths]
    w = fragmentation_waste(seqs, bs)
    contiguous_waste = sum(max_len - l for l in lengths)
    emit("paging/fragmentation", 0.0,
         f"paged_waste_frac={w['waste_frac']:.4f};"
         f"contig_waste_frac={contiguous_waste / (64 * max_len):.4f}")
    # paged kernel vs contiguous reference decode (structural overhead)
    from repro.kernels import ref
    b, hq, kvh, d, page, pps = 4, 8, 2, 32, 16, 8
    P = 64
    q = jnp.asarray(rng.randn(b, hq, d), jnp.float32)
    kp = jnp.asarray(rng.randn(P, page, kvh, d), jnp.float32)
    vp = jnp.asarray(rng.randn(P, page, kvh, d), jnp.float32)
    bt = jnp.asarray(rng.choice(P, (b, pps)), jnp.int32)
    sl = jnp.asarray(rng.randint(page, pps * page, b), jnp.int32)
    us_paged = time_jit(jax.jit(
        lambda *a: ref.paged_attention_ref(*a)), q, kp, vp, bt, sl)
    k_contig = kp[bt].reshape(b, pps * page, kvh, d)
    v_contig = vp[bt].reshape(b, pps * page, kvh, d)
    us_contig = time_jit(jax.jit(
        lambda qq, kk, vv: ref.flash_attention_ref(
            jnp.swapaxes(qq[:, None], 1, 2).reshape(b, hq, 1, d),
            jnp.swapaxes(kk, 1, 2), jnp.swapaxes(vv, 1, 2), causal=False)),
        q, k_contig, v_contig)
    emit("paging/gather_overhead", us_paged,
         f"contiguous_us={us_contig:.1f}")


def run() -> None:
    selector_fidelity()
    budget_policies()
    paging()


if __name__ == "__main__":
    run()
